//! Online value-function learning — the paper's §1 motivation:
//! "simultaneous learning of a value function and a policy in
//! reinforcement learning".
//!
//! A 1-D corridor MDP (positions 0..N, +1 reward at the right end,
//! γ-discounted) is solved by TD(0) where the value function V(s) is the
//! FIGMN's conditional mean E[v | s], learned online through the
//! coordinator's **regression** path — every TD target is one `learn_reg`
//! record, every bootstrap read is one `predict_reg`. Single pass over
//! experience, no replay buffer, no parameter vector.
//!
//! Run: `cargo run --release --example rl_value`

use figmn::coordinator::{Metrics, ModelSpec, Registry};
use figmn::gmm::GmmConfig;
use figmn::rng::Pcg64;
use std::sync::Arc;

const N_STATES: usize = 20;
const GAMMA: f64 = 0.95;

fn main() {
    let registry = Registry::new(Arc::new(Metrics::new()));
    registry
        .create(
            // 1 feature (state), 1 continuous output (value).
            ModelSpec::new("V", 1, 1)
                .with_gmm(GmmConfig::new(1).with_delta(0.15).with_beta(0.2).without_pruning())
                .with_stds(vec![N_STATES as f64 / 3.0]),
        )
        .unwrap();
    let router = registry.router("V").unwrap();
    let mut rng = Pcg64::seed(7);

    // A fixed stochastic policy: move right with p=0.7, left 0.3.
    let mut episodes = 0;
    let mut steps = 0u64;
    while episodes < 400 {
        let mut s = rng.below(N_STATES - 1); // random start
        loop {
            steps += 1;
            let right = rng.uniform() < 0.7;
            let s2 = if right { s + 1 } else { s.saturating_sub(1) };
            let (reward, done) = if s2 == N_STATES - 1 { (1.0, true) } else { (0.0, false) };
            // TD(0) target: r + γ·V(s′) (bootstrap through the model).
            let v_next = if done {
                0.0
            } else {
                router.predict_reg(&[s2 as f64]).map(|t| t[0]).unwrap_or(0.0)
            };
            let target = reward + GAMMA * v_next;
            router.learn_reg(vec![s as f64], vec![target]).unwrap();
            if done {
                break;
            }
            s = s2;
        }
        episodes += 1;
    }

    // The analytic value for this chain is monotone in s and ≈ γ^{E[steps to goal]}.
    let stats = registry.stats("V").unwrap();
    println!(
        "trained V(s) over {episodes} episodes / {steps} TD steps, {} components",
        stats.get("components").unwrap()
    );
    let mut prev = -1.0;
    let mut monotone_violations = 0;
    print!("V: ");
    for s in (0..N_STATES - 1).step_by(3) {
        let v = router.predict_reg(&[s as f64]).unwrap()[0];
        print!("V({s:2})={v:5.2}  ");
        if v < prev - 0.05 {
            monotone_violations += 1;
        }
        prev = v;
    }
    println!();
    let v_near = router.predict_reg(&[(N_STATES - 2) as f64]).unwrap()[0];
    let v_far = router.predict_reg(&[0.0]).unwrap()[0];
    println!("near-goal V={v_near:.2}, far V={v_far:.2}, monotone violations={monotone_violations}");
    assert!(v_near > 0.6, "near-goal value too low: {v_near}");
    assert!(v_near > v_far + 0.3, "value gradient missing");
    assert!(monotone_violations <= 1, "value function not monotone-ish");
    println!("rl_value OK — TD(0) through the coordinator's regression path");
}
