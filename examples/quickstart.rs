//! Quickstart: the paper's algorithm in five minutes.
//!
//! Trains both IGMN variants single-pass on the iris-shaped synthetic
//! dataset, verifies they produce identical predictions (the paper's
//! Section 4 equivalence check), and shows the autoassociative
//! inference API (any element predicts any other).
//!
//! Run: `cargo run --release --example quickstart`

use figmn::data::synth;
use figmn::eval::{multiclass_auc, Stopwatch};
use figmn::gmm::supervised::{supervised_figmn, supervised_igmn};
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
use figmn::rng::Pcg64;

fn main() {
    // ---- 1. A dataset (iris-shaped synthetic stand-in; see DESIGN.md §5)
    let spec = synth::spec("iris").unwrap();
    let data = synth::generate(spec, 42);
    let stds = data.feature_stds();
    println!("dataset: {} (N={}, D={}, classes={})", data.name, data.len(), data.dim(), data.n_classes);

    // 80/20 split.
    let mut rng = Pcg64::seed(7);
    let order = rng.permutation(data.len());
    let (tr, te) = order.split_at(data.len() * 4 / 5);
    let train = data.subset(tr);
    let test = data.subset(te);

    // ---- 2. Single-pass supervised training, both variants.
    let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.001).without_pruning();
    let mut fast = supervised_figmn(cfg.clone(), &stds, data.n_classes);
    let mut orig = supervised_igmn(cfg, &stds, data.n_classes);

    let mut sw_fast = Stopwatch::new();
    let mut sw_orig = Stopwatch::new();
    for (x, &y) in train.features.iter().zip(train.labels.iter()) {
        sw_fast.time(|| fast.train_one(x, y));
        sw_orig.time(|| orig.train_one(x, y));
    }
    println!(
        "trained: {} components | FIGMN {:.4}s vs IGMN {:.4}s (single pass)",
        fast.num_components(),
        sw_fast.seconds(),
        sw_orig.seconds()
    );

    // ---- 3. The equivalence claim: identical predictions.
    let scores_fast: Vec<Vec<f64>> = test.features.iter().map(|x| fast.class_scores(x)).collect();
    let scores_orig: Vec<Vec<f64>> = test.features.iter().map(|x| orig.class_scores(x)).collect();
    let max_diff = scores_fast
        .iter()
        .flatten()
        .zip(scores_orig.iter().flatten())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0_f64, f64::max);
    println!("max |FIGMN − IGMN| prediction difference: {max_diff:.2e} (paper: \"exactly the same results\")");
    assert!(max_diff < 1e-6);

    let auc = multiclass_auc(&scores_fast, &test.labels, data.n_classes);
    println!("holdout AUC: {auc:.3}");

    // ---- 4. Autoassociative inference: any element predicts any other.
    // Train an unsupervised joint model on (x, y=sin x) pairs…
    let mut joint = Figmn::new(
        GmmConfig::new(2).with_delta(0.1).with_beta(0.2).without_pruning(),
        &[1.8, 0.7],
    );
    // (x kept in [−π/2, π/2] so the inverse direction is single-valued —
    // a conditional mean cannot represent multi-branch inverses.)
    let mut rng = Pcg64::seed(1);
    for _ in 0..2000 {
        let x = rng.uniform_in(-1.5, 1.5);
        joint.learn(&[x, x.sin()]);
    }
    // …then run it FORWARD (x → y) and BACKWARD (y → x) with the same model.
    let y_hat = joint.predict(&[1.5], &[0], &[1]);
    let x_hat = joint.predict(&[0.5], &[1], &[0]);
    println!(
        "forward  sin(1.5) ≈ {:+.3} (true {:+.3}) | inverse sin(x)=0.5 → x ≈ {:+.3} (one branch of asin: {:+.3})",
        y_hat[0],
        1.5_f64.sin(),
        x_hat[0],
        0.5_f64.asin()
    );
    println!("quickstart OK");
}
