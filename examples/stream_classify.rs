//! END-TO-END DRIVER (DESIGN.md §4 row "E2E"): the full three-layer
//! system on a real small workload.
//!
//! Phase 1 — the paper's headline (Tables 2/3 shape): single-component
//!   training/testing time on the MNIST-shaped dataset (N=1000, D=784),
//!   original IGMN vs Fast IGMN, and the speedup factor.
//!
//! Phase 2 — the full pipeline: TCP coordinator → router → worker
//!   (native learn hot path + XLA predict artifact on the inference
//!   path), streaming a 3-class workload over the wire, then measuring
//!   classification quality and serving throughput. Proves L3 (rust
//!   coordinator) ∘ L2 (JAX model) ∘ L1 (Pallas kernel) compose.
//!
//! Run: `make artifacts && cargo run --release --example stream_classify`
//! Results are recorded in EXPERIMENTS.md §E2E.

use figmn::coordinator::protocol::{Request, Response};
use figmn::coordinator::{serve, Metrics, Registry, ServerConfig};
use figmn::data::synth;
use figmn::eval::{multiclass_auc, Stopwatch};
use figmn::gmm::supervised::{supervised_figmn, supervised_igmn};
use figmn::gmm::GmmConfig;
use figmn::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    phase1_headline_speedup();
    phase2_full_pipeline();
}

/// Paper Tables 2/3 shape at the MNIST row: δ=1, β=0 → exactly one
/// Gaussian component; the timing difference is pure O(D³) vs O(D²).
fn phase1_headline_speedup() {
    println!("== Phase 1: headline speedup (MNIST-shaped, N=1000, D=784, K=1) ==");
    let data = synth::generate(synth::spec("MNIST").unwrap(), 42);
    let stds = data.feature_stds();
    let half = data.len() / 2;
    let idx: Vec<usize> = (0..data.len()).collect();
    let (tr, te) = idx.split_at(half);
    let train = data.subset(tr);
    let test = data.subset(te);

    let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();

    let mut fast = supervised_figmn(cfg.clone(), &stds, data.n_classes);
    let mut sw_fast_train = Stopwatch::new();
    sw_fast_train.time(|| {
        for (x, &y) in train.features.iter().zip(train.labels.iter()) {
            fast.train_one(x, y);
        }
    });
    let mut sw_fast_test = Stopwatch::new();
    let scores_fast: Vec<Vec<f64>> =
        sw_fast_test.time(|| test.features.iter().map(|x| fast.class_scores(x)).collect());

    let mut orig = supervised_igmn(cfg, &stds, data.n_classes);
    let mut sw_orig_train = Stopwatch::new();
    sw_orig_train.time(|| {
        for (x, &y) in train.features.iter().zip(train.labels.iter()) {
            orig.train_one(x, y);
        }
    });
    let mut sw_orig_test = Stopwatch::new();
    let scores_orig: Vec<Vec<f64>> =
        sw_orig_test.time(|| test.features.iter().map(|x| orig.class_scores(x)).collect());

    let auc_fast = multiclass_auc(&scores_fast, &test.labels, data.n_classes);
    let auc_orig = multiclass_auc(&scores_orig, &test.labels, data.n_classes);
    println!(
        "  IGMN  train {:8.3}s   test {:8.3}s   AUC {:.3}",
        sw_orig_train.seconds(),
        sw_orig_test.seconds(),
        auc_orig
    );
    println!(
        "  FIGMN train {:8.3}s   test {:8.3}s   AUC {:.3}",
        sw_fast_train.seconds(),
        sw_fast_test.seconds(),
        auc_fast
    );
    println!(
        "  speedup: {:.1}× training, {:.1}× testing (paper: ~26× / ~370× at this shape)",
        sw_orig_train.seconds() / sw_fast_train.seconds().max(1e-9),
        sw_orig_test.seconds() / sw_fast_test.seconds().max(1e-9),
    );
}

fn send(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, req: &Request) -> Response {
    let mut line = req.to_json().to_string_compact();
    line.push('\n');
    writer.write_all(line.as_bytes()).unwrap();
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    Response::from_line(&buf).unwrap()
}

fn phase2_full_pipeline() {
    println!("\n== Phase 2: full pipeline over TCP (L3 ∘ L2 ∘ L1) ==");
    let have_artifacts = figmn::runtime::Runtime::default_dir().join("manifest.json").exists();
    if !have_artifacts {
        println!("  (no artifacts/ — run `make artifacts` for the XLA inference path)");
    }

    // Coordinator with the XLA predict artifact for 2-feature/3-class
    // models (the `blobs3` AOT config).
    let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
    let server = serve(
        registry.clone(),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            xla_config: have_artifacts.then(|| "blobs3".to_string()),
        },
    )
    .expect("server");
    println!("  coordinator on {}", server.local_addr);

    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let create = Request::CreateModel {
        model: "stream".into(),
        n_features: 2,
        n_classes: 3,
        delta: 0.5,
        beta: 0.05,
        stds: vec![4.0, 4.0],
        shards: 1,
        kernel_mode: figmn::gmm::KernelMode::Strict,
        search_mode: figmn::gmm::SearchMode::Strict,
    };
    assert_eq!(send(&mut reader, &mut writer, &create), Response::Ok);

    // Stream 3000 labeled records; interleave predictions every 10th.
    let mut rng = Pcg64::seed(99);
    let centers = [[0.0_f64, 0.0], [8.0, 8.0], [0.0, 8.0]];
    let n_stream = 3000;
    let started = Instant::now();
    let mut predictions = 0u64;
    for i in 0..n_stream {
        let c = i % 3;
        let x = vec![
            centers[c][0] + rng.normal() * 0.6,
            centers[c][1] + rng.normal() * 0.6,
        ];
        let resp = send(
            &mut reader,
            &mut writer,
            &Request::Learn { model: "stream".into(), features: x.clone(), label: c },
        );
        assert_eq!(resp, Response::Ok);
        if i % 10 == 9 {
            let resp = send(
                &mut reader,
                &mut writer,
                &Request::Predict { model: "stream".into(), features: x },
            );
            assert!(matches!(resp, Response::Scores { .. }));
            predictions += 1;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    println!(
        "  streamed {n_stream} learns + {predictions} predicts in {wall:.2}s \
         ({:.0} records/s over TCP, single client)",
        (n_stream as f64 + predictions as f64) / wall
    );

    // Holdout quality through the wire.
    let mut correct = 0;
    let n_test = 300;
    let mut scores_all = Vec::new();
    let mut truth = Vec::new();
    for i in 0..n_test {
        let c = i % 3;
        let x = vec![
            centers[c][0] + rng.normal() * 0.6,
            centers[c][1] + rng.normal() * 0.6,
        ];
        match send(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "stream".into(), features: x },
        ) {
            Response::Scores { scores, class } => {
                if class == c {
                    correct += 1;
                }
                scores_all.push(scores);
                truth.push(c);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    let auc = multiclass_auc(&scores_all, &truth, 3);
    println!("  holdout: accuracy {}/{n_test}, AUC {auc:.3}", correct);

    // Coordinator stats (incl. whether the XLA path served batches).
    match send(&mut reader, &mut writer, &Request::Stats { model: "stream".into() }) {
        Response::Stats(j) => {
            println!(
                "  stats: learned={} predicted={} components={} xla_batches={}",
                j.get("learned").unwrap(),
                j.get("predicted").unwrap(),
                j.get("components").unwrap(),
                j.get("xla_batches").unwrap()
            );
        }
        other => panic!("unexpected {other:?}"),
    }
    assert!(correct as f64 / n_test as f64 > 0.95, "pipeline accuracy too low");
    server.shutdown();
    println!("stream_classify OK");
}
