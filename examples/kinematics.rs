//! Simultaneous forward + inverse kinematics with ONE model — the
//! application the IGMN line of work was built for (paper §1: "useful
//! for simultaneous learning of forward and inverse kinematics").
//!
//! A planar 2-link arm: joint angles (θ₁, θ₂) → end-effector (x, y).
//! We stream random motor babbling as joint vectors [θ₁, θ₂, x, y]; the
//! same mixture then answers both directions:
//!   forward:  given (θ₁, θ₂) predict (x, y)
//!   inverse:  given (x, y) predict (θ₁, θ₂)   — the classic ill-posed
//!             problem; the mixture returns a consistent branch.
//!
//! Run: `cargo run --release --example kinematics`

use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
use figmn::rng::Pcg64;

const L1: f64 = 1.0;
const L2: f64 = 0.6;

fn fk(t1: f64, t2: f64) -> (f64, f64) {
    let x = L1 * t1.cos() + L2 * (t1 + t2).cos();
    let y = L1 * t1.sin() + L2 * (t1 + t2).sin();
    (x, y)
}

fn main() {
    // Restrict θ to a half-workspace so the inverse is single-branched —
    // the honest way to demo conditional-mean inverse models.
    let mut rng = Pcg64::seed(5);
    let cfg = GmmConfig::new(4).with_delta(0.08).with_beta(0.15).without_pruning();
    let mut model = Figmn::new(cfg, &[0.9, 0.7, 0.8, 0.8]);

    let n = 20_000;
    for _ in 0..n {
        let t1 = rng.uniform_in(0.0, std::f64::consts::FRAC_PI_2);
        let t2 = rng.uniform_in(0.2, std::f64::consts::FRAC_PI_2);
        let (x, y) = fk(t1, t2);
        model.learn(&[t1, t2, x, y]);
    }
    println!(
        "motor babbling: {n} samples → {} Gaussian components",
        model.num_components()
    );

    // ---- forward predictions
    let mut fwd_err = 0.0;
    let trials = 200;
    for _ in 0..trials {
        let t1 = rng.uniform_in(0.1, 1.4);
        let t2 = rng.uniform_in(0.3, 1.4);
        let (x, y) = fk(t1, t2);
        let pred = model.predict(&[t1, t2], &[0, 1], &[2, 3]);
        fwd_err += ((pred[0] - x).powi(2) + (pred[1] - y).powi(2)).sqrt();
    }
    fwd_err /= trials as f64;
    println!("forward kinematics:  mean end-effector error {fwd_err:.3} (link lengths 1.0/0.6)");

    // ---- inverse predictions, validated through the true FK
    let mut inv_err = 0.0;
    for _ in 0..trials {
        let t1 = rng.uniform_in(0.1, 1.4);
        let t2 = rng.uniform_in(0.3, 1.4);
        let (x, y) = fk(t1, t2);
        let joints = model.predict(&[x, y], &[2, 3], &[0, 1]);
        let (x2, y2) = fk(joints[0], joints[1]);
        inv_err += ((x2 - x).powi(2) + (y2 - y).powi(2)).sqrt();
    }
    inv_err /= trials as f64;
    println!("inverse kinematics:  mean reprojection error {inv_err:.3}");

    assert!(fwd_err < 0.15, "forward error too high: {fwd_err}");
    assert!(inv_err < 0.15, "inverse error too high: {inv_err}");
    println!("kinematics OK — one model, both directions");
}
