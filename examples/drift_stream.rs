//! Concept drift on a live stream: the component lifecycle (creation via
//! the χ² novelty test, removal via the §2.3 pruning rule) lets the
//! mixture track a distribution that moves under it — the data-stream
//! scenario the paper's single-pass property targets.
//!
//! Run: `cargo run --release --example drift_stream`

use figmn::coordinator::{Metrics, ModelSpec, Registry, RoutingPolicy};
use figmn::gmm::GmmConfig;
use figmn::rng::Pcg64;
use std::sync::Arc;

fn main() {
    let registry = Registry::new(Arc::new(Metrics::new()));
    let gmm = GmmConfig::new(1).with_delta(0.4).with_beta(0.1).with_pruning(200, 2.0);
    registry
        .create(
            ModelSpec::new("drift", 2, 2)
                .with_gmm(gmm)
                .with_stds(vec![3.0, 3.0])
                .with_shards(2, RoutingPolicy::Broadcast),
        )
        .unwrap();
    let router = registry.router("drift").unwrap();
    let mut rng = Pcg64::seed(3);

    // Phase A: classes at (0,0) and (6,6).
    // Phase B (drift): classes JUMP to (12,0) and (0,12).
    let phases: [[[f64; 2]; 2]; 2] = [
        [[0.0, 0.0], [6.0, 6.0]],
        [[12.0, 0.0], [0.0, 12.0]],
    ];

    for (p, centers) in phases.iter().enumerate() {
        for i in 0..1500 {
            let c = i % 2;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        // Accuracy within the current phase.
        let mut correct = 0;
        let trials = 200;
        for i in 0..trials {
            let c = i % 2;
            let x = vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7];
            let scores = router.predict(&x).unwrap();
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == c {
                correct += 1;
            }
        }
        let stats = registry.stats("drift").unwrap();
        println!(
            "phase {}: accuracy {}/{} | components {} | learned {}",
            (b'A' + p as u8) as char,
            correct,
            trials,
            stats.get("components").unwrap(),
            stats.get("learned").unwrap(),
        );
        assert!(correct * 100 >= trials * 90, "phase {p} accuracy too low");
    }
    println!("drift_stream OK — model tracked an abrupt distribution shift single-pass");
}
