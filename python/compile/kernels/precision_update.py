"""L1 Pallas kernel: the fused Sherman–Morrison rank-two precision update
(paper Eqs. 20–21) with the Matrix-Determinant-Lemma factors (Eqs. 25–26).

One grid step updates one component: two D-length mat-vecs, two symmetric
rank-one GERs, all on the VMEM-resident (D, D) block. ω = 0 (masked /
zero-responsibility components) degrades to an exact no-op because every
correction term carries a factor of ω — no branching needed inside the
kernel.

Returns (μ', Λ', log|C|') per component; the log-det arithmetic happens
in-kernel from the two lemma factors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _update_kernel(x_ref, omega_ref, mu_ref, lam_ref, ld_ref,
                   mu_out, lam_out, ld_out):
    x = x_ref[...]  # (D,)
    omega = omega_ref[...][0]  # scalar
    mu = mu_ref[...][0]  # (D,)
    lam = lam_ref[...][0]  # (D, D)
    ld = ld_ref[...][0]  # scalar log|C(t-1)|
    D = x.shape[0]

    one_minus = 1.0 - omega
    e = x - mu  # Eq. 6 (old-mean error; DESIGN.md §Deviations)
    dmu = omega * e  # Eq. 8
    mu_new = mu + dmu  # Eq. 9

    # ---- Eq. 20: rank-one downdate of Λ/(1−ω) ----
    w = lam @ e  # (D,)
    q = jnp.sum(e * w)
    denom1 = 1.0 + omega / one_minus * q
    lam_bar = lam / one_minus - (omega / (one_minus * one_minus * denom1)) * jnp.outer(w, w)

    # ---- Eq. 25 in log space ----
    ld_bar = D * jnp.log(one_minus) + ld + jnp.log(denom1)

    # ---- Eq. 21: rank-one update with Δμ ----
    w2 = lam_bar @ dmu
    r = jnp.sum(dmu * w2)
    denom2 = 1.0 - r
    lam_new = lam_bar + jnp.outer(w2, w2) / denom2

    # ---- Eq. 26 in log space ----
    ld_new = ld_bar + jnp.log(denom2)

    mu_out[...] = mu_new[None]
    lam_out[...] = lam_new[None]
    ld_out[...] = ld_new[None]


def precision_update(x, omegas, mus, lambdas, log_dets):
    """Apply the fused update to every component.

    x: (D,), omegas: (K,) — per-component ω = p(j|x)/sp_j (0 for masked),
    mus: (K, D), lambdas: (K, D, D), log_dets: (K,).
    Returns (mus', lambdas', log_dets').
    """
    K, D = mus.shape
    return pl.pallas_call(
        _update_kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((D,), lambda k: (0,)),
            pl.BlockSpec((1,), lambda k: (k,)),
            pl.BlockSpec((1, D), lambda k: (k, 0)),
            pl.BlockSpec((1, D, D), lambda k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k: (k,)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda k: (k, 0)),
            pl.BlockSpec((1, D, D), lambda k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k: (k,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, D), x.dtype),
            jax.ShapeDtypeStruct((K, D, D), x.dtype),
            jax.ShapeDtypeStruct((K,), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, omegas, mus, lambdas, log_dets)
