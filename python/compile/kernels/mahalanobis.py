"""L1 Pallas kernels: squared Mahalanobis distance (paper Eq. 22).

The K× (D×D) precision tensor is blocked per-component into VMEM
(BlockSpec grid over K); each grid step computes e = x − μ_k and the
quadratic form eᵀΛₖe with one D×D mat-vec — the paper's O(D²) insight
expressed as a TPU HBM↔VMEM schedule (DESIGN.md §Hardware-Adaptation).

Kernels are lowered with interpret=True: on this CPU-PJRT toolchain a
real-TPU Mosaic lowering would emit a custom-call the CPU plugin cannot
execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT requirement; see module docstring.


def _maha_kernel(x_ref, mu_ref, lam_ref, out_ref):
    """One grid step = one component k."""
    x = x_ref[...]  # (D,)
    mu = mu_ref[...]  # (1, D)
    lam = lam_ref[...]  # (1, D, D)
    e = x - mu[0]  # (D,)
    w = lam[0] @ e  # (D,)  one O(D²) mat-vec, VMEM-resident
    out_ref[...] = jnp.sum(e * w)[None]


def mahalanobis(x, mus, lambdas):
    """d²(x, j) for every component j. x: (D,), mus: (K, D),
    lambdas: (K, D, D) -> (K,)."""
    K, D = mus.shape
    return pl.pallas_call(
        _maha_kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((D,), lambda k: (0,)),
            pl.BlockSpec((1, D), lambda k: (k, 0)),
            pl.BlockSpec((1, D, D), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda k: (k,)),
        out_shape=jax.ShapeDtypeStruct((K,), x.dtype),
        interpret=INTERPRET,
    )(x, mus, lambdas)


def _maha_batch_kernel(xs_ref, mu_ref, lam_ref, out_ref):
    """One grid step = one component k against the whole B×D tile.

    E·Λₖ is a (B,D)@(D,D) matmul — MXU-shaped work on real hardware.
    """
    xs = xs_ref[...]  # (B, D)
    mu = mu_ref[...]  # (1, D)
    lam = lam_ref[...]  # (1, D, D)
    e = xs - mu  # (B, D) broadcast over rows
    q = e @ lam[0]  # (B, D)
    out_ref[...] = jnp.sum(q * e, axis=1, keepdims=True)


def mahalanobis_batch(xs, mus, lambdas):
    """Batched distances: xs (B, D) -> (B, K)."""
    B, D = xs.shape
    K = mus.shape[0]
    return pl.pallas_call(
        _maha_batch_kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((B, D), lambda k: (0, 0)),
            pl.BlockSpec((1, D), lambda k: (k, 0)),
            pl.BlockSpec((1, D, D), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((B, K), xs.dtype),
        interpret=INTERPRET,
    )(xs, mus, lambdas)
