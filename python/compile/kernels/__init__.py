"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .mahalanobis import mahalanobis, mahalanobis_batch
from .precision_update import precision_update

__all__ = ["mahalanobis", "mahalanobis_batch", "precision_update"]
