"""Pure-jnp correctness oracles for the Pallas kernels (L1) and the L2
model step.

Everything here is written as the most literal translation of the paper's
equations — including the naive O(D^3) covariance-form IGMN step that the
fast path must match (the paper's Section 4 equivalence claim). The pytest
suite checks kernels/model against these oracles; the Rust integration
tests then check the AOT artifacts against the Rust native implementation,
closing the loop across all three layers.

Conventions (shared with model.py and the Rust side):
  - state is padded to a fixed component capacity K with a boolean mask;
  - determinants are tracked as log|C| (see DESIGN.md §Deviations);
  - Eq. 11 uses the exact old-mean error form (DESIGN.md §Deviations).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LOG_2PI = jnp.log(2.0 * jnp.pi)


def mahalanobis_ref(x, mus, lambdas):
    """Squared Mahalanobis distance of one point to every component.

    x: (D,), mus: (K, D), lambdas: (K, D, D) -> (K,)   [paper Eq. 22]
    """
    e = x[None, :] - mus  # (K, D)
    return jnp.einsum("kd,kde,ke->k", e, lambdas, e)


def mahalanobis_batch_ref(xs, mus, lambdas):
    """Batched distances: xs (B, D) -> (B, K)."""
    e = xs[:, None, :] - mus[None, :, :]  # (B, K, D)
    return jnp.einsum("bkd,kde,bke->bk", e, lambdas, e)


def log_gaussian_ref(d2, log_det, dim):
    """ln N(x; mu, C) from distance + log|C| (Eq. 2 in log space)."""
    return -0.5 * (dim * LOG_2PI + log_det + d2)


def posteriors_ref(log_liks, sps, mask):
    """p(j|x) with sp-proportional priors (Eqs. 3/12), masked softmax.

    log_liks: (..., K), sps: (K,), mask: (K,) -> (..., K)
    """
    logw = jnp.where(mask, log_liks + jnp.log(jnp.maximum(sps, 1e-300)), -jnp.inf)
    best = jnp.max(logw, axis=-1, keepdims=True)
    best = jnp.where(jnp.isfinite(best), best, 0.0)
    w = jnp.where(mask, jnp.exp(logw - best), 0.0)
    total = jnp.sum(w, axis=-1, keepdims=True)
    return w / jnp.maximum(total, 1e-300)


def precision_update_ref(x, mu, lam, log_det, omega):
    """One component's rank-two update, computed the *slow* way as an
    independent oracle: form C = lam^-1, apply the covariance recurrence
    C' = (1-w)C + w e e^T - dmu dmu^T (old-mean-error form), then
    invert/slogdet directly. Returns (mu', lam', log_det').
    """
    del log_det
    e = x - mu
    dmu = omega * e
    mu_new = mu + dmu
    cov = jnp.linalg.inv(lam)
    cov_new = (1.0 - omega) * cov + omega * jnp.outer(e, e) - jnp.outer(dmu, dmu)
    lam_new = jnp.linalg.inv(cov_new)
    _sign, logdet_new = jnp.linalg.slogdet(cov_new)
    return mu_new, lam_new, logdet_new


def igmn_learn_step_ref(x, state, chi2_thresh, sigma_ini):
    """Full IGMN learn step on a padded state — the L2 oracle.

    state: dict with mus (K,D), lambdas (K,D,D), log_dets (K,), sps (K,),
    vs (K,), mask (K,) bool. Returns the new state dict. Purely
    functional; mirrors model.figmn_learn_step's create/update gating so
    the two can be compared on random streams.
    """
    mus, lambdas = state["mus"], state["lambdas"]
    log_dets, sps, vs, mask = state["log_dets"], state["sps"], state["vs"], state["mask"]
    K, D = mus.shape

    d2 = mahalanobis_ref(x, mus, lambdas)
    accept = jnp.any(jnp.where(mask, d2 < chi2_thresh, False))
    any_active = jnp.any(mask)
    full = jnp.all(mask)
    # Capacity full => always update (mirrors GmmConfig::max_components).
    do_update = jnp.logical_and(any_active, jnp.logical_or(accept, full))

    # ---- update branch (all components, soft assignment) ----
    ll = log_gaussian_ref(d2, log_dets, D)
    post = posteriors_ref(ll, sps, mask)
    sps_u = jnp.where(mask, sps + post, sps)
    vs_u = jnp.where(mask, vs + 1, vs)
    omega = jnp.where(mask, post / jnp.maximum(sps_u, 1e-300), 0.0)

    mus_u, lams_u, lds_u = jax.vmap(
        lambda mu_k, lam_k, ld_k, om_k: precision_update_ref(x, mu_k, lam_k, ld_k, om_k)
    )(mus, lambdas, log_dets, omega)
    # omega == 0 rows must be exact no-ops (matches the Rust skip rule).
    keep = (omega > 0.0)[:, None]
    mus_u = jnp.where(keep, mus_u, mus)
    lams_u = jnp.where(keep[..., None], lams_u, lambdas)
    lds_u = jnp.where(omega > 0.0, lds_u, log_dets)

    # ---- create branch: activate the first inactive slot ----
    slot = jnp.argmin(mask)
    lam_init = jnp.diag(1.0 / (sigma_ini ** 2))
    ld_init = jnp.sum(jnp.log(sigma_ini ** 2))
    onehot = jax.nn.one_hot(slot, K, dtype=bool)
    mus_c = jnp.where(onehot[:, None], x[None, :], mus)
    lams_c = jnp.where(onehot[:, None, None], lam_init[None], lambdas)
    lds_c = jnp.where(onehot, ld_init, log_dets)
    sps_c = jnp.where(onehot, 1.0, sps)
    vs_c = jnp.where(onehot, 1, vs)
    mask_c = jnp.logical_or(mask, onehot)

    def pick(u, c):
        return jnp.where(do_update, u, c)

    return {
        "mus": pick(mus_u, mus_c),
        "lambdas": pick(lams_u, lams_c),
        "log_dets": pick(lds_u, lds_c),
        "sps": pick(sps_u, sps_c),
        "vs": pick(vs_u, vs_c),
        "mask": jnp.where(do_update, mask, mask_c),
    }


def conditional_ref(x_known, mu, lam, log_det, n_known):
    """Precision-form conditional (Eq. 27 + Schur marginal) for one
    component, with the known block = leading `n_known` dims.

    Returns (log_lik, reconstruction (D - n_known,)).
    """
    i = n_known
    d = x_known - mu[:i]
    X = lam[:i, :i]
    Y = lam[:i, i:]
    W = lam[i:, i:]
    ytd = Y.T @ d
    z = jnp.linalg.solve(W, ytd)
    recon = mu[i:] - z
    d2 = d @ (X @ d) - ytd @ z
    _sign, logdet_w = jnp.linalg.slogdet(W)
    log_det_a = log_det + logdet_w
    ll = log_gaussian_ref(jnp.maximum(d2, 0.0), log_det_a, i)
    return ll, recon


def predict_ref(x_known, state, n_known):
    """Mixture conditional mean (Eqs. 14 + 27) over a padded state."""
    lls, recons = jax.vmap(
        lambda mu, lam, ld: conditional_ref(x_known, mu, lam, ld, n_known)
    )(state["mus"], state["lambdas"], state["log_dets"])
    post = posteriors_ref(lls, state["sps"], state["mask"])
    return post @ recons
