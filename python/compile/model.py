"""L2: the FIGMN compute graph in JAX, calling the L1 Pallas kernels.

Three jittable entry points over a fixed-capacity padded state (the Rust
coordinator owns dynamic component lifecycle; XLA owns fixed-shape math):

  - figmn_score      — batched log-likelihoods + posteriors (Eqs. 2-3/22)
  - figmn_learn_step — one full Algorithm-1 step: χ² gate, soft update of
                       every component via the fused rank-two kernel, or
                       activation of a fresh slot (Eqs. 4-12, 20-26)
  - figmn_predict    — batched conditional-mean inference (Eqs. 14 + 27)

State layout (all float32 in the AOT artifacts, float64 under tests):
  mus (K, D), lambdas (K, D, D), log_dets (K,), sps (K,), vs (K,),
  mask (K,) bool — plus hyper-parameter tensors chi2_thresh () and
  sigma_ini (D,). Python never runs at serving time: `aot.py` lowers
  these once to HLO text that rust/src/runtime/ loads via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mahalanobis, mahalanobis_batch, precision_update
from .kernels.ref import LOG_2PI, posteriors_ref


def figmn_score(xs, mus, lambdas, log_dets, sps, mask):
    """Score a batch: returns (d2 (B,K), log_liks (B,K), posteriors (B,K)).

    The O(B·K·D²) distance work runs in the Pallas batch kernel; the
    posterior softmax is cheap jnp glue that XLA fuses around it.
    """
    D = mus.shape[1]
    d2 = mahalanobis_batch(xs, mus, lambdas)  # (B, K)
    ll = -0.5 * (D * LOG_2PI + log_dets[None, :] + d2)
    post = posteriors_ref(ll, sps, mask)
    return d2, ll, post


def figmn_learn_step(x, mus, lambdas, log_dets, sps, vs, mask,
                     chi2_thresh, sigma_ini):
    """One Algorithm-1 step. Returns the updated
    (mus, lambdas, log_dets, sps, vs, mask, updated_flag)."""
    K, D = mus.shape

    d2 = mahalanobis(x, mus, lambdas)  # (K,) Pallas kernel, Eq. 22
    accept = jnp.any(jnp.where(mask, d2 < chi2_thresh, False))
    any_active = jnp.any(mask)
    full = jnp.all(mask)
    do_update = jnp.logical_and(any_active, jnp.logical_or(accept, full))

    # ---- update branch ----
    ll = -0.5 * (D * LOG_2PI + log_dets + d2)
    post = posteriors_ref(ll, sps, mask)  # Eqs. 2-3/12
    sps_u = jnp.where(mask, sps + post, sps)  # Eq. 5
    vs_u = jnp.where(mask, vs + 1, vs)  # Eq. 4
    omega = jnp.where(mask, post / jnp.maximum(sps_u, 1e-300), 0.0)  # Eq. 7
    # Fused rank-two kernel (Eqs. 20-21, 25-26); ω = 0 rows are no-ops.
    mus_u, lams_u, lds_u = precision_update(x, omega, mus, lambdas, log_dets)

    # ---- create branch: activate the first inactive slot ----
    slot = jnp.argmin(mask)
    onehot = jax.nn.one_hot(slot, K, dtype=bool)
    lam_init = jnp.diag(1.0 / (sigma_ini ** 2))
    ld_init = jnp.sum(jnp.log(sigma_ini ** 2))
    mus_c = jnp.where(onehot[:, None], x[None, :], mus)
    lams_c = jnp.where(onehot[:, None, None], lam_init[None], lambdas)
    lds_c = jnp.where(onehot, ld_init, log_dets)
    sps_c = jnp.where(onehot, 1.0, sps)
    vs_c = jnp.where(onehot, 1, vs)
    mask_c = jnp.logical_or(mask, onehot)

    pick = lambda u, c: jnp.where(do_update, u, c)  # noqa: E731
    return (
        pick(mus_u, mus_c),
        pick(lams_u, lams_c),
        pick(lds_u, lds_c),
        pick(sps_u, sps_c),
        pick(vs_u, vs_c),
        jnp.where(do_update, mask, mask_c),
        do_update,
    )


def _cholesky_small(W):
    """Cholesky of a small (..., o, o) SPD block, unrolled over the static
    `o` so it lowers to plain HLO ops.

    `jnp.linalg.{solve,slogdet}` lower to typed-FFI LAPACK custom-calls
    that the Rust side's xla_extension 0.5.1 cannot execute — and the
    paper's point (§3) is that only this o×o block ever needs O(o³) work,
    so an unrolled textbook Cholesky is both portable and cheap.
    """
    o = W.shape[-1]
    rows = []  # rows[i][j] = L_ij, entries are (...,) arrays
    for i in range(o):
        row = []
        for j in range(i + 1):
            s = W[..., i, j]
            prev = row if j == i else rows[j]
            for k in range(j):
                s = s - row[k] * prev[k]
            if i == j:
                row.append(jnp.sqrt(jnp.maximum(s, 1e-30)))
            else:
                row.append(s / rows[j][j])
        rows.append(row)
    # Assemble (..., o, o) lower-triangular L.
    zero = jnp.zeros_like(W[..., 0, 0])
    L = jnp.stack(
        [
            jnp.stack([rows[i][j] if j <= i else zero for j in range(o)], axis=-1)
            for i in range(o)
        ],
        axis=-2,
    )
    return L


def _chol_solve_small(L, b):
    """Solve (L·Lᵀ)·x = b with unrolled forward/back substitution.
    L: (..., o, o) lower-triangular, b: (..., o) -> x: (..., o)."""
    o = L.shape[-1]
    y = []
    for i in range(o):
        s = b[..., i]
        for k in range(i):
            s = s - L[..., i, k] * y[k]
        y.append(s / L[..., i, i])
    x = [None] * o
    for i in reversed(range(o)):
        s = y[i]
        for k in range(i + 1, o):
            s = s - L[..., k, i] * x[k]
        x[i] = s / L[..., i, i]
    return jnp.stack(x, axis=-1)


def figmn_predict(xs_known, mus, lambdas, log_dets, sps, mask, n_known: int):
    """Batched conditional-mean inference (Eqs. 14 + 27).

    xs_known: (B, n_known); targets are the trailing D − n_known dims.
    Returns (B, D − n_known) reconstructions. Only the (o, o) target
    block W is ever solved — the O(o³) the paper accepts (§3).
    """
    i = n_known
    X = lambdas[:, :i, :i]  # (K, i, i)
    Y = lambdas[:, :i, i:]  # (K, i, o)
    W = lambdas[:, i:, i:]  # (K, o, o)
    L = _cholesky_small(W)  # (K, o, o)
    logdet_w = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L, axis1=-2, axis2=-1)), axis=-1)
    log_det_a = log_dets + logdet_w

    d = xs_known[:, None, :] - mus[None, :, :i]  # (B, K, i)
    ytd = jnp.einsum("kio,bki->bko", Y, d)  # (B, K, o)
    z = _chol_solve_small(L[None], ytd)  # (B, K, o)
    recon = mus[None, :, i:] - z  # (B, K, o)

    dxd = jnp.einsum("bki,kij,bkj->bk", d, X, d)
    d2 = jnp.maximum(dxd - jnp.einsum("bko,bko->bk", ytd, z), 0.0)
    ll = -0.5 * (i * LOG_2PI + log_det_a[None, :] + d2)
    post = posteriors_ref(ll, sps, mask)  # (B, K), Eq. 14
    return jnp.einsum("bk,bko->bo", post, recon)  # Eq. 27 mixture


def empty_state(K: int, D: int, dtype=jnp.float32):
    """Fresh all-inactive padded state (what the Rust runtime feeds first)."""
    return {
        "mus": jnp.zeros((K, D), dtype),
        "lambdas": jnp.zeros((K, D, D), dtype),
        "log_dets": jnp.zeros((K,), dtype),
        "sps": jnp.zeros((K,), dtype),
        "vs": jnp.zeros((K,), dtype),
        "mask": jnp.zeros((K,), bool),
    }
