"""AOT lowering: JAX (L2 + L1) → HLO **text** artifacts for the Rust
runtime.

HLO text — not `lowered.compile()` / serialized HloModuleProto — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the published `xla` crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are float32 (the PJRT hot path); the Python test-suite checks
the same graphs in float64 against the oracles, and the Rust integration
tests compare artifact outputs against the Rust native f64 implementation
at f32 tolerance.

Usage: python -m compile.aot --out-dir ../artifacts
Emits one .hlo.txt per (entry-point × shape config) plus manifest.json.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", False)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Shape configurations shipped by `make artifacts`.
#   name: (D joint dim, K capacity, B scoring batch, n_known)
# `quickstart` matches examples/quickstart.rs (4 features + 2 classes);
# `iris` matches the Table-1 iris row (4 features + 3 classes);
# `blobs3` matches the coordinator integration tests (2 features + 3
# classes); `mnist_like` is a scoring-only high-D config proving the
# batch kernel lowers at paper scale.
CONFIGS = {
    "quickstart": dict(D=6, K=8, B=16, n_known=4),
    "blobs3": dict(D=5, K=16, B=32, n_known=2),
    "iris": dict(D=7, K=16, B=32, n_known=4),
    "mnist_like": dict(D=794, K=4, B=8, n_known=784, score_only=True),
}

F32 = jnp.float32


def _state_specs(K: int, D: int):
    return (
        jax.ShapeDtypeStruct((K, D), F32),  # mus
        jax.ShapeDtypeStruct((K, D, D), F32),  # lambdas
        jax.ShapeDtypeStruct((K,), F32),  # log_dets
        jax.ShapeDtypeStruct((K,), F32),  # sps
    )


# Masks cross the Rust<->XLA boundary as f32 (0.0 / 1.0): the published
# `xla` crate has no bool (Pred) NativeType, so artifacts take a f32 mask
# and threshold it internally, and return masks/flags as f32.


def lower_score(D, K, B, **_):
    def fn(xs, mus, lambdas, log_dets, sps, mask_f):
        mask = mask_f > 0.5
        d2, ll, post = model.figmn_score(xs, mus, lambdas, log_dets, sps, mask)
        return d2, ll, post

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((B, D), F32),
        *_state_specs(K, D),
        jax.ShapeDtypeStruct((K,), F32),
    )


def lower_learn(D, K, **_):
    def fn(x, mus, lambdas, log_dets, sps, vs, mask_f, chi2, sigma_ini):
        mask = mask_f > 0.5
        mus2, lams2, lds2, sps2, vs2, mask2, updated = model.figmn_learn_step(
            x, mus, lambdas, log_dets, sps, vs, mask, chi2, sigma_ini
        )
        return (mus2, lams2, lds2, sps2, vs2,
                mask2.astype(F32), updated.astype(F32))

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((D,), F32),
        *_state_specs(K, D),
        jax.ShapeDtypeStruct((K,), F32),  # vs
        jax.ShapeDtypeStruct((K,), F32),  # mask as f32
        jax.ShapeDtypeStruct((), F32),  # chi2 threshold
        jax.ShapeDtypeStruct((D,), F32),  # sigma_ini
    )


def lower_predict(D, K, B, n_known, **_):
    def fn(xs_known, mus, lambdas, log_dets, sps, mask_f):
        mask = mask_f > 0.5
        return (model.figmn_predict(xs_known, mus, lambdas, log_dets, sps, mask,
                                    n_known=n_known),)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((B, n_known), F32),
        *_state_specs(K, D),
        jax.ShapeDtypeStruct((K,), F32),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(CONFIGS), help="comma list")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "version": 1, "artifacts": []}
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        entries = [("score", lower_score)]
        if not cfg.get("score_only"):
            entries += [("learn", lower_learn), ("predict", lower_predict)]
        for kind, lower in entries:
            lowered = lower(**cfg)
            text = to_hlo_text(lowered)
            fname = f"{name}.{kind}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "config": name,
                    "kind": kind,
                    "file": fname,
                    "dim": cfg["D"],
                    "capacity": cfg["K"],
                    "batch": cfg["B"],
                    "n_known": cfg["n_known"],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
