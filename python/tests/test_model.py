"""L2 model correctness: the kernel-backed learn/score/predict graphs
match the pure-jnp oracle on random streams (shape- and branch-coverage
for the exact graphs that aot.py lowers)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def run_stream(n_steps, K, D, seed, beta_thresh):
    """Drive both the model step and the oracle step over one stream;
    assert states agree after every step. Returns the final model state."""
    rng = np.random.default_rng(seed)
    sigma_ini = jnp.asarray(0.5 + rng.uniform(size=D))
    chi2 = jnp.asarray(beta_thresh, dtype=jnp.float64)

    state = model.empty_state(K, D, dtype=jnp.float64)
    centers = rng.normal(size=(3, D)) * 4.0

    for step in range(n_steps):
        x = jnp.asarray(centers[step % 3] + rng.normal(size=D) * 0.6)
        mus, lams, lds, sps, vs, mask, _upd = model.figmn_learn_step(
            x, state["mus"], state["lambdas"], state["log_dets"],
            state["sps"], state["vs"], state["mask"], chi2, sigma_ini,
        )
        oracle = ref.igmn_learn_step_ref(x, state, chi2, sigma_ini)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(oracle["mask"]),
                                      err_msg=f"mask diverged at step {step}")
        np.testing.assert_allclose(np.asarray(mus), np.asarray(oracle["mus"]),
                                   rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(np.asarray(lams), np.asarray(oracle["lambdas"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(lds), np.asarray(oracle["log_dets"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(sps), np.asarray(oracle["sps"]),
                                   rtol=1e-9, atol=1e-9)
        state = {"mus": mus, "lambdas": lams, "log_dets": lds,
                 "sps": sps, "vs": vs, "mask": mask}
    return state


@settings(max_examples=6, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_learn_step_matches_oracle(d, seed):
    run_stream(n_steps=25, K=6, D=d, seed=seed, beta_thresh=float(2 * d + 3))


def test_learn_step_creates_then_updates():
    # Huge threshold: first point creates, rest update (β = 0 behaviour).
    state = run_stream(n_steps=30, K=4, D=3, seed=1, beta_thresh=1e30)
    assert int(np.sum(np.asarray(state["mask"]))) == 1
    # sp accumulates one unit of mass per step.
    np.testing.assert_allclose(float(jnp.sum(state["sps"])), 30.0, rtol=1e-9)


def test_learn_step_capacity_fallback():
    # Tiny threshold: every point wants to create; once K slots are full
    # the step must fall back to updating.
    state = run_stream(n_steps=12, K=3, D=2, seed=2, beta_thresh=1e-12)
    assert int(np.sum(np.asarray(state["mask"]))) == 3


def test_score_matches_ref():
    rng = np.random.default_rng(5)
    state = run_stream(n_steps=20, K=6, D=4, seed=3, beta_thresh=11.0)
    xs = jnp.asarray(rng.normal(size=(9, 4)) * 3.0)
    d2, ll, post = model.figmn_score(
        xs, state["mus"], state["lambdas"], state["log_dets"],
        state["sps"], state["mask"],
    )
    want_d2 = ref.mahalanobis_batch_ref(xs, state["mus"], state["lambdas"])
    np.testing.assert_allclose(np.asarray(d2), np.asarray(want_d2), rtol=1e-9, atol=1e-9)
    want_ll = ref.log_gaussian_ref(want_d2, state["log_dets"][None, :], 4)
    np.testing.assert_allclose(np.asarray(ll), np.asarray(want_ll), rtol=1e-9, atol=1e-9)
    want_post = ref.posteriors_ref(want_ll, state["sps"], state["mask"])
    np.testing.assert_allclose(np.asarray(post), np.asarray(want_post), rtol=1e-9, atol=1e-9)
    # Posterior rows are distributions, zero on masked slots.
    p = np.asarray(post)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-9)
    assert np.all(p[:, ~np.asarray(state["mask"])] == 0.0)


def test_predict_matches_ref():
    state = run_stream(n_steps=25, K=6, D=5, seed=4, beta_thresh=14.0)
    rng = np.random.default_rng(6)
    n_known = 3
    xs_known = jnp.asarray(rng.normal(size=(7, n_known)) * 2.0)
    got = model.figmn_predict(
        xs_known, state["mus"], state["lambdas"], state["log_dets"],
        state["sps"], state["mask"], n_known=n_known,
    )
    # Oracle: per-row masked mixture of per-component conditionals. The
    # masked components must be excluded from the softmax; predict_ref
    # handles that via posteriors_ref, but its vmap includes inactive
    # rows whose W may be singular — restrict to active components.
    active = np.asarray(state["mask"])
    sub = {k: jnp.asarray(np.asarray(v)[active]) for k, v in state.items()}
    for b in range(xs_known.shape[0]):
        want = ref.predict_ref(xs_known[b], sub, n_known)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=1e-7, atol=1e-7)


def test_learn_step_lowers_to_hlo_text():
    """The exact AOT path (stablehlo → XlaComputation → HLO text) works
    for the learn graph — guards the interchange format end to end."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from compile import aot

    lowered = aot.lower_learn(D=4, K=4)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "main" in text
    assert len(text) > 1000
