"""L1 kernel correctness: Pallas (interpret) vs the pure-jnp oracles,
swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mahalanobis, mahalanobis_batch, precision_update
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def random_state(rng, K, D, dtype=np.float64):
    """Random PD precision matrices + means."""
    mus = rng.normal(size=(K, D)).astype(dtype)
    lams = []
    for _ in range(K):
        a = rng.normal(size=(D, D)) * 0.4
        lam = a @ a.T + np.eye(D) * (0.5 + rng.uniform())
        lams.append(lam)
    lambdas = np.stack(lams).astype(dtype)
    log_dets = np.array(
        [-np.linalg.slogdet(l)[1] for l in lambdas], dtype=dtype
    )  # log|C| = -log|Λ|
    return mus, lambdas, log_dets


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=12),
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mahalanobis_matches_ref(d, k, seed):
    rng = np.random.default_rng(seed)
    mus, lambdas, _ = random_state(rng, k, d)
    x = rng.normal(size=d)
    got = mahalanobis(jnp.asarray(x), jnp.asarray(mus), jnp.asarray(lambdas))
    want = ref.mahalanobis_ref(jnp.asarray(x), jnp.asarray(mus), jnp.asarray(lambdas))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=10),
    k=st.integers(min_value=1, max_value=5),
    b=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mahalanobis_batch_matches_ref(d, k, b, seed):
    rng = np.random.default_rng(seed)
    mus, lambdas, _ = random_state(rng, k, d)
    xs = rng.normal(size=(b, d))
    got = mahalanobis_batch(jnp.asarray(xs), jnp.asarray(mus), jnp.asarray(lambdas))
    want = ref.mahalanobis_batch_ref(jnp.asarray(xs), jnp.asarray(mus), jnp.asarray(lambdas))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_mahalanobis_dtypes(dtype):
    rng = np.random.default_rng(0)
    mus, lambdas, _ = random_state(rng, 3, 4, dtype=dtype)
    x = rng.normal(size=4).astype(dtype)
    got = mahalanobis(jnp.asarray(x), jnp.asarray(mus), jnp.asarray(lambdas))
    assert got.dtype == dtype
    want = ref.mahalanobis_ref(jnp.asarray(x), jnp.asarray(mus), jnp.asarray(lambdas))
    tol = 1e-4 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=10),
    k=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_precision_update_matches_covariance_oracle(d, k, seed):
    """The paper's central algebra: the fused kernel equals the direct
    covariance-path recompute (invert, update C, invert back)."""
    rng = np.random.default_rng(seed)
    mus, lambdas, log_dets = random_state(rng, k, d)
    x = rng.normal(size=d)
    # Realistic omegas: p/sp with sp >= 1+p.
    post = rng.dirichlet(np.ones(k))
    sps = 1.0 + rng.uniform(size=k) * 10.0
    omegas = post / (sps + post)

    got_mu, got_lam, got_ld = precision_update(
        jnp.asarray(x), jnp.asarray(omegas), jnp.asarray(mus),
        jnp.asarray(lambdas), jnp.asarray(log_dets),
    )
    for j in range(k):
        want_mu, want_lam, want_ld = ref.precision_update_ref(
            jnp.asarray(x), jnp.asarray(mus[j]), jnp.asarray(lambdas[j]),
            jnp.asarray(log_dets[j]), float(omegas[j]),
        )
        np.testing.assert_allclose(np.asarray(got_mu[j]), np.asarray(want_mu),
                                   rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(np.asarray(got_lam[j]), np.asarray(want_lam),
                                   rtol=1e-6, atol=1e-6)
        # Oracle recomputes log|C| from scratch; ours is incremental.
        np.testing.assert_allclose(float(got_ld[j]), float(want_ld),
                                   rtol=1e-8, atol=1e-8)


def test_precision_update_omega_zero_is_noop():
    rng = np.random.default_rng(3)
    mus, lambdas, log_dets = random_state(rng, 4, 5)
    x = rng.normal(size=5)
    omegas = np.zeros(4)
    got_mu, got_lam, got_ld = precision_update(
        jnp.asarray(x), jnp.asarray(omegas), jnp.asarray(mus),
        jnp.asarray(lambdas), jnp.asarray(log_dets),
    )
    np.testing.assert_allclose(np.asarray(got_mu), mus, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(got_lam), lambdas, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(got_ld), log_dets, rtol=0, atol=0)


def test_precision_update_preserves_symmetry_and_pd():
    rng = np.random.default_rng(11)
    mus, lambdas, log_dets = random_state(rng, 1, 6)
    x0 = mus[0].copy()
    mus_j, lams_j, lds_j = (jnp.asarray(mus), jnp.asarray(lambdas), jnp.asarray(log_dets))
    for step in range(100):
        x = x0 + rng.normal(size=6) * 0.5
        omega = np.array([1.0 / (2.0 + step)])
        mus_j, lams_j, lds_j = precision_update(
            jnp.asarray(x), jnp.asarray(omega), mus_j, lams_j, lds_j
        )
        lam = np.asarray(lams_j[0])
        np.testing.assert_allclose(lam, lam.T, rtol=0, atol=1e-9)
        assert np.all(np.linalg.eigvalsh(lam) > 0), f"lost PD at step {step}"
        # Tracked log|C| consistent with the matrix itself.
        np.testing.assert_allclose(
            float(lds_j[0]), -np.linalg.slogdet(lam)[1], rtol=1e-7, atol=1e-7
        )
