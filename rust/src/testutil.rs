//! Shared helpers for the test suite, including a small seeded
//! property-testing harness (`proptest` is not in the offline vendor set;
//! the same "many random cases, shrink-free, seed printed on failure"
//! discipline is implemented here directly).

use crate::linalg::{rank_one::syr, Matrix};
use crate::rng::Pcg64;

/// Random symmetric positive-definite matrix: `A = Q + n·I` with
/// `Q = Σ vᵢvᵢᵀ`, guaranteed well-conditioned for tests.
pub fn random_spd(n: usize, rng: &mut Pcg64) -> Matrix {
    let mut a = Matrix::scaled_identity(n, 1.0 + rng.uniform());
    for _ in 0..n {
        let v: Vec<f64> = (0..n).map(|_| rng.normal() * 0.5).collect();
        syr(&mut a, 1.0, &v);
    }
    a
}

/// Random vector of standard normals.
pub fn random_vec(n: usize, rng: &mut Pcg64) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Assert two slices are elementwise close; prints the first offender.
#[track_caller]
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let diff = (x - y).abs();
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            diff / scale <= tol,
            "element {i}: {x} vs {y} (rel diff {})",
            diff / scale
        );
    }
}

/// Relative closeness for scalars.
#[track_caller]
pub fn assert_rel(a: f64, b: f64, tol: f64) {
    let scale = a.abs().max(b.abs()).max(1e-300);
    assert!((a - b).abs() / scale <= tol, "{a} vs {b} (rel {})", (a - b).abs() / scale);
}

/// Mini property-test driver: runs `f` for `cases` seeded inputs; on panic
/// the failing seed is in the panic message via `track_caller` + closure
/// argument, so failures are reproducible with `check_with_seed`.
pub fn check(cases: u64, mut f: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Pcg64::seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed for seed {seed} (case {case}/{cases})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing property case.
pub fn check_with_seed(seed: u64, mut f: impl FnMut(&mut Pcg64)) {
    let mut rng = Pcg64::seed(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;

    #[test]
    fn random_spd_is_pd() {
        check(20, |rng| {
            let n = 2 + (rng.below(8));
            let a = random_spd(n, rng);
            assert!(Cholesky::new(&a).is_some(), "not PD at n={n}");
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 0.0);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(&[1.0], &[2.0], 1e-6);
    }
}
