//! Compact JSON writer.

use super::Json;

pub(super) fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; encode as null (checkpoint loaders treat
        // it as corrupt and reject).
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Shortest round-trippable representation.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use crate::json::{parse, Json};

    #[test]
    fn numbers_round_trip_exactly() {
        for &x in &[0.0, -1.0, 3.5, 1e-17, 123456789.125, -2.2250738585072014e-308] {
            let s = Json::Num(x).to_string_compact();
            assert_eq!(parse(&s).unwrap().as_f64().unwrap(), x, "via {s}");
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
    }

    #[test]
    fn strings_escape() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\"b\\c\nd\u{1}".into()));
    }

    #[test]
    fn non_finite_encodes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
