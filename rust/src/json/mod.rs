//! Minimal JSON substrate.
//!
//! `serde`/`serde_json` are not in the offline vendor set; the coordinator
//! protocol, model checkpoints, and the artifact manifest all speak JSON,
//! so a small but complete implementation lives here: a [`Json`] value
//! tree, a recursive-descent parser with location-carrying errors, and a
//! compact writer. Covers the full JSON grammar (RFC 8259) except for
//! `\u` surrogate pairs outside the BMP being passed through unpaired.

mod parse;
mod write;

pub use parse::{parse, JsonError};

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable checkpoint diffs, reproducible protocol traces).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers from a slice.
    pub fn num_array(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Decode an array of numbers into a `Vec<f64>`.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        let arr = self.as_array()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()?);
        }
        Some(out)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write::write_value(self, &mut s);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let v = Json::obj(vec![
            ("name", "figmn".into()),
            ("dims", Json::num_array(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("ok", true.into()), ("n", Json::Null)])),
        ]);
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": [1, 2], "b": "x", "c": 3.5, "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().to_f64_vec().unwrap(), vec![1.0, 2.0]);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), 3.5);
        assert_eq!(v.get("d").unwrap().as_bool().unwrap(), false);
        assert!(v.get("missing").is_none());
        assert_eq!(v.get("c").unwrap().as_usize(), None);
    }

    #[test]
    fn deterministic_key_order() {
        let a = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(a.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
