//! Recursive-descent JSON parser with byte-offset error reporting.

use super::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Parse error with the byte offset where it occurred.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage rejected.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for non-BMP chars.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.peek() == Some(b'\\') {
                                self.pos += 1;
                                if self.bump() != Some(b'u') {
                                    return Err(self.err("expected low surrogate"));
                                }
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                out.push(
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?,
                                );
                            } else {
                                out.push('\u{FFFD}');
                            }
                        } else {
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("raw control character in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a' + 10) as u32,
                b'A'..=b'F' => (b - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("invalid number '{text}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"é direct\"").unwrap(), Json::Str("é direct".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn deep_nesting() {
        let s = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(parse(&s).is_ok());
    }
}
