//! Evaluation harness reproducing the paper's methodology:
//! 2-fold cross-validation, AUC (area under the ROC curve, weighted
//! one-vs-rest for multiclass — Weka's convention), accuracy/confusion,
//! wall-clock timing split into training and testing phases, and the
//! paired t-test significance marks of Tables 2–4.

mod auc;
mod crossval;
mod metrics;
mod timing;

pub use auc::{binary_auc, multiclass_auc};
pub use crossval::{kfold_indices, stratified_kfold, CvTimings, FoldResult};
pub use metrics::{accuracy, ConfusionMatrix};
pub use timing::{format_seconds, Stopwatch};
