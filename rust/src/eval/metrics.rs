//! Classification metrics.

/// Fraction of predictions equal to truth.
pub fn accuracy(predictions: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(predictions.len(), truth.len());
    if truth.is_empty() {
        return 0.0;
    }
    let correct = predictions.iter().zip(truth.iter()).filter(|(p, t)| p == t).count();
    correct as f64 / truth.len() as f64
}

/// Row = truth, column = prediction.
#[derive(Debug, Clone)]
pub struct ConfusionMatrix {
    n_classes: usize,
    counts: Vec<u64>,
}

impl ConfusionMatrix {
    pub fn new(n_classes: usize) -> Self {
        ConfusionMatrix { n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, truth: usize, prediction: usize) {
        assert!(truth < self.n_classes && prediction < self.n_classes);
        self.counts[truth * self.n_classes + prediction] += 1;
    }

    pub fn count(&self, truth: usize, prediction: usize) -> u64 {
        self.counts[truth * self.n_classes + prediction]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn accuracy(&self) -> f64 {
        let diag: u64 = (0..self.n_classes).map(|i| self.count(i, i)).sum();
        diag as f64 / self.total().max(1) as f64
    }

    /// Per-class recall (diagonal / row sum); `None` for absent classes.
    pub fn recall(&self, class: usize) -> Option<f64> {
        let row: u64 = (0..self.n_classes).map(|j| self.count(class, j)).sum();
        if row == 0 {
            None
        } else {
            Some(self.count(class, class) as f64 / row as f64)
        }
    }

    /// Per-class precision (diagonal / column sum).
    pub fn precision(&self, class: usize) -> Option<f64> {
        let col: u64 = (0..self.n_classes).map(|i| self.count(i, class)).sum();
        if col == 0 {
            None
        } else {
            Some(self.count(class, class) as f64 / col as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_matrix_counts() {
        let mut cm = ConfusionMatrix::new(2);
        cm.record(0, 0);
        cm.record(0, 1);
        cm.record(1, 1);
        cm.record(1, 1);
        assert_eq!(cm.total(), 4);
        assert_eq!(cm.accuracy(), 0.75);
        assert_eq!(cm.recall(0), Some(0.5));
        assert_eq!(cm.precision(1), Some(2.0 / 3.0));
    }

    #[test]
    fn absent_class_is_none() {
        let cm = ConfusionMatrix::new(3);
        assert_eq!(cm.recall(2), None);
        assert_eq!(cm.precision(2), None);
    }
}
