//! Wall-clock timing helpers for the Tables 2/3 reproduction.

use std::time::Instant;

/// A simple accumulating stopwatch: repeatedly `start()`/`stop()`, read
/// the accumulated total. Used to separate training time from testing
/// time inside a fold exactly like the paper's experiment harness.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: 0.0, started: None }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed().as_secs_f64();
        }
    }

    /// Accumulated seconds (excluding a currently-running interval).
    pub fn seconds(&self) -> f64 {
        self.total
    }

    /// Time a closure and accumulate its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Render seconds like the paper's tables: 3 decimal places, or
/// scientific for sub-millisecond values in verbose contexts.
pub fn format_seconds(s: f64) -> String {
    format!("{s:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_intervals() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        let first = sw.seconds();
        assert!(first >= 0.004);
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(sw.seconds() > first);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.seconds(), 0.0);
    }

    #[test]
    fn formats_three_decimals() {
        assert_eq!(format_seconds(1.23456), "1.235");
        assert_eq!(format_seconds(0.0004), "0.000");
    }
}
