//! Cross-validation splits and fold bookkeeping (the paper uses 2-fold CV
//! with paired t-tests at p = 0.05 throughout).

use crate::rng::Pcg64;

/// Plain k-fold: a seeded permutation chopped into `k` contiguous folds.
/// Returns `(train_idx, test_idx)` per fold.
pub fn kfold_indices(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n, "kfold: need 2 ≤ k ≤ n");
    let mut rng = Pcg64::seed(seed);
    let perm = rng.permutation(n);
    let mut folds = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * n / k;
        let hi = (f + 1) * n / k;
        let test: Vec<usize> = perm[lo..hi].to_vec();
        let train: Vec<usize> =
            perm[..lo].iter().chain(perm[hi..].iter()).copied().collect();
        folds.push((train, test));
    }
    folds
}

/// Stratified k-fold: class proportions preserved per fold (Weka's CV
/// default, hence the paper's). Each class's examples are shuffled and
/// dealt round-robin to folds.
pub fn stratified_kfold(
    labels: &[usize],
    n_classes: usize,
    k: usize,
    seed: u64,
) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2, "stratified_kfold: k ≥ 2");
    let mut rng = Pcg64::seed(seed);
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l].push(i);
    }
    let mut fold_members: Vec<Vec<usize>> = vec![Vec::new(); k];
    for members in per_class.iter_mut() {
        rng.shuffle(members);
        for (i, &idx) in members.iter().enumerate() {
            fold_members[i % k].push(idx);
        }
    }
    (0..k)
        .map(|f| {
            let test = fold_members[f].clone();
            let train: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| fold_members[g].iter().copied())
                .collect();
            (train, test)
        })
        .collect()
}

/// Timing of one CV fold, split like the paper's Tables 2/3.
#[derive(Debug, Clone, Copy, Default)]
pub struct CvTimings {
    pub train_seconds: f64,
    pub test_seconds: f64,
}

/// Result of one evaluated fold.
#[derive(Debug, Clone)]
pub struct FoldResult {
    pub timings: CvTimings,
    /// Per-test-example class scores.
    pub scores: Vec<Vec<f64>>,
    /// Ground-truth labels of the test rows, aligned with `scores`.
    pub truth: Vec<usize>,
}

impl FoldResult {
    pub fn auc(&self, n_classes: usize) -> f64 {
        super::multiclass_auc(&self.scores, &self.truth, n_classes)
    }

    pub fn accuracy(&self) -> f64 {
        let correct = self
            .scores
            .iter()
            .zip(self.truth.iter())
            .filter(|(s, &t)| {
                s.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    == Some(t)
            })
            .count();
        correct as f64 / self.truth.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kfold_partitions() {
        let folds = kfold_indices(10, 3, 1);
        assert_eq!(folds.len(), 3);
        let mut all_test: Vec<usize> = folds.iter().flat_map(|(_, t)| t.clone()).collect();
        all_test.sort_unstable();
        assert_eq!(all_test, (0..10).collect::<Vec<_>>());
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 10);
            for t in test {
                assert!(!train.contains(t));
            }
        }
    }

    #[test]
    fn stratified_preserves_proportions() {
        // 40 of class 0, 20 of class 1, 2 folds → each fold has 20/10.
        let labels: Vec<usize> =
            (0..60).map(|i| if i < 40 { 0 } else { 1 }).collect();
        let folds = stratified_kfold(&labels, 2, 2, 42);
        for (_, test) in &folds {
            let c0 = test.iter().filter(|&&i| labels[i] == 0).count();
            let c1 = test.iter().filter(|&&i| labels[i] == 1).count();
            assert_eq!(c0, 20);
            assert_eq!(c1, 10);
        }
    }

    #[test]
    fn stratified_is_partition() {
        let labels: Vec<usize> = (0..31).map(|i| i % 3).collect();
        let folds = stratified_kfold(&labels, 3, 2, 7);
        let mut all: Vec<usize> = folds.iter().flat_map(|(_, t)| t.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..31).collect::<Vec<_>>());
    }

    #[test]
    fn fold_result_metrics() {
        let r = FoldResult {
            timings: CvTimings::default(),
            scores: vec![vec![0.9, 0.1], vec![0.2, 0.8], vec![0.6, 0.4]],
            truth: vec![0, 1, 1],
        };
        assert!((r.accuracy() - 2.0 / 3.0).abs() < 1e-12);
        let auc = r.auc(2);
        assert!(auc > 0.4 && auc <= 1.0);
    }
}
