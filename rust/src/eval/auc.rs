//! Area under the ROC curve.

/// Binary AUC via the Mann–Whitney U statistic with proper tie handling
/// (average ranks). `scores[i]` is the model's confidence that example
/// `i` is positive; `labels[i]` is the truth.
///
/// Returns 0.5 when one class is absent (undefined AUC — Weka reports the
/// same neutral value).
pub fn binary_auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // Rank all scores (average rank for ties). total_cmp: a NaN score
    // (diverged model) ranks deterministically instead of panicking.
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    let mut ranks = vec![0.0; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        // ranks are 1-based; tied block [i..=j] gets the average rank.
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            ranks[k] = avg;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 =
        labels.iter().zip(ranks.iter()).filter(|(&l, _)| l).map(|(_, &r)| r).sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Multiclass AUC: one-vs-rest per class, weighted by class prevalence —
/// Weka's "weighted average AUC", which is what the paper's Table 4
/// averages report.
///
/// `scores[i][c]` = model confidence that example `i` is class `c`.
pub fn multiclass_auc(scores: &[Vec<f64>], labels: &[usize], n_classes: usize) -> f64 {
    assert_eq!(scores.len(), labels.len());
    assert!(!scores.is_empty());
    let n = labels.len() as f64;
    let mut weighted = 0.0;
    let mut total_weight = 0.0;
    for c in 0..n_classes {
        let class_count = labels.iter().filter(|&&l| l == c).count();
        if class_count == 0 {
            continue;
        }
        let bin_labels: Vec<bool> = labels.iter().map(|&l| l == c).collect();
        let bin_scores: Vec<f64> = scores.iter().map(|s| s[c]).collect();
        let auc = binary_auc(&bin_scores, &bin_labels);
        let w = class_count as f64 / n;
        weighted += w * auc;
        total_weight += w;
    }
    if total_weight > 0.0 {
        weighted / total_weight
    } else {
        0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [false, false, true, true];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
    }

    #[test]
    fn inverted_is_zero() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [false, false, true, true];
        assert_eq!(binary_auc(&scores, &labels), 0.0);
    }

    #[test]
    fn random_constant_is_half() {
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [true, false, true, false];
        assert_eq!(binary_auc(&scores, &labels), 0.5);
    }

    #[test]
    fn known_mixed_case() {
        // scores: pos {0.8, 0.4}, neg {0.6, 0.2} → pairs: (0.8>0.6, 0.8>0.2,
        // 0.4<0.6, 0.4>0.2) = 3/4 wins.
        let scores = [0.8, 0.6, 0.4, 0.2];
        let labels = [true, false, true, false];
        assert_eq!(binary_auc(&scores, &labels), 0.75);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(binary_auc(&[0.1, 0.9], &[true, true]), 0.5);
    }

    #[test]
    fn multiclass_perfect() {
        let scores = vec![
            vec![0.9, 0.05, 0.05],
            vec![0.1, 0.8, 0.1],
            vec![0.0, 0.1, 0.9],
            vec![0.8, 0.1, 0.1],
        ];
        let labels = [0, 1, 2, 0];
        assert_eq!(multiclass_auc(&scores, &labels, 3), 1.0);
    }

    #[test]
    fn multiclass_weighted_by_prevalence() {
        // Class 0 (3 examples) perfectly ranked, class 1 (1 example)
        // perfectly wrong → weighted = (3/4·1 + 1/4·0) = 0.75.
        let scores = vec![
            vec![0.9, 0.9],
            vec![0.8, 0.8],
            vec![0.7, 0.7],
            vec![0.1, 0.1],
        ];
        let labels = [0, 0, 0, 1];
        let auc = multiclass_auc(&scores, &labels, 2);
        assert!((auc - 0.75).abs() < 1e-12, "auc {auc}");
    }
}
