//! Component-sharded parallel execution engine.
//!
//! The paper's per-point work is a sum over the K Gaussian components —
//! the `Λ·v` Mahalanobis pass (Eq. 22) and the fused rank-two
//! Sherman–Morrison update (Eqs. 20–21/25–26) touch each component
//! independently — so the K axis is embarrassingly parallel (Pinto &
//! Engel 2017 exploit the same structure). This module supplies that
//! axis:
//!
//! - [`WorkerPool`] — a fixed pool of `std::thread` workers; each call
//!   partitions `0..K` into contiguous shards and runs one task per
//!   shard. Every worker owns a private [`Scratch`] arena, the
//!   per-thread analogue of `Figmn`'s `buf_e`/`buf_ws` buffers.
//! - [`EngineConfig`] — thread-count policy attached to a model via
//!   `Figmn::with_engine` / `Igmn::with_engine`.
//! - [`tree_sum`] / [`logsumexp_tree`] — deterministic pairwise tree
//!   reductions used to merge per-component scores.
//!
//! ## Determinism guarantee
//!
//! Engine results are **bit-identical** for every thread count (and to
//! the serial path). Two properties make this hold:
//!
//! 1. Per-component work is component-local: a shard task reads shared
//!    immutable inputs and writes only slots indexed by its own
//!    component indices, with the exact same instruction sequence the
//!    serial path runs. Shard boundaries change *which thread* computes
//!    a value, never the value.
//! 2. Cross-component merges (posterior normalization, log-density
//!    accumulation) run through [`tree_sum`], whose reduction shape is a
//!    pure function of K — never of thread count, shard boundaries, or
//!    completion order.
//!
//! The `engine_determinism` integration test enforces this across thread
//! counts {1, 2, 4} on the paper's Table 1 synthetic streams.

mod pool;

pub use pool::{Scratch, ShardTask, SharedMut, WorkerPool};

/// Thread-count policy for a model's shard pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads; `0` means "use the machine's available
    /// parallelism".
    pub threads: usize,
}

impl EngineConfig {
    /// A fixed thread count (`0` = auto).
    pub fn new(threads: usize) -> EngineConfig {
        EngineConfig { threads }
    }

    /// Use `std::thread::available_parallelism`.
    pub fn auto() -> EngineConfig {
        EngineConfig { threads: 0 }
    }

    /// The concrete thread count this config resolves to on this host.
    pub fn resolve_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig::auto()
    }
}

/// Minimum pass work (in ~multiply-add units) below which dispatching to
/// the pool costs more than it saves. The gate only picks *where* the
/// identical arithmetic runs, so it cannot affect results.
pub(crate) const MIN_PARALLEL_WORK: usize = 1 << 14;

/// Gate for a pass whose per-component cost is `per_comp_work` flops
/// (pass `d·d` for the precision-path O(D²) sweeps, `d·d·d` for the
/// covariance path's per-component Cholesky).
pub(crate) fn worth_sharding_work(k: usize, per_comp_work: usize, threads: usize) -> bool {
    threads > 1 && k >= 2 && k.saturating_mul(per_comp_work) >= MIN_PARALLEL_WORK
}

/// Should a K-component, D-dimensional O(K·D²) pass use the pool?
pub(crate) fn worth_sharding(k: usize, d: usize, threads: usize) -> bool {
    worth_sharding_work(k, d.saturating_mul(d), threads)
}

/// Gate for batch scoring/inference: `b` points amortize one dispatch.
pub(crate) fn worth_sharding_batch(b: usize, k: usize, d: usize, threads: usize) -> bool {
    worth_sharding_work(k, b.saturating_mul(d.saturating_mul(d)), threads)
}

/// Deterministic pairwise tree sum.
///
/// The reduction tree's shape depends only on `xs.len()`: leaves are the
/// elements in index order, and each level sums adjacent pairs. Unlike a
/// left-fold split across threads, the result is independent of how the
/// index space was sharded — the engine's cross-component merges all
/// funnel through here (or through a serial pass over per-component
/// slots, which is equally schedule-independent).
pub fn tree_sum(xs: &[f64]) -> f64 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        2 => xs[0] + xs[1],
        n => {
            let mid = n / 2;
            tree_sum(&xs[..mid]) + tree_sum(&xs[mid..])
        }
    }
}

/// Deterministic log-sum-exp over per-component log-terms: max-shifted
/// (the max is order-independent) and tree-summed.
pub fn logsumexp_tree(terms: &[f64]) -> f64 {
    let best = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !best.is_finite() {
        return f64::NEG_INFINITY;
    }
    let exps: Vec<f64> = terms.iter().map(|&t| (t - best).exp()).collect();
    best + tree_sum(&exps).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sum_matches_exact_on_integers() {
        // Integer-valued f64s sum exactly, so tree and fold must agree.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(tree_sum(&xs), 5050.0);
        assert_eq!(tree_sum(&[]), 0.0);
        assert_eq!(tree_sum(&[3.5]), 3.5);
    }

    #[test]
    fn tree_sum_is_shard_independent_by_construction() {
        // The same values summed through the tree give the same bits no
        // matter how a caller would have sharded them — here we just
        // check the tree is stable against repeated evaluation and
        // equals the mathematically-expected value within float error.
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.1).collect();
        let a = tree_sum(&xs);
        let b = tree_sum(&xs);
        assert_eq!(a.to_bits(), b.to_bits());
        let linear: f64 = xs.iter().sum();
        assert!((a - linear).abs() < 1e-9 * linear.abs().max(1.0));
    }

    #[test]
    fn logsumexp_handles_extremes() {
        // Far-underflowing terms must not produce NaN.
        let v = logsumexp_tree(&[-1e5, -1e5 - 1.0]);
        assert!((v - (-1e5 + (1.0 + (-1.0f64).exp()).ln())).abs() < 1e-9);
        assert_eq!(logsumexp_tree(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
        assert_eq!(logsumexp_tree(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn engine_config_resolves() {
        assert_eq!(EngineConfig::new(3).resolve_threads(), 3);
        assert!(EngineConfig::auto().resolve_threads() >= 1);
        assert_eq!(EngineConfig::default(), EngineConfig::auto());
    }

    #[test]
    fn sharding_gate_scales_with_work() {
        assert!(!worth_sharding(32, 64, 1)); // single thread: never
        assert!(worth_sharding(32, 64, 4)); // 32·64² ≫ threshold
        assert!(!worth_sharding(2, 4, 4)); // tiny model: sync dominates
        // The cubic covariance pass engages at K·D³ even when K·D² is
        // below the threshold…
        assert!(!worth_sharding(3, 64, 4));
        assert!(worth_sharding_work(3, 64 * 64 * 64, 4));
        // …and batches amortize one dispatch across points.
        assert!(!worth_sharding(4, 16, 4));
        assert!(worth_sharding_batch(64, 4, 16, 4));
        assert!(!worth_sharding_batch(64, 1, 16, 4)); // K=1: nothing to shard
    }
}
