//! Fixed pool of component-shard workers.
//!
//! A [`WorkerPool`] owns `T` OS threads for the lifetime of the model it
//! serves. Each call to [`WorkerPool::run`] partitions the component
//! index space `0..k` into `T` contiguous shards and executes one task
//! over every shard in parallel, blocking until all shards finish. Each
//! worker thread owns a private [`Scratch`] arena (the per-thread
//! analogue of `Figmn`'s `buf_e`/`buf_ws` buffers), so the learn hot
//! path stays allocation-free under parallel execution too.
//!
//! Synchronization is a hybrid spin-then-sleep epoch protocol: workers
//! spin briefly on an atomic epoch counter (learn streams issue phases
//! every few tens of microseconds, so the pool is usually hot) and fall
//! back to a condvar so an idle pool consumes no CPU.

use std::any::Any;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Iterations to spin on the epoch/done atomics before sleeping.
const SPIN_LIMIT: u32 = 20_000;

/// Per-thread scratch arena. Buffers grow on demand and are reused for
/// every subsequent task on that worker thread.
pub struct Scratch {
    /// Mean-error vector `e = x − μ` (D floats).
    pub e: Vec<f64>,
    /// Second general-purpose D-float buffer (e.g. `Δμ` for the
    /// covariance-form update).
    pub tmp: Vec<f64>,
    /// Wide arena for the query-block scoring paths (a `B×D` residual
    /// block plus kernel scratch — see [`Scratch::split3`]). Grows on
    /// demand and persists across tasks like the other buffers.
    wide: Vec<f64>,
}

impl Scratch {
    fn new() -> Scratch {
        Scratch { e: Vec::new(), tmp: Vec::new(), wide: Vec::new() }
    }

    /// Make sure both buffers hold at least `d` elements.
    pub fn ensure(&mut self, d: usize) {
        if self.e.len() < d {
            self.e.resize(d, 0.0);
        }
        if self.tmp.len() < d {
            self.tmp.resize(d, 0.0);
        }
    }

    /// Both buffers, sized to `d`, as disjoint mutable slices — for
    /// call sites that need the error vector and a kernel scratch in
    /// the same expression (call [`Scratch::ensure`] first).
    pub fn pair(&mut self, d: usize) -> (&mut [f64], &mut [f64]) {
        (&mut self.e[..d], &mut self.tmp[..d])
    }

    /// Three disjoint mutable slices of `a`, `b` and `c` floats carved
    /// from the wide arena (growing it on demand) — the block scoring
    /// path's (residual block, kernel w-block, per-query terms)
    /// scratch. Contents are whatever the previous task left behind;
    /// callers overwrite before reading.
    pub fn split3(&mut self, a: usize, b: usize, c: usize) -> (&mut [f64], &mut [f64], &mut [f64]) {
        let n = a + b + c;
        if self.wide.len() < n {
            self.wide.resize(n, 0.0);
        }
        let (x, rest) = self.wide.split_at_mut(a);
        let (y, rest) = rest.split_at_mut(b);
        (x, y, &mut rest[..c])
    }
}

/// The task signature: `(worker_index, component_range, scratch)`.
pub type ShardTask<'a> = &'a (dyn Fn(usize, Range<usize>, &mut Scratch) + Sync + 'a);

struct State {
    epoch: u64,
    /// Lifetime-erased task reference, set for the duration of one `run`
    /// call. Safety: `run` does not return until every worker has
    /// finished calling the task and it is cleared before `run` returns,
    /// so the pointee always outlives its uses; the `Sync` bound makes
    /// the concurrent calls sound.
    task: Option<ShardTask<'static>>,
    ranges: Vec<Range<usize>>,
    remaining: usize,
    /// First panic payload caught in a shard task this epoch; re-raised
    /// on the calling thread by `run` (a dead shard must crash the
    /// caller, not deadlock it).
    panic: Option<Box<dyn Any + Send>>,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Mirrors `State::epoch` for the workers' lock-free spin phase.
    epoch: AtomicU64,
    /// Mirrors `State::remaining` for the caller's lock-free spin phase.
    pending: AtomicUsize,
    shutdown: AtomicBool,
}

/// A fixed pool of component-shard worker threads (see module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Serializes `run` calls: the epoch protocol supports one task at a
    /// time (learn takes `&mut` anyway; this guards `&self` callers).
    run_guard: Mutex<()>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                task: None,
                ranges: vec![0..0; threads],
                remaining: 0,
                panic: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            epoch: AtomicU64::new(0),
            pending: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads)
            .map(|id| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("figmn-shard-{id}"))
                    .spawn(move || worker_loop(id, &shared))
                    .expect("spawn shard worker")
            })
            .collect();
        WorkerPool { shared, workers, run_guard: Mutex::new(()) }
    }

    /// Number of worker threads (= number of component shards).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Split `0..k` into contiguous per-worker shards and run `task` on
    /// every shard in parallel; returns when all shards are done.
    ///
    /// The shard partition is a pure function of `(k, threads)`, and
    /// every component index is visited by exactly one worker, so tasks
    /// that only touch per-component state (plus shared read-only data)
    /// are race-free and produce results independent of scheduling.
    pub fn run(&self, k: usize, task: ShardTask<'_>) {
        if k == 0 {
            return;
        }
        // Poison-tolerant: a shard panic re-raised by a previous `run`
        // unwinds through this guard; the pool itself stays consistent
        // (state was settled before the re-raise), so keep serving.
        let _serial = self.run_guard.lock().unwrap_or_else(|e| e.into_inner());
        let t = self.workers.len();
        // Erase the borrow lifetime for storage; see the `State::task`
        // safety note — the reference is dead before `run` returns.
        let task: ShardTask<'static> =
            unsafe { std::mem::transmute::<ShardTask<'_>, ShardTask<'static>>(task) };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.task.is_none() && st.remaining == 0, "run re-entered");
            st.task = Some(task);
            st.ranges = partition_ranges(k, t);
            st.remaining = t;
            self.shared.pending.store(t, Ordering::Release);
            st.epoch += 1;
            self.shared.epoch.store(st.epoch, Ordering::Release);
        }
        self.shared.work_cv.notify_all();

        // Wait for completion: spin first, then sleep.
        let mut spins = 0u32;
        while self.shared.pending.load(Ordering::Acquire) > 0 {
            spins += 1;
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
                continue;
            }
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            break;
        }
        let mut st = self.shared.state.lock().unwrap();
        debug_assert_eq!(st.remaining, 0);
        st.task = None;
        if let Some(payload) = st.panic.take() {
            drop(st);
            // Surface a shard-task panic on the calling thread, exactly
            // like the serial path would have crashed.
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(id: usize, shared: &Shared) {
    let mut scratch = Scratch::new();
    let mut seen_epoch = 0u64;
    loop {
        // Wait for a new epoch: bounded spin, then condvar sleep.
        let mut spins = 0u32;
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if shared.epoch.load(Ordering::Acquire) != seen_epoch {
                break;
            }
            spins += 1;
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
                continue;
            }
            let mut st = shared.state.lock().unwrap();
            while st.epoch == seen_epoch && !shared.shutdown.load(Ordering::Acquire) {
                st = shared.work_cv.wait(st).unwrap();
            }
            break;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Fetch this worker's assignment for the new epoch.
        let (task, range) = {
            let st = shared.state.lock().unwrap();
            seen_epoch = st.epoch;
            (st.task, st.ranges[id].clone())
        };
        if let Some(f) = task {
            if !range.is_empty() {
                // `run` keeps the task alive until `remaining` hits 0,
                // which happens strictly after this call returns. Catch
                // panics so a dying shard still reports completion —
                // otherwise `run` would wait forever; the payload is
                // re-raised on the calling thread.
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(id, range, &mut scratch)))
                {
                    let mut st = shared.state.lock().unwrap();
                    if st.panic.is_none() {
                        st.panic = Some(payload);
                    }
                }
            }
        }
        // Report completion.
        let mut st = shared.state.lock().unwrap();
        st.remaining -= 1;
        let done = st.remaining == 0;
        shared.pending.store(st.remaining, Ordering::Release);
        drop(st);
        if done {
            shared.done_cv.notify_all();
        }
    }
}

/// Contiguous, balanced partition of `0..k` into `t` ranges (some may be
/// empty when `k < t`). Pure function of `(k, t)`.
fn partition_ranges(k: usize, t: usize) -> Vec<Range<usize>> {
    let base = k / t;
    let rem = k % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, k);
    out
}

/// Raw-pointer wrapper that lets a `Fn + Sync` shard task write into a
/// caller-owned buffer. Safety contract: every index written through the
/// pointer is touched by exactly one worker (the shard partition
/// guarantees this when indices are derived from the component range),
/// and the buffer outlives the `run` call.
#[derive(Clone, Copy)]
pub struct SharedMut<T>(*mut T);

unsafe impl<T: Send> Send for SharedMut<T> {}
unsafe impl<T: Send> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(ptr: *mut T) -> SharedMut<T> {
        SharedMut(ptr)
    }

    /// Raw element pointer at offset `i`.
    ///
    /// # Safety
    /// `i` must be in bounds of the original allocation, and no other
    /// thread may concurrently access the same element.
    pub unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }

    /// Mutable slice view of `len` elements starting at `start`.
    ///
    /// # Safety
    /// `[start, start+len)` must be in bounds and disjoint from every
    /// range any other thread accesses during the same `run` call.
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_and_balances() {
        for k in [0usize, 1, 2, 3, 7, 8, 31, 32, 1000] {
            for t in [1usize, 2, 3, 4, 8] {
                let ranges = partition_ranges(k, t);
                assert_eq!(ranges.len(), t);
                let mut covered = 0;
                let mut prev_end = 0;
                for r in &ranges {
                    assert_eq!(r.start, prev_end, "ranges must be contiguous");
                    prev_end = r.end;
                    covered += r.len();
                }
                assert_eq!(covered, k);
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let max = lens.iter().max().unwrap();
                let min = lens.iter().min().unwrap();
                assert!(max - min <= 1, "unbalanced: {lens:?}");
            }
        }
    }

    #[test]
    fn pool_runs_tasks_over_all_indices() {
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let k = 37;
            let mut hits = vec![0u64; k];
            let out = SharedMut::new(hits.as_mut_ptr());
            pool.run(k, &move |worker, range, scratch| {
                scratch.ensure(4);
                assert!(worker < threads);
                for j in range {
                    // Safety: each j belongs to exactly one shard.
                    unsafe { *out.at(j) += (j as u64) + 1 };
                }
            });
            for (j, &h) in hits.iter().enumerate() {
                assert_eq!(h, (j as u64) + 1, "index {j} visited wrong number of times");
            }
        }
    }

    #[test]
    fn pool_survives_many_small_epochs() {
        let pool = WorkerPool::new(4);
        let mut acc = vec![0u64; 16];
        for round in 0..500u64 {
            let out = SharedMut::new(acc.as_mut_ptr());
            pool.run(16, &move |_, range, _| {
                for j in range {
                    unsafe { *out.at(j) += round };
                }
            });
        }
        let expect: u64 = (0..500).sum();
        assert!(acc.iter().all(|&v| v == expect));
    }

    #[test]
    fn empty_k_is_noop() {
        let pool = WorkerPool::new(2);
        pool.run(0, &|_, _, _| panic!("must not run"));
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|_, range, _| {
                if range.contains(&0) {
                    panic!("shard boom");
                }
            });
        }));
        assert!(result.is_err(), "shard panic must reach the caller");
        // The pool is still usable for the next epoch.
        let mut ok = vec![0u8; 8];
        let out = SharedMut::new(ok.as_mut_ptr());
        pool.run(8, &move |_, range, _| {
            for j in range {
                unsafe { *out.at(j) = 1 };
            }
        });
        assert!(ok.iter().all(|&v| v == 1));
    }

    #[test]
    fn scratch_split3_is_disjoint_and_grows() {
        let pool = WorkerPool::new(2);
        for (a, b, c) in [(8usize, 8usize, 2usize), (32, 0, 4), (4, 4, 1)] {
            pool.run(4, &move |_, _, scratch| {
                let (x, y, z) = scratch.split3(a, b, c);
                assert_eq!((x.len(), y.len(), z.len()), (a, b, c));
                x.fill(1.0);
                y.fill(2.0);
                z.fill(3.0);
                assert!(x.iter().all(|&v| v == 1.0));
                assert!(y.iter().all(|&v| v == 2.0));
                assert!(z.iter().all(|&v| v == 3.0));
            });
        }
    }

    #[test]
    fn scratch_grows_and_persists() {
        let pool = WorkerPool::new(2);
        // First epoch sizes the arenas; later epochs see them pre-sized
        // (len only grows).
        for d in [4usize, 8, 8, 2] {
            pool.run(8, &move |_, _, scratch| {
                scratch.ensure(d);
                assert!(scratch.e.len() >= d);
                assert!(scratch.tmp.len() >= d);
                scratch.e[..d].fill(1.0);
            });
        }
    }
}
