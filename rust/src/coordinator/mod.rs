//! L3 — the streaming coordinator.
//!
//! The paper's algorithm is single-pass and online; this layer turns it
//! into a deployable stream-processing service, mirroring the router/
//! worker split of serving frameworks (cf. vLLM's router):
//!
//! - [`worker`] — one OS thread per model shard; owns a native
//!   [`crate::gmm::SupervisedGmm`] (learning is inherently sequential per
//!   model) and, when AOT artifacts are available, an XLA batch-scoring
//!   path for inference traffic.
//! - [`router`] — spreads records across shards (round-robin /
//!   feature-hash / broadcast-ensemble policies).
//! - [`batcher`] — groups inference requests into size-or-deadline
//!   micro-batches before they hit a worker.
//! - [`backpressure`] — bounded queues with block/drop policies between
//!   all stages.
//! - [`registry`] — named-model lifecycle (create, lookup, drop,
//!   checkpoint).
//! - [`server`] — a line-delimited-JSON TCP front end over the
//!   [`protocol`] types.
//! - [`metrics`] — per-stage counters and latency statistics.
//!
//! Threading model: plain `std::thread` + `std::sync::mpsc` (the offline
//! vendor set has no tokio — DESIGN.md §5); every queue is bounded, so
//! backpressure propagates from workers to the ingest edge.

pub mod backpressure;
pub mod batcher;
pub mod checkpoint;
pub mod metrics;
pub mod protocol;
pub mod registry;
pub mod router;
pub mod server;
pub mod worker;

pub use backpressure::{BoundedQueue, OverflowPolicy};
pub use batcher::{Batch, Batcher, BatcherConfig};
pub use checkpoint::CheckpointStore;
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{ModelSpec, Registry};
pub use router::{Router, RoutingPolicy};
pub use server::{serve, ServerConfig};
pub use worker::{Worker, WorkerHandle, WorkerStats};

/// Coordinator-level errors.
#[derive(Debug)]
pub enum CoordError {
    /// The target worker/model does not exist.
    UnknownModel(String),
    /// A bounded queue rejected the item (drop policy) or the worker hung
    /// up.
    Rejected(&'static str),
    /// Underlying I/O problem (server, checkpointing).
    Io(std::io::Error),
    /// Malformed request/checkpoint payload.
    Protocol(String),
}

impl std::fmt::Display for CoordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            CoordError::Rejected(stage) => write!(f, "rejected at {stage}"),
            CoordError::Io(e) => write!(f, "io: {e}"),
            CoordError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for CoordError {}

impl From<std::io::Error> for CoordError {
    fn from(e: std::io::Error) -> Self {
        CoordError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, CoordError>;
