//! L3 — the streaming coordinator.
//!
//! The paper's algorithm is single-pass and online; this layer turns it
//! into a deployable stream-processing service, mirroring the router/
//! worker split of serving frameworks (cf. vLLM's router):
//!
//! - [`worker`] — one OS thread per model shard; owns a native
//!   [`crate::gmm::SupervisedGmm`] (learning is inherently sequential per
//!   model) and, when AOT artifacts are available, an XLA batch-scoring
//!   path for inference traffic. Every `snapshot_interval` learn steps it
//!   republishes an immutable [`crate::gmm::ModelSnapshot`] into a shared
//!   [`worker::SnapshotCell`] for the read path.
//! - [`scorer`] — the read half of the read–write split: a fixed pool of
//!   scorer threads serving `score`/`predict` traffic from published
//!   snapshots, never queueing behind the learn path.
//! - [`router`] — spreads records across shards (round-robin /
//!   feature-hash / broadcast-ensemble policies) and splits traffic into
//!   a **write class** (learn + sequential read-your-writes predict,
//!   through the worker queues) and a **read class**
//!   (`score_read`/`predict_read`/`*_batch_read`, served from snapshots
//!   on the scorer pool).
//! - [`batcher`] — size-or-deadline micro-batching. The server's
//!   drivers use it to coalesce concurrent single-query snapshot reads
//!   for the same model into the blocked batch-read surfaces
//!   (bit-identical to per-request dispatch; adds at most `max_delay`
//!   to a lone read).
//! - [`backpressure`] — bounded queues with block/drop policies between
//!   all stages.
//! - [`registry`] — named-model lifecycle (create, lookup, drop,
//!   checkpoint); owns the shared scorer pool. The model table is
//!   name-sharded across 16 locks so unrelated tenants never contend.
//! - [`server`] — a line-delimited-JSON TCP front end over the
//!   [`protocol`] types, run as a readiness-driven multiplexed event
//!   loop: a small pool of driver threads each `poll(2)`s many
//!   nonblocking sockets (no idle wakeups; cross-thread wakeup via a
//!   loopback self-pipe), frames request lines incrementally with a
//!   bounded buffer, and writes responses back in request order.
//!   Shutdown wakes and joins every driver — no driver touches the
//!   registry after `Server::shutdown` returns.
//! - [`framing`] — the bounded incremental line framer (pure, so its
//!   tests run under miri).
//! - [`poller`] — minimal `poll(2)`/`rlimit` FFI plus the loopback
//!   wake pair (std links libc; no external crates).
//! - [`metrics`] — per-stage counters and latency statistics:
//!   snapshot publish counts, observed read staleness, read-coalescing
//!   counters, and lock-free p50/p95/p99 latency histograms per
//!   traffic class (read / write / control).
//!
//! ## Snapshot staleness contract
//!
//! Read-class results may lag the model's **applied** learn stream by
//! fewer than `snapshot_interval` learn steps while the stream flows
//! (the worker republishes every N applied learns), plus at most one
//! worker queue timeout (~50 ms) when the stream pauses (the idle
//! republish catches the snapshot up). Learns that are accepted but
//! still sitting in a shard's command queue are not yet applied, so
//! under backlog the lag relative to *enqueued* writes can additionally
//! reach the queue depth (`WorkerConfig::queue_capacity`) — the
//! sequential `predict` path is the one that observes every queued
//! learn. Within one snapshot, results are deterministic and
//! bit-identical to a serial model trained on the same prefix. Pick a
//! small `snapshot_interval` (the default is 8) when reads must track
//! writes closely; raise it — or set it to 0 on write-only workloads —
//! to avoid the `O(K·D²)` copy per publish when learn throughput
//! matters more than read freshness.
//!
//! Threading model: plain `std::thread` + `std::sync::mpsc` (the offline
//! vendor set has no tokio — DESIGN.md §5); every queue is bounded, so
//! backpressure propagates from workers to the ingest edge. Read traffic
//! is the exception by design: it touches only the snapshot cells and
//! the scorer pool, so a saturated learn queue cannot stall scoring.

pub mod backpressure;
pub mod batcher;
pub mod checkpoint;
pub mod framing;
pub mod metrics;
pub mod poller;
pub mod protocol;
pub mod registry;
pub mod router;
pub mod scorer;
pub mod server;
pub mod worker;

pub use backpressure::{BoundedQueue, OverflowPolicy};
pub use batcher::{Batch, Batcher, BatcherConfig};
pub use checkpoint::CheckpointStore;
pub use framing::DEFAULT_MAX_LINE_BYTES;
pub use metrics::{LatencySummary, Metrics, MetricsSnapshot, TrafficClass};
pub use registry::{ModelSpec, Registry};
pub use router::{Router, RoutingPolicy};
pub use scorer::ScorerPool;
pub use server::{serve, Server, ServerConfig};
pub use worker::{SnapshotCell, Worker, WorkerHandle, WorkerStats, DEFAULT_SNAPSHOT_INTERVAL};

/// Coordinator-level errors.
#[derive(Debug)]
pub enum CoordError {
    /// The target worker/model does not exist.
    UnknownModel(String),
    /// A bounded queue rejected the item (drop policy) or the worker hung
    /// up.
    Rejected(&'static str),
    /// Underlying I/O problem (server, checkpointing).
    Io(std::io::Error),
    /// Malformed request/checkpoint payload.
    Protocol(String),
}

impl std::fmt::Display for CoordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            CoordError::Rejected(stage) => write!(f, "rejected at {stage}"),
            CoordError::Io(e) => write!(f, "io: {e}"),
            CoordError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for CoordError {}

impl From<std::io::Error> for CoordError {
    fn from(e: std::io::Error) -> Self {
        CoordError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, CoordError>;
