//! Named-model lifecycle: create, look up, drop, checkpoint.

use super::checkpoint::CheckpointStore;
use super::metrics::Metrics;
use super::router::{Router, RoutingPolicy};
use super::scorer::ScorerPool;
use super::worker::{Worker, WorkerConfig, WorkerStats, DEFAULT_SNAPSHOT_INTERVAL};
use super::{CoordError, Result};
use crate::engine::EngineConfig;
use crate::gmm::GmmConfig;
use crate::json::Json;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Everything needed to create a model's shard group.
#[derive(Clone)]
pub struct ModelSpec {
    pub name: String,
    pub n_features: usize,
    pub n_classes: usize,
    pub gmm: GmmConfig,
    pub feature_stds: Vec<f64>,
    pub shards: usize,
    pub policy: RoutingPolicy,
    /// Optional XLA inference config name (see [`WorkerConfig::with_xla`]).
    pub xla_config: Option<String>,
    /// Optional component-sharded engine for every shard's model (see
    /// [`WorkerConfig::with_engine`]).
    pub engine: Option<EngineConfig>,
    /// Learn steps between read-snapshot republishes per shard — the
    /// read path's staleness bound (0 disables snapshot publishing; see
    /// [`WorkerConfig::snapshot_interval`]).
    pub snapshot_interval: usize,
}

impl ModelSpec {
    pub fn new(name: &str, n_features: usize, n_classes: usize) -> Self {
        ModelSpec {
            name: name.to_string(),
            n_features,
            n_classes,
            gmm: GmmConfig::new(1).with_delta(0.1).with_beta(0.05),
            feature_stds: vec![1.0; n_features],
            shards: 1,
            policy: RoutingPolicy::RoundRobin,
            xla_config: None,
            engine: None,
            snapshot_interval: DEFAULT_SNAPSHOT_INTERVAL,
        }
    }

    pub fn with_gmm(mut self, gmm: GmmConfig) -> Self {
        self.gmm = gmm;
        self
    }

    pub fn with_stds(mut self, stds: Vec<f64>) -> Self {
        assert_eq!(stds.len(), self.n_features);
        self.feature_stds = stds;
        self
    }

    pub fn with_shards(mut self, shards: usize, policy: RoutingPolicy) -> Self {
        assert!(shards >= 1);
        self.shards = shards;
        self.policy = policy;
        self
    }

    pub fn with_xla(mut self, config: &str) -> Self {
        self.xla_config = Some(config.to_string());
        self
    }

    /// Select the packed-kernel implementation for every shard's model
    /// (carried in the spec's `GmmConfig`; see
    /// [`crate::linalg::KernelMode`]).
    pub fn with_kernel_mode(mut self, mode: crate::linalg::KernelMode) -> Self {
        self.gmm = self.gmm.with_kernel_mode(mode);
        self
    }

    /// Select the component-axis search strategy for every shard's
    /// model (carried in the spec's `GmmConfig`; see
    /// [`crate::gmm::SearchMode`]).
    pub fn with_search_mode(mut self, mode: crate::gmm::SearchMode) -> Self {
        self.gmm = self.gmm.with_search_mode(mode);
        self
    }

    /// Select the snapshot read-replica mode for every shard's model
    /// (carried in the spec's `GmmConfig`; see
    /// [`crate::gmm::ReplicaMode`]).
    pub fn with_replica_mode(mut self, mode: crate::gmm::ReplicaMode) -> Self {
        self.gmm = self.gmm.with_replica_mode(mode);
        self
    }

    /// Select the write-path staging mode for every shard's model
    /// (carried in the spec's `GmmConfig`; see
    /// [`crate::gmm::LearnMode`]).
    pub fn with_learn_mode(mut self, mode: crate::gmm::LearnMode) -> Self {
        self.gmm = self.gmm.with_learn_mode(mode);
        self
    }

    /// Set the per-point `sp` decay factor for every shard's model
    /// (carried in the spec's `GmmConfig`; `1.0` disables decay).
    pub fn with_decay(mut self, decay: f64) -> Self {
        self.gmm = self.gmm.with_decay(decay);
        self
    }

    /// Evict components not refreshed within `max_age` points (carried
    /// in the spec's `GmmConfig`; `0` disables age-based eviction).
    pub fn with_max_age(mut self, max_age: u64) -> Self {
        self.gmm = self.gmm.with_max_age(max_age);
        self
    }

    /// Attach a component-sharded engine to every shard of this model.
    /// Each shard gets its own pool; `EngineConfig::auto()` (threads=0)
    /// is resolved at create time as `cores / shards` so a sharded model
    /// doesn't oversubscribe the machine by shards × cores threads.
    pub fn with_engine(mut self, engine: EngineConfig) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Set the per-shard snapshot republish interval (0 disables the
    /// snapshot read path for this model).
    pub fn with_snapshot_interval(mut self, every: usize) -> Self {
        self.snapshot_interval = every;
        self
    }
}

struct Entry {
    router: Arc<Router>,
    workers: Vec<Worker>,
    spec: ModelSpec,
}

/// Lock shards for the model table. Lookups hash the model name to one
/// shard, so unrelated tenants never contend on a registry lock even
/// when thousands of connections resolve models concurrently (the
/// event-loop server does a router+spec lookup per request).
const LOCK_SHARDS: usize = 16;

/// FNV-1a over the model name — stable, cheap, and the same name always
/// lands on the same shard (which is what makes the create-time
/// uniqueness check sound under sharding).
fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h as usize) % LOCK_SHARDS
}

/// Thread-safe model registry — the coordinator's control plane.
pub struct Registry {
    /// Name-sharded model table (see [`shard_of`]).
    models: Vec<Mutex<HashMap<String, Entry>>>,
    metrics: Arc<Metrics>,
    checkpoints: Option<CheckpointStore>,
    /// Shared scorer pool serving every model's snapshot read class —
    /// spawned lazily on first use so registries that never create a
    /// model (or set an explicit size) carry no idle threads.
    scorers: OnceLock<Arc<ScorerPool>>,
}

/// Default scorer-thread count: half the machine (the other half is for
/// learners/workers), clamped to [1, 4] — override with
/// [`Registry::with_scorers`].
fn default_scorer_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).clamp(1, 4))
        .unwrap_or(1)
}

impl Registry {
    pub fn new(metrics: Arc<Metrics>) -> Self {
        Registry {
            models: (0..LOCK_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            metrics,
            checkpoints: None,
            scorers: OnceLock::new(),
        }
    }

    /// The lock shard owning `name`.
    fn shard(&self, name: &str) -> &Mutex<HashMap<String, Entry>> {
        &self.models[shard_of(name)]
    }

    /// Enable checkpointing into a directory.
    pub fn with_checkpoints(mut self, store: CheckpointStore) -> Self {
        self.checkpoints = Some(store);
        self
    }

    /// Use a scorer pool of `threads` threads. Call before creating
    /// models — routers capture the pool at create time.
    pub fn with_scorers(mut self, threads: usize) -> Self {
        self.scorers = OnceLock::new();
        let _ = self.scorers.set(Arc::new(ScorerPool::new(threads)));
        self
    }

    /// The scorer pool, created on first use.
    fn scorers(&self) -> &Arc<ScorerPool> {
        self.scorers.get_or_init(|| Arc::new(ScorerPool::new(default_scorer_threads())))
    }

    /// Scorer threads serving the snapshot read path.
    pub fn scorer_threads(&self) -> usize {
        self.scorers().threads()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Create a model; errors if the name exists. Holds only the name's
    /// lock shard — creates of differently-named models proceed in
    /// parallel.
    pub fn create(&self, spec: ModelSpec) -> Result<()> {
        let mut models = self.shard(&spec.name).lock().unwrap();
        if models.contains_key(&spec.name) {
            return Err(CoordError::Protocol(format!("model '{}' already exists", spec.name)));
        }
        let mut workers = Vec::with_capacity(spec.shards);
        let mut handles = Vec::with_capacity(spec.shards);
        for _ in 0..spec.shards {
            let mut wc = WorkerConfig::new(
                spec.n_features,
                spec.n_classes,
                spec.gmm.clone(),
                spec.feature_stds.clone(),
            )
            .with_snapshot_interval(spec.snapshot_interval);
            if let Some(x) = &spec.xla_config {
                wc = wc.with_xla(x.clone());
            }
            if let Some(mut e) = spec.engine {
                if e.threads == 0 {
                    // Divide auto parallelism among the shards (each
                    // runs its own pool concurrently).
                    let cores = std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1);
                    e.threads = (cores / spec.shards.max(1)).max(1);
                }
                wc = wc.with_engine(e);
            }
            let w = Worker::spawn(wc, self.metrics.clone());
            handles.push(w.handle.clone());
            workers.push(w);
        }
        let router = Arc::new(
            Router::new(handles, spec.policy)
                .with_read_path(self.scorers().clone(), self.metrics.clone())
                .with_shape(spec.n_features, spec.n_classes),
        );
        models.insert(spec.name.clone(), Entry { router, workers, spec });
        Ok(())
    }

    /// Look up the router for a model.
    pub fn router(&self, name: &str) -> Result<Arc<Router>> {
        self.shard(name)
            .lock()
            .unwrap()
            .get(name)
            .map(|e| e.router.clone())
            .ok_or_else(|| CoordError::UnknownModel(name.to_string()))
    }

    /// Aggregate stats across a model's shards.
    pub fn stats(&self, name: &str) -> Result<Json> {
        let router = self.router(name)?;
        let mut shard_stats: Vec<WorkerStats> = Vec::new();
        for s in router.shards() {
            shard_stats.push(s.stats()?);
        }
        let total = |f: fn(&WorkerStats) -> u64| -> usize {
            shard_stats.iter().map(|s| f(s) as usize).sum()
        };
        Ok(Json::obj(vec![
            ("shards", shard_stats.len().into()),
            ("scorers", self.scorers().threads().into()),
            ("components", shard_stats.iter().map(|s| s.components).sum::<usize>().into()),
            ("learned", total(|s| s.learned).into()),
            ("predicted", total(|s| s.predicted).into()),
            ("xla_batches", total(|s| s.xla_batches).into()),
            // Model memory footprint: total arena payload across shards
            // (packed-symmetric layout — about half the dense size).
            ("model_bytes", shard_stats.iter().map(|s| s.model_bytes).sum::<usize>().into()),
            // f32 read-replica payload across shards (0 unless the
            // model was created with a replica mode).
            ("replica_bytes", shard_stats.iter().map(|s| s.replica_bytes).sum::<usize>().into()),
            // Candidate-index machinery totals (all-zero for Strict
            // models; see `gmm::IndexCounters`).
            ("index_rebuilds", total(|s| s.index_rebuilds).into()),
            (
                "index_incremental_updates",
                total(|s| s.index_incremental_updates).into(),
            ),
            ("fallback_gate_triggers", total(|s| s.fallback_gate_triggers).into()),
            ("masked_block_rows", total(|s| s.masked_block_rows).into()),
            ("coordinator", self.metrics.snapshot().to_json()),
            (
                "per_shard",
                Json::Arr(shard_stats.iter().map(WorkerStats::to_json).collect()),
            ),
        ]))
    }

    /// Checkpoint every shard of a model. Returns the file paths written.
    pub fn checkpoint(&self, name: &str) -> Result<Vec<String>> {
        let store = self
            .checkpoints
            .as_ref()
            .ok_or(CoordError::Rejected("checkpointing disabled"))?;
        let router = self.router(name)?;
        let mut paths = Vec::new();
        for (i, s) in router.shards().iter().enumerate() {
            let doc = s.checkpoint_json()?;
            paths.push(store.save(name, i, &doc)?);
        }
        Ok(paths)
    }

    /// Drop a model, joining its workers.
    pub fn drop_model(&self, name: &str) -> Result<()> {
        let entry = self
            .shard(name)
            .lock()
            .unwrap()
            .remove(name)
            .ok_or_else(|| CoordError::UnknownModel(name.to_string()))?;
        drop(entry.router);
        for w in entry.workers {
            w.join();
        }
        Ok(())
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models
            .iter()
            .flat_map(|m| m.lock().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect()
    }

    /// The spec a model was created with.
    pub fn spec(&self, name: &str) -> Result<ModelSpec> {
        self.shard(name)
            .lock()
            .unwrap()
            .get(name)
            .map(|e| e.spec.clone())
            .ok_or_else(|| CoordError::UnknownModel(name.to_string()))
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        let names = self.model_names();
        for n in names {
            let _ = self.drop_model(&n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn registry() -> Registry {
        Registry::new(Arc::new(Metrics::new()))
    }

    fn blob_spec(name: &str) -> ModelSpec {
        ModelSpec::new(name, 2, 3)
            .with_gmm(GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning())
            .with_stds(vec![3.0, 3.0])
    }

    #[test]
    fn create_learn_predict_drop() {
        let reg = registry();
        reg.create(blob_spec("m")).unwrap();
        let router = reg.router("m").unwrap();
        let mut rng = Pcg64::seed(1);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..150 {
            let c = i % 3;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        let scores = router.predict(&[7.0, 7.0]).unwrap();
        let best = scores.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(best, 1);
        let stats = reg.stats("m").unwrap();
        assert_eq!(stats.get("learned").unwrap().as_usize(), Some(150));
        // The memory footprint gauge reflects the packed arenas: joint
        // dim is 2 features + 3 classes = 5 → 5 + 15 + 2 floats + the
        // u64 age and refresh stamp.
        let per_comp = (5 + 15 + 2) * 8 + 16;
        let components = stats.get("components").unwrap().as_usize().unwrap();
        assert!(components > 0);
        assert_eq!(
            stats.get("model_bytes").unwrap().as_usize(),
            Some(components * per_comp)
        );
        reg.drop_model("m").unwrap();
        assert!(reg.router("m").is_err());
    }

    #[test]
    fn duplicate_name_rejected() {
        let reg = registry();
        reg.create(blob_spec("m")).unwrap();
        assert!(reg.create(blob_spec("m")).is_err());
    }

    #[test]
    fn unknown_model_errors() {
        let reg = registry();
        assert!(matches!(reg.router("nope"), Err(CoordError::UnknownModel(_))));
        assert!(reg.stats("nope").is_err());
        assert!(reg.drop_model("nope").is_err());
    }

    #[test]
    fn engine_spec_resolves_and_model_serves() {
        let reg = registry();
        reg.create(
            blob_spec("e")
                .with_shards(2, RoutingPolicy::RoundRobin)
                .with_engine(EngineConfig::auto()),
        )
        .unwrap();
        let router = reg.router("e").unwrap();
        for i in 0..30 {
            router.learn(vec![i as f64, 0.0], i % 3).unwrap();
        }
        assert_eq!(router.predict(&[0.0, 0.0]).unwrap().len(), 3);
        reg.drop_model("e").unwrap();
    }

    #[test]
    fn kernel_mode_spec_propagates_and_serves() {
        use crate::linalg::KernelMode;
        let reg = registry();
        reg.create(blob_spec("f").with_kernel_mode(KernelMode::Fast)).unwrap();
        let router = reg.router("f").unwrap();
        let mut rng = Pcg64::seed(3);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..60 {
            let c = i % 3;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        assert_eq!(router.predict(&[7.0, 7.0]).unwrap().len(), 3);
        assert_eq!(reg.spec("f").unwrap().gmm.kernel_mode, KernelMode::Fast);
        reg.drop_model("f").unwrap();
    }

    #[test]
    fn search_mode_spec_propagates_and_serves() {
        use crate::gmm::SearchMode;
        let reg = registry();
        reg.create(blob_spec("t").with_search_mode(SearchMode::TopC { c: 4 })).unwrap();
        let router = reg.router("t").unwrap();
        let mut rng = Pcg64::seed(9);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..60 {
            let c = i % 3;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        assert_eq!(router.predict(&[7.0, 7.0]).unwrap().len(), 3);
        assert_eq!(reg.spec("t").unwrap().gmm.search_mode, SearchMode::TopC { c: 4 });
        reg.drop_model("t").unwrap();
    }

    #[test]
    fn learn_mode_spec_propagates_and_serves_batches() {
        use crate::gmm::LearnMode;
        let reg = registry();
        reg.create(
            blob_spec("mb")
                .with_learn_mode(LearnMode::MiniBatch { b: 16 })
                .with_decay(0.999)
                .with_max_age(10_000),
        )
        .unwrap();
        let router = reg.router("mb").unwrap();
        let mut rng = Pcg64::seed(13);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..120 {
            let c = i % 3;
            xs.push(vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7]);
            labels.push(c);
        }
        for (cx, cc) in xs.chunks(40).zip(labels.chunks(40)) {
            router.learn_batch(cx.to_vec(), cc.to_vec()).unwrap();
        }
        let scores = router.predict(&[7.0, 7.0]).unwrap();
        let best =
            scores.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(best, 1);
        let spec = reg.spec("mb").unwrap();
        assert_eq!(spec.gmm.learn_mode, LearnMode::MiniBatch { b: 16 });
        assert_eq!(spec.gmm.decay, 0.999);
        assert_eq!(spec.gmm.max_age, 10_000);
        let stats = reg.stats("mb").unwrap();
        assert_eq!(stats.get("learned").unwrap().as_usize(), Some(120));
        let coord = stats.get("coordinator").unwrap();
        assert_eq!(coord.get("points_learned").unwrap().as_usize(), Some(120));
        reg.drop_model("mb").unwrap();
    }

    #[test]
    fn replica_mode_spec_propagates_and_serves() {
        use crate::gmm::ReplicaMode;
        let reg = registry();
        reg.create(
            blob_spec("p")
                .with_replica_mode(ReplicaMode::f32_default())
                .with_snapshot_interval(4),
        )
        .unwrap();
        let router = reg.router("p").unwrap();
        let mut rng = Pcg64::seed(11);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..60 {
            let c = i % 3;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        assert_eq!(router.predict(&[7.0, 7.0]).unwrap().len(), 3);
        assert_eq!(reg.spec("p").unwrap().gmm.replica_mode, ReplicaMode::f32_default());
        router.shards()[0]
            .wait_snapshot_points(60, 1000)
            .expect("snapshot never caught up");
        // The published snapshot carries an f32 replica, and the stats
        // surface reports its footprint (half the f64 mean+mat payload).
        let joint = vec![7.0, 7.0, 0.0, 1.0, 0.0];
        assert!(router.score_read(&joint).unwrap().is_finite());
        let stats = reg.stats("p").unwrap();
        let replica_bytes = stats.get("replica_bytes").unwrap().as_usize().unwrap();
        assert!(replica_bytes > 0, "replica-configured model reports replica bytes");
        // Replica-off models report zero.
        reg.create(blob_spec("p0").with_snapshot_interval(4)).unwrap();
        let r0 = reg.router("p0").unwrap();
        r0.learn(vec![0.0, 0.0], 0).unwrap();
        let s0 = reg.stats("p0").unwrap();
        assert_eq!(s0.get("replica_bytes").unwrap().as_usize(), Some(0));
        reg.drop_model("p").unwrap();
        reg.drop_model("p0").unwrap();
    }

    #[test]
    fn read_path_serves_through_registry_scorers() {
        let reg = registry().with_scorers(2);
        assert_eq!(reg.scorer_threads(), 2);
        reg.create(blob_spec("r").with_snapshot_interval(4)).unwrap();
        let router = reg.router("r").unwrap();
        let mut rng = Pcg64::seed(7);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..16 {
            let c = i % 3;
            router
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        // Drain the queue, then wait for the snapshot to catch up.
        let _ = reg.stats("r").unwrap();
        router.shards()[0]
            .wait_snapshot_points(16, 1000)
            .expect("snapshot never caught up");
        let scores = router.predict_read(&[7.0, 7.0]).unwrap();
        assert_eq!(scores, router.predict(&[7.0, 7.0]).unwrap());
        let joint = vec![7.0, 7.0, 0.0, 1.0, 0.0];
        assert!(router.score_read(&joint).unwrap().is_finite());
        let stats = reg.stats("r").unwrap();
        assert_eq!(stats.get("scorers").unwrap().as_usize(), Some(2));
        let coord = stats.get("coordinator").unwrap();
        assert!(coord.get("snapshots_published").unwrap().as_usize().unwrap() >= 1);
        assert!(coord.get("snapshot_reads").unwrap().as_usize().unwrap() >= 2);
        reg.drop_model("r").unwrap();
    }

    #[test]
    fn lock_sharding_keeps_tenants_independent() {
        // Many tenants created/used/dropped from concurrent threads:
        // the name-sharded lock table must preserve the uniqueness
        // check and never lose or cross-wire an entry.
        let reg = Arc::new(registry());
        let mut handles = Vec::new();
        for t in 0..8 {
            let reg = reg.clone();
            handles.push(std::thread::spawn(move || {
                let name = format!("tenant-{t}");
                reg.create(blob_spec(&name)).unwrap();
                // A duplicate create must still be rejected on the same
                // shard.
                assert!(reg.create(blob_spec(&name)).is_err());
                let router = reg.router(&name).unwrap();
                for i in 0..30 {
                    router.learn(vec![i as f64, t as f64], i % 3).unwrap();
                }
                let stats = reg.stats(&name).unwrap();
                assert_eq!(stats.get("learned").unwrap().as_usize(), Some(30));
                assert_eq!(reg.spec(&name).unwrap().name, name);
                reg.drop_model(&name).unwrap();
                assert!(reg.router(&name).is_err());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(reg.model_names().is_empty());
    }

    #[test]
    fn sharded_model_aggregates_stats() {
        let reg = registry();
        reg.create(blob_spec("s").with_shards(3, RoutingPolicy::RoundRobin)).unwrap();
        let router = reg.router("s").unwrap();
        let mut rng = Pcg64::seed(2);
        for i in 0..90 {
            let c = i % 3;
            router.learn(vec![rng.normal() + c as f64 * 6.0, rng.normal()], c).unwrap();
        }
        let stats = reg.stats("s").unwrap();
        assert_eq!(stats.get("shards").unwrap().as_usize(), Some(3));
        assert_eq!(stats.get("learned").unwrap().as_usize(), Some(90));
    }
}
