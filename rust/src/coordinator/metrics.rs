//! Coordinator metrics: lock-light counters plus latency statistics,
//! snapshotted to JSON for the `stats` protocol op and the benches.

use crate::json::Json;
use crate::stats::Welford;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared metrics hub (one per coordinator; cheap to clone via Arc).
#[derive(Default)]
pub struct Metrics {
    learned: AtomicU64,
    predicted: AtomicU64,
    created_components: AtomicU64,
    shed: AtomicU64,
    learn_latency: Mutex<Welford>,
    predict_latency: Mutex<Welford>,
    batch_sizes: Mutex<Welford>,
    // --- read-path (snapshot) counters ---
    snapshots_published: AtomicU64,
    snapshot_reads: AtomicU64,
    snapshot_fallbacks: AtomicU64,
    /// Learn steps between consecutive publishes — the staleness bound
    /// actually observed (≤ snapshot_interval by construction).
    snapshot_lag: Mutex<Welford>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_learn(&self, started: Instant) {
        self.learned.fetch_add(1, Ordering::Relaxed);
        self.learn_latency.lock().unwrap().push(started.elapsed().as_secs_f64());
    }

    pub fn record_predict(&self, started: Instant, batch: usize) {
        self.predicted.fetch_add(batch as u64, Ordering::Relaxed);
        self.predict_latency.lock().unwrap().push(started.elapsed().as_secs_f64());
        self.batch_sizes.lock().unwrap().push(batch as f64);
    }

    pub fn record_component_created(&self) {
        self.created_components.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker republished its read snapshot after `lag_points` learn
    /// steps (the staleness the previous snapshot had accumulated).
    pub fn record_snapshot_publish(&self, lag_points: u64) {
        self.snapshots_published.fetch_add(1, Ordering::Relaxed);
        self.snapshot_lag.lock().unwrap().push(lag_points as f64);
    }

    /// A read-class request (score/predict) was served from snapshots.
    pub fn record_snapshot_read(&self) {
        self.snapshot_reads.fetch_add(1, Ordering::Relaxed);
    }

    /// A read-class request fell back to the sequential write path
    /// (no snapshot published yet).
    pub fn record_snapshot_fallback(&self) {
        self.snapshot_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let learn = self.learn_latency.lock().unwrap().clone();
        let predict = self.predict_latency.lock().unwrap().clone();
        let batch = self.batch_sizes.lock().unwrap().clone();
        let lag = self.snapshot_lag.lock().unwrap().clone();
        MetricsSnapshot {
            learned: self.learned.load(Ordering::Relaxed),
            predicted: self.predicted.load(Ordering::Relaxed),
            created_components: self.created_components.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            learn_latency_mean_s: learn.mean(),
            learn_latency_max_s: if learn.count() > 0 { learn.max() } else { 0.0 },
            predict_latency_mean_s: predict.mean(),
            predict_latency_max_s: if predict.count() > 0 { predict.max() } else { 0.0 },
            mean_batch: batch.mean(),
            snapshots_published: self.snapshots_published.load(Ordering::Relaxed),
            snapshot_reads: self.snapshot_reads.load(Ordering::Relaxed),
            snapshot_fallbacks: self.snapshot_fallbacks.load(Ordering::Relaxed),
            snapshot_lag_mean_points: lag.mean(),
            snapshot_lag_max_points: if lag.count() > 0 { lag.max() } else { 0.0 },
        }
    }
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub learned: u64,
    pub predicted: u64,
    pub created_components: u64,
    pub shed: u64,
    pub learn_latency_mean_s: f64,
    pub learn_latency_max_s: f64,
    pub predict_latency_mean_s: f64,
    pub predict_latency_max_s: f64,
    pub mean_batch: f64,
    pub snapshots_published: u64,
    pub snapshot_reads: u64,
    pub snapshot_fallbacks: u64,
    pub snapshot_lag_mean_points: f64,
    pub snapshot_lag_max_points: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learned", (self.learned as usize).into()),
            ("predicted", (self.predicted as usize).into()),
            ("created_components", (self.created_components as usize).into()),
            ("shed", (self.shed as usize).into()),
            ("learn_latency_mean_s", self.learn_latency_mean_s.into()),
            ("learn_latency_max_s", self.learn_latency_max_s.into()),
            ("predict_latency_mean_s", self.predict_latency_mean_s.into()),
            ("predict_latency_max_s", self.predict_latency_max_s.into()),
            ("mean_batch", self.mean_batch.into()),
            ("snapshots_published", (self.snapshots_published as usize).into()),
            ("snapshot_reads", (self.snapshot_reads as usize).into()),
            ("snapshot_fallbacks", (self.snapshot_fallbacks as usize).into()),
            ("snapshot_lag_mean_points", self.snapshot_lag_mean_points.into()),
            ("snapshot_lag_max_points", self.snapshot_lag_max_points.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        let t = Instant::now();
        m.record_learn(t);
        m.record_learn(t);
        m.record_predict(t, 8);
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.learned, 2);
        assert_eq!(s.predicted, 8);
        assert_eq!(s.shed, 1);
        assert_eq!(s.mean_batch, 8.0);
        assert!(s.learn_latency_mean_s >= 0.0);
    }

    #[test]
    fn snapshot_read_path_counters() {
        let m = Metrics::new();
        m.record_snapshot_publish(8);
        m.record_snapshot_publish(4);
        m.record_snapshot_read();
        m.record_snapshot_fallback();
        let s = m.snapshot();
        assert_eq!(s.snapshots_published, 2);
        assert_eq!(s.snapshot_reads, 1);
        assert_eq!(s.snapshot_fallbacks, 1);
        assert_eq!(s.snapshot_lag_mean_points, 6.0);
        assert_eq!(s.snapshot_lag_max_points, 8.0);
    }

    #[test]
    fn snapshot_serializes() {
        let m = Metrics::new();
        m.record_learn(Instant::now());
        let j = m.snapshot().to_json().to_string_compact();
        assert!(j.contains("\"learned\":1"));
        crate::json::parse(&j).unwrap();
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    m.record_learn(Instant::now());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().learned, 1000);
    }
}
