//! Coordinator metrics: lock-light counters plus latency statistics,
//! snapshotted to JSON for the `stats` protocol op and the benches.

use crate::gmm::IndexCounters;
use crate::json::Json;
use crate::stats::Welford;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Which latency histogram a request feeds (see
/// [`crate::coordinator::protocol::Request::traffic_class`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Snapshot-served reads: `score`, `score_batch`, snapshot
    /// `predict`, `predict_batch`.
    Read,
    /// Through the worker queues: `learn`, `learn_reg`, sequential
    /// `predict`, `predict_reg`.
    Write,
    /// Lifecycle / introspection: create, stats, checkpoint, drop,
    /// ping, shutdown — plus protocol errors.
    Control,
}

/// Lock-free latency histogram with power-of-two nanosecond buckets:
/// bucket `i` holds durations in `(2^(i-1), 2^i]` ns, so 64 buckets
/// span sub-nanosecond to ~584 years. Quantiles come back as the
/// bucket's upper bound — at worst a 2× overestimate, which is the
/// right bias for tail-latency alerting and costs zero locks on the
/// hot path.
pub struct LatencyHistogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
}

// `[T; 64]` has no derived Default (the std impls stop at 32).
impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(d: Duration) -> usize {
        let nanos = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        (64 - nanos.leading_zeros() as usize).min(63)
    }

    pub fn record(&self, d: Duration) {
        self.buckets[Self::bucket(d)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Nearest-rank quantile (`q` in [0, 1]) in seconds; 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // Upper bound of bucket i is 2^i ns.
                return (1u64 << i.min(62)) as f64 * 1e-9;
            }
        }
        (1u64 << 62) as f64 * 1e-9
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            p50_s: self.quantile(0.50),
            p95_s: self.quantile(0.95),
            p99_s: self.quantile(0.99),
        }
    }
}

/// Tail-latency digest of one traffic class.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl LatencySummary {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", (self.count as usize).into()),
            ("p50_s", self.p50_s.into()),
            ("p95_s", self.p95_s.into()),
            ("p99_s", self.p99_s.into()),
        ])
    }
}

/// Shared metrics hub (one per coordinator; cheap to clone via Arc).
#[derive(Default)]
pub struct Metrics {
    learned: AtomicU64,
    /// Points applied to models — a `learn` advances this by 1, a
    /// `learn_batch` of B by B. This (not `learned`, which counts learn
    /// *operations*) is what the snapshot republish cadence tracks.
    points_learned: AtomicU64,
    predicted: AtomicU64,
    created_components: AtomicU64,
    shed: AtomicU64,
    learn_latency: Mutex<Welford>,
    predict_latency: Mutex<Welford>,
    batch_sizes: Mutex<Welford>,
    // --- read-path (snapshot) counters ---
    snapshots_published: AtomicU64,
    snapshot_reads: AtomicU64,
    snapshot_fallbacks: AtomicU64,
    /// Snapshot reads served from an f32 read replica (a subset of
    /// `snapshot_reads`; 0 for replica-off models).
    replica_reads: AtomicU64,
    /// Learn steps between consecutive publishes — the staleness bound
    /// actually observed (≤ snapshot_interval by construction).
    snapshot_lag: Mutex<Welford>,
    // --- candidate-index machinery (TopC write path) ---
    /// Staleness-triggered full `CandidateIndex` rebuilds across all
    /// shard models (bootstrap builds excluded).
    index_rebuilds: AtomicU64,
    /// Incremental index-maintenance events (create appends + drift
    /// cell reassignments) that replaced what used to be rebuilds.
    index_incremental_updates: AtomicU64,
    /// χ²-fallback gate scans (per-point exact sweeps of unprovable
    /// cells before a create is allowed).
    fallback_gate_triggers: AtomicU64,
    /// Union rows streamed by the masked TopC blocked distance pass.
    masked_block_rows: AtomicU64,
    // --- serving front end (event-loop server) ---
    /// End-to-end request latency per traffic class, measured from the
    /// moment a complete request line is framed to the moment its
    /// response string is ready (includes coalescing queue time).
    read_latency: LatencyHistogram,
    write_latency: LatencyHistogram,
    control_latency: LatencyHistogram,
    /// Single-query reads that went through a coalescing batcher…
    coalesced_reads: AtomicU64,
    /// …and how many blocked-kernel batches they collapsed into.
    coalesced_batches: AtomicU64,
    /// Live-connection gauge per event-loop driver, registered by the
    /// server at startup (shared with its accept-time balancer; absent
    /// when no event-loop server runs on this hub).
    driver_fds: OnceLock<Arc<Vec<AtomicU64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_learn(&self, started: Instant) {
        self.learned.fetch_add(1, Ordering::Relaxed);
        self.points_learned.fetch_add(1, Ordering::Relaxed);
        self.learn_latency.lock().unwrap().push(started.elapsed().as_secs_f64());
    }

    /// One `learn_batch` of `points` examples finished applying — one
    /// learn operation, `points` points, one latency sample (the whole
    /// block's wall time).
    pub fn record_learn_block(&self, started: Instant, points: usize) {
        self.learned.fetch_add(1, Ordering::Relaxed);
        self.points_learned.fetch_add(points as u64, Ordering::Relaxed);
        self.learn_latency.lock().unwrap().push(started.elapsed().as_secs_f64());
    }

    pub fn record_predict(&self, started: Instant, batch: usize) {
        self.predicted.fetch_add(batch as u64, Ordering::Relaxed);
        self.predict_latency.lock().unwrap().push(started.elapsed().as_secs_f64());
        self.batch_sizes.lock().unwrap().push(batch as f64);
    }

    pub fn record_component_created(&self) {
        self.created_components.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker republished its read snapshot after `lag_points` learn
    /// steps (the staleness the previous snapshot had accumulated).
    pub fn record_snapshot_publish(&self, lag_points: u64) {
        self.snapshots_published.fetch_add(1, Ordering::Relaxed);
        self.snapshot_lag.lock().unwrap().push(lag_points as f64);
    }

    /// A read-class request (score/predict) was served from snapshots.
    pub fn record_snapshot_read(&self) {
        self.snapshot_reads.fetch_add(1, Ordering::Relaxed);
    }

    /// A read-class request fell back to the sequential write path
    /// (no snapshot published yet).
    pub fn record_snapshot_fallback(&self) {
        self.snapshot_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// A snapshot read was served from the f32 read replica.
    pub fn record_replica_read(&self) {
        self.replica_reads.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold a model's candidate-index counter *delta* into the hub
    /// (workers call this after each learn op with the counters'
    /// advance since the previous call, so hub totals stay additive
    /// across shards).
    pub fn record_index_counters(&self, delta: IndexCounters) {
        self.index_rebuilds.fetch_add(delta.rebuilds, Ordering::Relaxed);
        self.index_incremental_updates.fetch_add(delta.incremental_updates, Ordering::Relaxed);
        self.fallback_gate_triggers.fetch_add(delta.fallback_gate_triggers, Ordering::Relaxed);
        self.masked_block_rows.fetch_add(delta.masked_block_rows, Ordering::Relaxed);
    }

    /// Share the event-loop server's per-driver connection gauges so
    /// stats can report them. First registration wins (one server per
    /// hub); re-registering is a no-op.
    pub fn register_driver_fds(&self, fds: Arc<Vec<AtomicU64>>) {
        let _ = self.driver_fds.set(fds);
    }

    /// One served request finished (event-loop server front end).
    pub fn record_request_latency(&self, class: TrafficClass, elapsed: Duration) {
        match class {
            TrafficClass::Read => self.read_latency.record(elapsed),
            TrafficClass::Write => self.write_latency.record(elapsed),
            TrafficClass::Control => self.control_latency.record(elapsed),
        }
    }

    /// A coalescing batcher flushed `size` single-query reads as one
    /// blocked batch.
    pub fn record_coalesced_batch(&self, size: u64) {
        self.coalesced_reads.fetch_add(size, Ordering::Relaxed);
        self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let learn = self.learn_latency.lock().unwrap().clone();
        let predict = self.predict_latency.lock().unwrap().clone();
        let batch = self.batch_sizes.lock().unwrap().clone();
        let lag = self.snapshot_lag.lock().unwrap().clone();
        MetricsSnapshot {
            learned: self.learned.load(Ordering::Relaxed),
            points_learned: self.points_learned.load(Ordering::Relaxed),
            predicted: self.predicted.load(Ordering::Relaxed),
            created_components: self.created_components.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            learn_latency_mean_s: learn.mean(),
            learn_latency_max_s: if learn.count() > 0 { learn.max() } else { 0.0 },
            predict_latency_mean_s: predict.mean(),
            predict_latency_max_s: if predict.count() > 0 { predict.max() } else { 0.0 },
            mean_batch: batch.mean(),
            snapshots_published: self.snapshots_published.load(Ordering::Relaxed),
            snapshot_reads: self.snapshot_reads.load(Ordering::Relaxed),
            snapshot_fallbacks: self.snapshot_fallbacks.load(Ordering::Relaxed),
            replica_reads: self.replica_reads.load(Ordering::Relaxed),
            index_rebuilds: self.index_rebuilds.load(Ordering::Relaxed),
            index_incremental_updates: self.index_incremental_updates.load(Ordering::Relaxed),
            fallback_gate_triggers: self.fallback_gate_triggers.load(Ordering::Relaxed),
            masked_block_rows: self.masked_block_rows.load(Ordering::Relaxed),
            snapshot_lag_mean_points: lag.mean(),
            snapshot_lag_max_points: if lag.count() > 0 { lag.max() } else { 0.0 },
            read_latency: self.read_latency.summary(),
            write_latency: self.write_latency.summary(),
            control_latency: self.control_latency.summary(),
            coalesced_reads: self.coalesced_reads.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            driver_fds: self
                .driver_fds
                .get()
                .map_or_else(Vec::new, |g| g.iter().map(|c| c.load(Ordering::Relaxed)).collect()),
        }
    }
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub learned: u64,
    /// Points applied across all learn ops (`learn` = 1, `learn_batch`
    /// of B = B); the snapshot republish cadence counts these.
    pub points_learned: u64,
    pub predicted: u64,
    pub created_components: u64,
    pub shed: u64,
    pub learn_latency_mean_s: f64,
    pub learn_latency_max_s: f64,
    pub predict_latency_mean_s: f64,
    pub predict_latency_max_s: f64,
    pub mean_batch: f64,
    pub snapshots_published: u64,
    pub snapshot_reads: u64,
    pub snapshot_fallbacks: u64,
    pub replica_reads: u64,
    pub index_rebuilds: u64,
    pub index_incremental_updates: u64,
    pub fallback_gate_triggers: u64,
    pub masked_block_rows: u64,
    pub snapshot_lag_mean_points: f64,
    pub snapshot_lag_max_points: f64,
    pub read_latency: LatencySummary,
    pub write_latency: LatencySummary,
    pub control_latency: LatencySummary,
    pub coalesced_reads: u64,
    pub coalesced_batches: u64,
    /// Live connections currently owned by each event-loop driver
    /// (empty when no event-loop server registered its gauges).
    pub driver_fds: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learned", (self.learned as usize).into()),
            ("points_learned", (self.points_learned as usize).into()),
            ("predicted", (self.predicted as usize).into()),
            ("created_components", (self.created_components as usize).into()),
            ("shed", (self.shed as usize).into()),
            ("learn_latency_mean_s", self.learn_latency_mean_s.into()),
            ("learn_latency_max_s", self.learn_latency_max_s.into()),
            ("predict_latency_mean_s", self.predict_latency_mean_s.into()),
            ("predict_latency_max_s", self.predict_latency_max_s.into()),
            ("mean_batch", self.mean_batch.into()),
            ("snapshots_published", (self.snapshots_published as usize).into()),
            ("snapshot_reads", (self.snapshot_reads as usize).into()),
            ("snapshot_fallbacks", (self.snapshot_fallbacks as usize).into()),
            ("replica_reads", (self.replica_reads as usize).into()),
            ("index_rebuilds", (self.index_rebuilds as usize).into()),
            (
                "index_incremental_updates",
                (self.index_incremental_updates as usize).into(),
            ),
            ("fallback_gate_triggers", (self.fallback_gate_triggers as usize).into()),
            ("masked_block_rows", (self.masked_block_rows as usize).into()),
            ("snapshot_lag_mean_points", self.snapshot_lag_mean_points.into()),
            ("snapshot_lag_max_points", self.snapshot_lag_max_points.into()),
            (
                "request_latency",
                Json::obj(vec![
                    ("read", self.read_latency.to_json()),
                    ("write", self.write_latency.to_json()),
                    ("control", self.control_latency.to_json()),
                ]),
            ),
            ("coalesced_reads", (self.coalesced_reads as usize).into()),
            ("coalesced_batches", (self.coalesced_batches as usize).into()),
            (
                "driver_fds",
                Json::Arr(self.driver_fds.iter().map(|&n| (n as usize).into()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        let t = Instant::now();
        m.record_learn(t);
        m.record_learn(t);
        m.record_learn_block(t, 32);
        m.record_predict(t, 8);
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.learned, 3, "a learn_batch is one learn operation");
        assert_eq!(s.points_learned, 34, "…but 32 points");
        assert_eq!(s.predicted, 8);
        assert_eq!(s.shed, 1);
        assert_eq!(s.mean_batch, 8.0);
        assert!(s.learn_latency_mean_s >= 0.0);
    }

    #[test]
    fn snapshot_read_path_counters() {
        let m = Metrics::new();
        m.record_snapshot_publish(8);
        m.record_snapshot_publish(4);
        m.record_snapshot_read();
        m.record_snapshot_fallback();
        m.record_replica_read();
        let s = m.snapshot();
        assert_eq!(s.snapshots_published, 2);
        assert_eq!(s.snapshot_reads, 1);
        assert_eq!(s.snapshot_fallbacks, 1);
        assert_eq!(s.replica_reads, 1);
        assert_eq!(s.snapshot_lag_mean_points, 6.0);
        assert_eq!(s.snapshot_lag_max_points, 8.0);
    }

    #[test]
    fn driver_fd_gauges_surface_in_snapshots() {
        let m = Metrics::new();
        assert!(m.snapshot().driver_fds.is_empty(), "no server registered yet");
        let gauges: Arc<Vec<AtomicU64>> = Arc::new((0..3).map(|_| AtomicU64::new(0)).collect());
        m.register_driver_fds(gauges.clone());
        gauges[0].fetch_add(2, Ordering::Relaxed);
        gauges[2].fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.driver_fds, vec![2, 0, 5]);
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"driver_fds\":[2,0,5]"), "{j}");
        // First registration wins.
        m.register_driver_fds(Arc::new(vec![AtomicU64::new(99)]));
        assert_eq!(m.snapshot().driver_fds, vec![2, 0, 5]);
    }

    #[test]
    fn index_counter_deltas_accumulate() {
        let m = Metrics::new();
        m.record_index_counters(IndexCounters {
            rebuilds: 1,
            incremental_updates: 40,
            fallback_gate_triggers: 2,
            masked_block_rows: 128,
        });
        m.record_index_counters(IndexCounters {
            rebuilds: 0,
            incremental_updates: 2,
            fallback_gate_triggers: 0,
            masked_block_rows: 64,
        });
        let s = m.snapshot();
        assert_eq!(s.index_rebuilds, 1);
        assert_eq!(s.index_incremental_updates, 42);
        assert_eq!(s.fallback_gate_triggers, 2);
        assert_eq!(s.masked_block_rows, 192);
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"index_rebuilds\":1"), "{j}");
        assert!(j.contains("\"masked_block_rows\":192"), "{j}");
        crate::json::parse(&j).unwrap();
    }

    #[test]
    fn snapshot_serializes() {
        let m = Metrics::new();
        m.record_learn(Instant::now());
        let j = m.snapshot().to_json().to_string_compact();
        assert!(j.contains("\"learned\":1"));
        crate::json::parse(&j).unwrap();
    }

    #[test]
    fn histogram_quantiles_bracket_the_samples() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram reads 0");
        // 99 fast samples (~1 µs) and one slow outlier (~16 ms).
        for _ in 0..99 {
            h.record(Duration::from_micros(1));
        }
        h.record(Duration::from_millis(16));
        assert_eq!(h.count(), 100);
        let s = h.summary();
        // Power-of-two buckets: p50/p95 land in the ~1 µs bucket
        // (upper bound ≤ 2 µs), p99… is dominated by bucket bounds but
        // the p100-ish tail must see the outlier.
        assert!(s.p50_s > 0.0 && s.p50_s <= 2.1e-6, "p50 {}", s.p50_s);
        assert!(s.p95_s <= 2.1e-6, "p95 {}", s.p95_s);
        assert!(h.quantile(1.0) >= 0.016, "p100 {}", h.quantile(1.0));
        // Quantiles are monotone in q.
        assert!(s.p50_s <= s.p95_s && s.p95_s <= s.p99_s);
    }

    #[test]
    fn traffic_classes_feed_separate_histograms() {
        let m = Metrics::new();
        m.record_request_latency(TrafficClass::Read, Duration::from_micros(10));
        m.record_request_latency(TrafficClass::Read, Duration::from_micros(10));
        m.record_request_latency(TrafficClass::Write, Duration::from_millis(1));
        m.record_request_latency(TrafficClass::Control, Duration::from_nanos(100));
        m.record_coalesced_batch(32);
        m.record_coalesced_batch(1);
        let s = m.snapshot();
        assert_eq!(s.read_latency.count, 2);
        assert_eq!(s.write_latency.count, 1);
        assert_eq!(s.control_latency.count, 1);
        assert!(s.write_latency.p99_s > s.read_latency.p99_s);
        assert_eq!(s.coalesced_reads, 33);
        assert_eq!(s.coalesced_batches, 2);
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"request_latency\""));
        assert!(j.contains("\"coalesced_reads\":33"));
        crate::json::parse(&j).unwrap();
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    m.record_learn(Instant::now());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().learned, 1000);
    }
}
