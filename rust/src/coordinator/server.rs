//! TCP front end: line-delimited JSON over `std::net`, one thread per
//! connection (adequate for the online-learning use case where a handful
//! of producers stream records; the heavy lifting is already pipelined
//! behind the workers' bounded queues, and heavy read traffic is served
//! from model snapshots by the registry's scorer pool).
//!
//! Lifecycle: connection handler threads are tracked, read with a short
//! timeout so they observe the shutdown flag even while idle, and are
//! joined by [`Server::shutdown`]/`Drop` — once `shutdown()` returns,
//! no handler thread is still touching the registry.

use super::protocol::{Request, Response};
use super::registry::{ModelSpec, Registry};
use super::router::RoutingPolicy;
use super::{CoordError, Result};
use crate::gmm::GmmConfig;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How often an idle connection handler wakes up to check the shutdown
/// flag (the stream's read timeout).
const CONN_POLL: Duration = Duration::from_millis(50);

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7464" (port 0 = ephemeral).
    pub addr: String,
    /// Optional XLA config name to give new models (see WorkerConfig).
    pub xla_config: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), xla_config: None }
    }
}

/// A running server (join on drop).
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Live connection-handler threads, joined on shutdown so no
    /// handler outlives the server (or keeps using the registry).
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the acceptor so it notices the flag.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Join every handler: they observe the flag within one read
        // timeout (CONN_POLL), finish their in-flight request, and exit.
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start serving a registry. Returns once the listener is bound.
pub fn serve(registry: Arc<Registry>, cfg: ServerConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));
    let conns2 = conns.clone();
    let accept_thread = std::thread::Builder::new()
        .name("figmn-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let reg = registry.clone();
                        let flag = flag.clone();
                        let xla = cfg.xla_config.clone();
                        let handle = std::thread::Builder::new()
                            .name("figmn-conn".into())
                            .spawn(move || handle_connection(s, reg, flag, xla))
                            .ok();
                        if let Some(h) = handle {
                            let mut conns = conns2.lock().unwrap();
                            // Reap finished handlers so the vec stays
                            // bounded on long-lived servers.
                            conns.retain(|c| !c.is_finished());
                            conns.push(h);
                        }
                    }
                    Err(_) => break,
                }
            }
        })
        .expect("spawn acceptor");
    Ok(Server { local_addr, shutdown, accept_thread: Some(accept_thread), conns })
}

fn handle_connection(
    stream: TcpStream,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    xla_config: Option<String>,
) {
    let peer = stream.peer_addr().ok();
    // A short read timeout so an idle handler still observes shutdown.
    let _ = stream.set_read_timeout(Some(CONN_POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // `read_line` appends, so a line split across timeout ticks
        // accumulates in `buf` until its newline arrives.
        let at_eof = match reader.read_line(&mut buf) {
            Ok(0) => true,
            Ok(_) => !buf.ends_with('\n'), // EOF mid-line: serve, then stop
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                continue; // idle tick — re-check the shutdown flag
            }
            Err(_) => break,
        };
        let line = std::mem::take(&mut buf);
        if !line.trim().is_empty() {
            let response = match Request::from_line(&line) {
                Err(e) => Response::Error(e.to_string()),
                Ok(req) => {
                    let is_shutdown = req == Request::Shutdown;
                    let resp = dispatch(req, &registry, &xla_config);
                    if is_shutdown {
                        shutdown.store(true, Ordering::SeqCst);
                    }
                    resp
                }
            };
            let mut out = response.to_json().to_string_compact();
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() {
                break;
            }
        }
        if at_eof {
            break;
        }
    }
    log::debug!("connection from {peer:?} closed");
}

/// Argmax class of a score vector (0 for an empty one).
fn argmax(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Map a request onto the registry.
pub fn dispatch(req: Request, registry: &Registry, xla_config: &Option<String>) -> Response {
    match execute(req, registry, xla_config) {
        Ok(resp) => resp,
        Err(e) => Response::Error(e.to_string()),
    }
}

fn execute(req: Request, registry: &Registry, xla_config: &Option<String>) -> Result<Response> {
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Shutdown => Ok(Response::Ok),
        Request::CreateModel {
            model,
            n_features,
            n_classes,
            delta,
            beta,
            stds,
            shards,
            kernel_mode,
            search_mode,
        } => {
            let gmm = GmmConfig::new(1)
                .with_delta(delta)
                .with_beta(beta)
                .with_kernel_mode(kernel_mode)
                .with_search_mode(search_mode);
            let mut spec = ModelSpec::new(&model, n_features, n_classes)
                .with_gmm(gmm)
                .with_stds(stds)
                .with_shards(shards, if shards > 1 { RoutingPolicy::RoundRobin } else { RoutingPolicy::RoundRobin });
            if let Some(x) = xla_config {
                spec = spec.with_xla(x);
            }
            registry.create(spec)?;
            Ok(Response::Ok)
        }
        Request::Learn { model, features, label } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features {
                return Err(CoordError::Protocol(format!(
                    "expected {} features, got {}",
                    spec.n_features,
                    features.len()
                )));
            }
            if label >= spec.n_classes {
                return Err(CoordError::Protocol(format!("label {label} out of range")));
            }
            router.learn(features, label)?;
            Ok(Response::Ok)
        }
        Request::LearnReg { model, features, targets } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features || targets.len() != spec.n_classes {
                return Err(CoordError::Protocol(format!(
                    "expected {} features + {} targets",
                    spec.n_features, spec.n_classes
                )));
            }
            router.learn_reg(features, targets)?;
            Ok(Response::Ok)
        }
        Request::PredictReg { model, features } => {
            let router = registry.router(&model)?;
            Ok(Response::Targets { targets: router.predict_reg(&features)? })
        }
        Request::Predict { model, features } => {
            let router = registry.router(&model)?;
            let scores = router.predict(&features)?;
            let class = argmax(&scores);
            Ok(Response::Scores { scores, class })
        }
        Request::PredictSnapshot { model, features } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features {
                return Err(CoordError::Protocol(format!(
                    "expected {} features, got {}",
                    spec.n_features,
                    features.len()
                )));
            }
            let scores = router.predict_read(&features)?;
            let class = argmax(&scores);
            Ok(Response::Scores { scores, class })
        }
        Request::Score { model, x } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            let dim = spec.n_features + spec.n_classes;
            if x.len() != dim {
                return Err(CoordError::Protocol(format!(
                    "score expects the full joint vector ({dim} dims), got {}",
                    x.len()
                )));
            }
            Ok(Response::Density { density: router.score_read(&x)? })
        }
        Request::ScoreBatch { model, xs } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            let dim = spec.n_features + spec.n_classes;
            if let Some(bad) = xs.iter().find(|x| x.len() != dim) {
                return Err(CoordError::Protocol(format!(
                    "score_batch expects {dim}-dim joint vectors, got {}",
                    bad.len()
                )));
            }
            Ok(Response::Densities { densities: router.score_batch_read(&xs)? })
        }
        Request::PredictBatch { model, xs } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if let Some(bad) = xs.iter().find(|x| x.len() != spec.n_features) {
                return Err(CoordError::Protocol(format!(
                    "predict_batch expects {} features per row, got {}",
                    spec.n_features,
                    bad.len()
                )));
            }
            let scores = router.predict_batch_read(&xs)?;
            let classes = scores.iter().map(|s| argmax(s)).collect();
            Ok(Response::ScoresBatch { scores, classes })
        }
        Request::Stats { model } => Ok(Response::Stats(registry.stats(&model)?)),
        Request::Checkpoint { model } => {
            registry.checkpoint(&model)?;
            Ok(Response::Ok)
        }
        Request::DropModel { model } => {
            registry.drop_model(&model)?;
            Ok(Response::Ok)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::rng::Pcg64;

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (reader, stream)
    }

    fn roundtrip(
        reader: &mut BufReader<TcpStream>,
        writer: &mut TcpStream,
        req: &Request,
    ) -> Response {
        let mut line = req.to_json().to_string_compact();
        line.push('\n');
        writer.write_all(line.as_bytes()).unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Response::from_line(&buf).unwrap()
    }

    #[test]
    fn end_to_end_over_tcp() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Strict,
            search_mode: crate::gmm::SearchMode::Strict,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);

        let mut rng = Pcg64::seed(1);
        for i in 0..120 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            assert_eq!(roundtrip(&mut reader, &mut writer, &req), Response::Ok);
        }

        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { scores, class } => {
                assert_eq!(class, 1);
                assert_eq!(scores.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }

        let resp =
            roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        match resp {
            Response::Stats(j) => {
                assert_eq!(j.get("learned").unwrap().as_usize(), Some(120));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Errors surface as protocol errors, not dropped connections.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "ghost".into(), features: vec![0.0, 0.0] },
        );
        assert!(matches!(resp, Response::Error(_)));

        server.shutdown();
    }

    #[test]
    fn read_ops_over_tcp() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry.clone(), ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Fast,
            search_mode: crate::gmm::SearchMode::TopC { c: 8 },
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);
        let mut rng = Pcg64::seed(4);
        for i in 0..64 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            assert_eq!(roundtrip(&mut reader, &mut writer, &req), Response::Ok);
        }
        // Drain the worker queue, then wait for the snapshot to catch up
        // (64 is a multiple of the default interval, but the idle
        // republish makes this robust regardless).
        let _ = roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        let router = registry.router("m").unwrap();
        router.shards()[0]
            .wait_snapshot_points(64, 1000)
            .expect("snapshot never published");

        // Snapshot-served single predict.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::PredictSnapshot { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { class, scores } => {
                assert_eq!(class, 1);
                assert_eq!(scores.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Batched class scores.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::PredictBatch {
                model: "m".into(),
                xs: vec![vec![6.0, 0.0], vec![0.0, 0.0]],
            },
        );
        match resp {
            Response::ScoresBatch { scores, classes } => {
                assert_eq!(scores.len(), 2);
                assert_eq!(classes, vec![1, 0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Joint densities (full joint vector: features + one-hot block).
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Score { model: "m".into(), x: vec![6.0, 0.0, 0.0, 1.0] },
        );
        match resp {
            Response::Density { density } => assert!(density.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::ScoreBatch {
                model: "m".into(),
                xs: vec![vec![6.0, 0.0, 0.0, 1.0], vec![0.0, 0.0, 1.0, 0.0]],
            },
        );
        match resp {
            Response::Densities { densities } => {
                assert_eq!(densities.len(), 2);
                assert!(densities.iter().all(|d| d.is_finite()));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Wrong arity on the read class is a protocol error.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Score { model: "m".into(), x: vec![6.0, 0.0] },
        );
        assert!(matches!(resp, Response::Error(_)));
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_connection_handlers() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry.clone(), ServerConfig::default()).unwrap();
        // Two connections: one active (did a roundtrip), one idle that
        // never sends anything — both must be joined by shutdown().
        let (mut reader, mut writer) = client(server.local_addr);
        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);
        let _idle = TcpStream::connect(server.local_addr).unwrap();
        // Give the acceptor a beat to register both handlers.
        std::thread::sleep(Duration::from_millis(20));
        server.shutdown();
        // Handlers joined ⇒ every registry clone they held is gone.
        assert_eq!(Arc::strong_count(&registry), 1, "a handler outlived shutdown");
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);
        writer.write_all(b"this is not json\n").unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        assert!(matches!(Response::from_line(&buf).unwrap(), Response::Error(_)));
        server.shutdown();
    }
}
