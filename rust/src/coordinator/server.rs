//! TCP front end: line-delimited JSON over `std::net`, one thread per
//! connection (adequate for the online-learning use case where a handful
//! of producers stream records; the heavy lifting is already pipelined
//! behind the workers' bounded queues).

use super::protocol::{Request, Response};
use super::registry::{ModelSpec, Registry};
use super::router::RoutingPolicy;
use super::{CoordError, Result};
use crate::gmm::GmmConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7464" (port 0 = ephemeral).
    pub addr: String,
    /// Optional XLA config name to give new models (see WorkerConfig).
    pub xla_config: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), xla_config: None }
    }
}

/// A running server (join on drop).
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the acceptor so it notices the flag.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start serving a registry. Returns once the listener is bound.
pub fn serve(registry: Arc<Registry>, cfg: ServerConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let accept_thread = std::thread::Builder::new()
        .name("figmn-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let reg = registry.clone();
                        let flag = flag.clone();
                        let xla = cfg.xla_config.clone();
                        std::thread::Builder::new()
                            .name("figmn-conn".into())
                            .spawn(move || handle_connection(s, reg, flag, xla))
                            .ok();
                    }
                    Err(_) => break,
                }
            }
        })
        .expect("spawn acceptor");
    Ok(Server { local_addr, shutdown, accept_thread: Some(accept_thread) })
}

fn handle_connection(
    stream: TcpStream,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    xla_config: Option<String>,
) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::from_line(&line) {
            Err(e) => Response::Error(e.to_string()),
            Ok(req) => {
                let is_shutdown = req == Request::Shutdown;
                let resp = dispatch(req, &registry, &xla_config);
                if is_shutdown {
                    shutdown.store(true, Ordering::SeqCst);
                }
                resp
            }
        };
        let mut out = response.to_json().to_string_compact();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    log::debug!("connection from {peer:?} closed");
}

/// Map a request onto the registry.
pub fn dispatch(req: Request, registry: &Registry, xla_config: &Option<String>) -> Response {
    match execute(req, registry, xla_config) {
        Ok(resp) => resp,
        Err(e) => Response::Error(e.to_string()),
    }
}

fn execute(req: Request, registry: &Registry, xla_config: &Option<String>) -> Result<Response> {
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Shutdown => Ok(Response::Ok),
        Request::CreateModel { model, n_features, n_classes, delta, beta, stds, shards } => {
            let gmm = GmmConfig::new(1).with_delta(delta).with_beta(beta);
            let mut spec = ModelSpec::new(&model, n_features, n_classes)
                .with_gmm(gmm)
                .with_stds(stds)
                .with_shards(shards, if shards > 1 { RoutingPolicy::RoundRobin } else { RoutingPolicy::RoundRobin });
            if let Some(x) = xla_config {
                spec = spec.with_xla(x);
            }
            registry.create(spec)?;
            Ok(Response::Ok)
        }
        Request::Learn { model, features, label } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features {
                return Err(CoordError::Protocol(format!(
                    "expected {} features, got {}",
                    spec.n_features,
                    features.len()
                )));
            }
            if label >= spec.n_classes {
                return Err(CoordError::Protocol(format!("label {label} out of range")));
            }
            router.learn(features, label)?;
            Ok(Response::Ok)
        }
        Request::LearnReg { model, features, targets } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features || targets.len() != spec.n_classes {
                return Err(CoordError::Protocol(format!(
                    "expected {} features + {} targets",
                    spec.n_features, spec.n_classes
                )));
            }
            router.learn_reg(features, targets)?;
            Ok(Response::Ok)
        }
        Request::PredictReg { model, features } => {
            let router = registry.router(&model)?;
            Ok(Response::Targets { targets: router.predict_reg(&features)? })
        }
        Request::Predict { model, features } => {
            let router = registry.router(&model)?;
            let scores = router.predict(&features)?;
            let class = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            Ok(Response::Scores { scores, class })
        }
        Request::Stats { model } => Ok(Response::Stats(registry.stats(&model)?)),
        Request::Checkpoint { model } => {
            registry.checkpoint(&model)?;
            Ok(Response::Ok)
        }
        Request::DropModel { model } => {
            registry.drop_model(&model)?;
            Ok(Response::Ok)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::rng::Pcg64;

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (reader, stream)
    }

    fn roundtrip(
        reader: &mut BufReader<TcpStream>,
        writer: &mut TcpStream,
        req: &Request,
    ) -> Response {
        let mut line = req.to_json().to_string_compact();
        line.push('\n');
        writer.write_all(line.as_bytes()).unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Response::from_line(&buf).unwrap()
    }

    #[test]
    fn end_to_end_over_tcp() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);

        let mut rng = Pcg64::seed(1);
        for i in 0..120 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            assert_eq!(roundtrip(&mut reader, &mut writer, &req), Response::Ok);
        }

        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { scores, class } => {
                assert_eq!(class, 1);
                assert_eq!(scores.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }

        let resp =
            roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        match resp {
            Response::Stats(j) => {
                assert_eq!(j.get("learned").unwrap().as_usize(), Some(120));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Errors surface as protocol errors, not dropped connections.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "ghost".into(), features: vec![0.0, 0.0] },
        );
        assert!(matches!(resp, Response::Error(_)));

        server.shutdown();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);
        writer.write_all(b"this is not json\n").unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        assert!(matches!(Response::from_line(&buf).unwrap(), Response::Error(_)));
        server.shutdown();
    }
}
