//! TCP front end: line-delimited JSON over `std::net`, served by a
//! readiness-driven multiplexed event loop.
//!
//! A small fixed pool of **connection-driver threads** (default
//! `cores/2` clamped to `[1, 4]`, see [`ServerConfig::drivers`]) each
//! owns many nonblocking sockets. A driver sleeps in `poll(2)` until a
//! socket has bytes, a write buffer drains, or its wake pair fires — no
//! timeout-based busy wakeups, so thousands of idle connections cost
//! zero CPU. Incoming bytes run through a bounded incremental
//! [`LineFramer`] (cap: [`ServerConfig::max_line_bytes`]; an oversized
//! line gets a protocol-error `Response` and the connection resyncs at
//! its newline), parsed requests dispatch onto the registry, and
//! responses queue per connection in **request order** regardless of
//! completion order. Fresh connections are dealt to whichever driver
//! currently owns the fewest live sockets (shared per-driver gauges,
//! charged at deal time and released on drop; surfaced as
//! `driver_fds` in the metrics snapshot) — plain rotation drifts under
//! mixed long-lived/short-lived clients.
//!
//! ## Read coalescing
//!
//! When [`ServerConfig::coalesce`] is on (default), single-query
//! snapshot reads (`score`, `predict`-from-snapshot) are not dispatched
//! one by one: each driver runs a size-or-deadline [`Batcher`] per
//! `(model, op)` and flushes whole blocks into the router's *batched*
//! read surfaces (`score_batch_read` / `predict_batch_read`), which
//! stream each packed component row once per 32-query block instead of
//! once per query. The PR 5 blocked kernels are bit-identical to
//! per-point scoring, the router's merge arithmetic is per-element
//! identical, and validation error strings are mirrored exactly — so
//! **every coalesced response is byte-identical to what per-request
//! dispatch would have produced**. Latency contract: coalescing adds at
//! most `BatcherConfig::max_delay` (default 2 ms) to a lone read; a
//! full block flushes immediately.
//!
//! ## Learn coalescing
//!
//! Writes get the same treatment: when `coalesce` is on and a model was
//! created with `learn_mode: minibatch:B`, consecutive single-point
//! `learn` requests for it are parked in a per-model [`Batcher`] and
//! flushed as one `learn_batch` block — the staged mini-batch pipeline
//! then scores the block through the PR 5 batched kernels instead of
//! point-by-point. `MiniBatch{b=1}` models apply coalesced blocks one
//! point at a time (the pipeline's own contract), and Online models are
//! never parked at all, so with coalescing off — or `b=1` — every
//! response and every model state is byte-identical to per-request
//! dispatch. Latency contract matches reads: at most
//! `BatcherConfig::max_delay` added to a lone learn.
//!
//! Ordering: coalescing only ever groups *consecutive* coalescable
//! requests of the same kind. Any other request on a driver (create,
//! drop, stats, ping, a read while learns are parked, a learn while
//! reads are parked, …) first flushes every pending batch on that
//! driver, so the registry observes effects in exactly the order a
//! sequential per-request loop would have produced — at most one kind
//! of batch (reads or learns) is ever pending at a time.
//!
//! ## Lifecycle
//!
//! Shutdown is race-free for any bind address: each driver owns a
//! loopback [`WakePair`] and [`Server::shutdown`] sets the flag, wakes
//! every driver, and joins them — once `shutdown()` returns, no driver
//! thread is still touching the registry. (The previous
//! thread-per-connection server poked `TcpStream::connect(local_addr)`
//! at the serving socket, which is not connectable-as-advertised when
//! bound to `0.0.0.0`.) Pending coalesced reads are answered and write
//! buffers get a short bounded drain before the sockets close.

use super::batcher::{Batcher, BatcherConfig};
use super::framing::{Frame, LineFramer, DEFAULT_MAX_LINE_BYTES};
use super::metrics::{Metrics, TrafficClass};
use super::poller::{poll_fds, PollFd, WakeHandle, WakePair, POLLIN, POLLOUT};
use super::protocol::{Request, Response};
use super::registry::{ModelSpec, Registry};
use super::router::RoutingPolicy;
use super::{CoordError, Result};
use crate::gmm::{GmmConfig, ReplicaMode};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-connection write-buffer high-water mark: above this backlog the
/// driver stops reading from the connection (natural backpressure on a
/// client that pipelines faster than it drains responses).
const OUTBUF_HIGH_WATER: usize = 4 << 20;

/// How long shutdown keeps pumping partially written responses before
/// closing sockets anyway.
const SHUTDOWN_DRAIN: Duration = Duration::from_millis(250);

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7464" (port 0 = ephemeral).
    pub addr: String,
    /// Optional XLA config name to give new models (see WorkerConfig).
    pub xla_config: Option<String>,
    /// Connection-driver threads (0 = auto: `cores/2` clamped to [1,4]).
    pub drivers: usize,
    /// Per-connection request-line cap; longer lines get a protocol
    /// error and are discarded to their newline.
    pub max_line_bytes: usize,
    /// Coalesce single-query snapshot reads into blocked batch reads.
    pub coalesce: bool,
    /// Size-or-deadline policy for coalesced reads (per driver, per
    /// model+op).
    pub batch: BatcherConfig,
    /// Default [`ReplicaMode`] for `create_model` requests that omit
    /// the `replica_mode` field (a client that sets it explicitly —
    /// including `"off"` — always wins).
    pub replica_mode: ReplicaMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            xla_config: None,
            drivers: 0,
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            coalesce: true,
            batch: BatcherConfig::default(),
            replica_mode: ReplicaMode::Off,
        }
    }
}

fn auto_drivers() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).clamp(1, 4))
        .unwrap_or(1)
}

/// A running server (join on drop).
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    drivers: Vec<std::thread::JoinHandle<()>>,
    wakes: Vec<WakeHandle>,
}

impl Server {
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// True once a client's `shutdown` request (or [`Server::shutdown`])
    /// has been observed — lets an embedding process park without
    /// polling the socket.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.wakes {
            w.wake();
        }
        // Join every driver: once this returns, no thread spawned by
        // `serve` is still touching the registry.
        for t in self.drivers.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start serving a registry. Returns once the listener is bound.
pub fn serve(registry: Arc<Registry>, cfg: ServerConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let n = if cfg.drivers == 0 { auto_drivers() } else { cfg.drivers };
    let mut pairs = Vec::with_capacity(n);
    for _ in 0..n {
        pairs.push(WakePair::new()?);
    }
    let wakes: Vec<WakeHandle> = pairs.iter().map(|p| p.handle()).collect();
    let inboxes: Vec<Arc<Mutex<Vec<TcpStream>>>> =
        (0..n).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
    // Live-connection gauge per driver, shared by the accept-time
    // balancer and (via the metrics hub) the stats surface.
    let fd_counts: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    registry.metrics().register_driver_fds(fd_counts.clone());
    let mut drivers = Vec::with_capacity(n);
    let mut listener = Some(listener);
    for (id, wake) in pairs.into_iter().enumerate() {
        let driver = Driver {
            id,
            registry: registry.clone(),
            metrics: registry.metrics().clone(),
            xla_config: cfg.xla_config.clone(),
            default_replica: cfg.replica_mode,
            shutdown: shutdown.clone(),
            wake,
            inbox: inboxes[id].clone(),
            inboxes: inboxes.clone(),
            wakes: wakes.clone(),
            // Driver 0 owns the accept path; new connections are dealt
            // to whichever driver currently owns the fewest live
            // sockets, through the inboxes.
            listener: listener.take(),
            fd_counts: fd_counts.clone(),
            max_line: cfg.max_line_bytes.max(1),
            coalesce: cfg.coalesce,
            batch_cfg: cfg.batch,
            conns: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            batchers: HashMap::new(),
            learn_batchers: HashMap::new(),
        };
        drivers.push(
            std::thread::Builder::new()
                .name(format!("figmn-driver-{id}"))
                .spawn(move || driver.run())
                .expect("spawn driver"),
        );
    }
    Ok(Server { local_addr, shutdown, drivers, wakes })
}

/// One multiplexed connection: socket, framer, ordered response slots,
/// write buffer.
struct Conn {
    stream: TcpStream,
    /// Generation of this token at registration — guards stale
    /// [`SlotRef`]s after the token is reused.
    gen: u64,
    framer: LineFramer,
    /// Response slots in request order; `None` = still in flight
    /// (e.g. waiting in a coalescing batcher). Responses are written out
    /// strictly front-to-back, so pipelined clients always see answers
    /// in the order they asked.
    slots: VecDeque<Option<String>>,
    /// Sequence number of `slots.front()`.
    first_seq: u64,
    /// Sequence number the next request will get.
    next_seq: u64,
    out: Vec<u8>,
    out_pos: usize,
    /// Peer sent EOF (or `shutdown`): serve what's pending, drain, close.
    closing: bool,
}

/// Stable handle to one response slot (survives the connection dying —
/// a fill for a dropped or reused token is a silent no-op).
#[derive(Clone, Copy)]
struct SlotRef {
    token: usize,
    gen: u64,
    seq: u64,
}

/// A single-query snapshot read parked in a coalescing batcher.
struct PendingRead {
    at: SlotRef,
    x: Vec<f64>,
    queued_at: Instant,
}

/// A single-point `learn` parked in a coalescing batcher (mini-batch
/// models only).
struct PendingLearn {
    at: SlotRef,
    features: Vec<f64>,
    label: usize,
    queued_at: Instant,
}

/// Which blocked read surface a batcher feeds.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum CoalOp {
    /// `score` → `Router::score_batch_read`.
    Score,
    /// snapshot `predict` → `Router::predict_batch_read`.
    Predict,
}

#[derive(Clone, Copy)]
enum FdKind {
    Wake,
    Listener,
    Conn(usize),
}

struct Driver {
    id: usize,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    xla_config: Option<String>,
    /// Server default for `create_model` requests without an explicit
    /// `replica_mode`.
    default_replica: ReplicaMode,
    shutdown: Arc<AtomicBool>,
    wake: WakePair,
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    inboxes: Vec<Arc<Mutex<Vec<TcpStream>>>>,
    wakes: Vec<WakeHandle>,
    listener: Option<TcpListener>,
    /// Live-connection gauge per driver (shared across the pool): a
    /// connection is charged to its driver when dealt and released when
    /// dropped, so the accept path can deal to the least-loaded driver
    /// instead of blindly rotating.
    fd_counts: Arc<Vec<AtomicU64>>,
    max_line: usize,
    coalesce: bool,
    batch_cfg: BatcherConfig,
    /// Token-indexed connections (`None` = free slot).
    conns: Vec<Option<Conn>>,
    /// Per-token generation counters (bumped on close).
    gens: Vec<u64>,
    free: Vec<usize>,
    /// One size-or-deadline batcher per (model, op) with anything
    /// pending.
    batchers: HashMap<(String, CoalOp), Batcher<PendingRead>>,
    /// One size-or-deadline batcher per mini-batch model with learns
    /// pending (mutually exclusive with `batchers` being non-empty —
    /// each kind barrier-flushes the other).
    learn_batchers: HashMap<String, Batcher<PendingLearn>>,
}

impl Driver {
    fn run(mut self) {
        let mut fds: Vec<PollFd> = Vec::new();
        let mut kinds: Vec<FdKind> = Vec::new();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            fds.clear();
            kinds.clear();
            fds.push(PollFd::new(self.wake.reader_fd(), POLLIN));
            kinds.push(FdKind::Wake);
            if let Some(l) = &self.listener {
                fds.push(PollFd::new(l.as_raw_fd(), POLLIN));
                kinds.push(FdKind::Listener);
            }
            for (token, slot) in self.conns.iter().enumerate() {
                let Some(c) = slot else { continue };
                let backlog = c.out.len() - c.out_pos;
                let mut ev = 0i16;
                if !c.closing && backlog < OUTBUF_HIGH_WATER {
                    ev |= POLLIN;
                }
                if backlog > 0 {
                    ev |= POLLOUT;
                }
                if ev == 0 {
                    continue;
                }
                fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
                kinds.push(FdKind::Conn(token));
            }
            // Sleep until readiness — or the oldest pending coalesced
            // read's deadline, whichever comes first. With no pending
            // batches this blocks indefinitely (wakeups come via the
            // wake pair): zero idle CPU.
            let timeout = self.poll_timeout_ms();
            if poll_fds(&mut fds, timeout).is_err() {
                break;
            }
            for i in 0..fds.len() {
                match kinds[i] {
                    FdKind::Wake => {
                        if fds[i].readable() {
                            self.wake.drain();
                        }
                    }
                    FdKind::Listener => {
                        if fds[i].readable() {
                            self.accept_ready();
                        }
                    }
                    FdKind::Conn(token) => {
                        if fds[i].invalid() {
                            self.drop_conn(token);
                            continue;
                        }
                        if fds[i].writable() {
                            self.pump(token);
                        }
                        if fds[i].readable() {
                            self.read_conn(token);
                        }
                    }
                }
            }
            self.take_inbox();
            self.poll_batchers();
            for token in 0..self.conns.len() {
                self.pump(token);
            }
        }
        // Shutdown: answer every parked read, then briefly drain write
        // buffers so clients get their in-flight responses.
        self.flush_all_batchers();
        let deadline = Instant::now() + SHUTDOWN_DRAIN;
        loop {
            for token in 0..self.conns.len() {
                self.pump(token);
            }
            let backlog =
                self.conns.iter().flatten().any(|c| c.out_pos < c.out.len());
            if !backlog || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        log::debug!("driver {} exiting", self.id);
    }

    /// Milliseconds until the oldest coalescing deadline (-1 = sleep
    /// until readiness).
    fn poll_timeout_ms(&self) -> i32 {
        let mut best: Option<Duration> = None;
        let deadlines = self
            .batchers
            .values()
            .filter_map(Batcher::time_to_deadline)
            .chain(self.learn_batchers.values().filter_map(Batcher::time_to_deadline));
        for d in deadlines {
            best = Some(match best {
                Some(cur) if cur <= d => cur,
                _ => d,
            });
        }
        match best {
            // Round up so we never wake *before* the deadline and spin.
            Some(d) => ((d.as_nanos() + 999_999) / 1_000_000).min(1_000) as i32,
            None => -1,
        }
    }

    fn accept_ready(&mut self) {
        let Some(listener) = self.listener.take() else { return };
        loop {
            match listener.accept() {
                Ok((s, _)) => self.place(s),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break, // transient (EMFILE etc.) — retry on next readiness
            }
        }
        self.listener = Some(listener);
    }

    /// Deal a fresh connection to the driver with the fewest live
    /// sockets (ties break toward the lowest id, so a single-driver
    /// pool and an all-idle pool behave deterministically). Plain
    /// round-robin drifts badly under mixed workloads: long-lived
    /// streaming clients pile up on whichever drivers happened to be
    /// next in rotation while short-lived probes churn the others.
    fn place(&mut self, s: TcpStream) {
        let target = self
            .fd_counts
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| c.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(self.id);
        // Charge the connection at deal time, not at registration —
        // otherwise a burst accepted in one poll round would see stale
        // zeros and land on a single driver.
        self.fd_counts[target].fetch_add(1, Ordering::Relaxed);
        if target == self.id {
            self.register(s);
        } else {
            self.inboxes[target].lock().unwrap().push(s);
            self.wakes[target].wake();
        }
    }

    /// Adopt connections other drivers dealt to us.
    fn take_inbox(&mut self) {
        let handed: Vec<TcpStream> = std::mem::take(&mut *self.inbox.lock().unwrap());
        for s in handed {
            self.register(s);
        }
    }

    fn register(&mut self, s: TcpStream) {
        if s.set_nonblocking(true).is_err() {
            // The connection was charged to this driver at deal time;
            // release it since it never registers.
            self.fd_counts[self.id].fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let _ = s.set_nodelay(true);
        let token = match self.free.pop() {
            Some(t) => t,
            None => {
                self.conns.push(None);
                self.gens.push(0);
                self.conns.len() - 1
            }
        };
        self.conns[token] = Some(Conn {
            stream: s,
            gen: self.gens[token],
            framer: LineFramer::new(self.max_line),
            slots: VecDeque::new(),
            first_seq: 0,
            next_seq: 0,
            out: Vec::new(),
            out_pos: 0,
            closing: false,
        });
    }

    fn drop_conn(&mut self, token: usize) {
        if self.conns[token].take().is_some() {
            // Invalidate any SlotRef still parked in a batcher.
            self.gens[token] = self.gens[token].wrapping_add(1);
            self.free.push(token);
            self.fd_counts[self.id].fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Drain every byte the socket has ready through the framer, then
    /// handle the completed frames.
    fn read_conn(&mut self, token: usize) {
        let mut frames = Vec::new();
        let mut dead = false;
        {
            let Some(c) = self.conns.get_mut(token).and_then(|s| s.as_mut()) else {
                return;
            };
            let mut chunk = [0u8; 16 * 1024];
            loop {
                match c.stream.read(&mut chunk) {
                    Ok(0) => {
                        // EOF mid-line: serve the truncated request,
                        // then close once everything pending is written
                        // (legacy server behavior).
                        if let Some(f) = c.framer.finish() {
                            frames.push(f);
                        }
                        c.closing = true;
                        break;
                    }
                    Ok(n) => c.framer.feed(&chunk[..n], &mut frames),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
        if dead {
            self.drop_conn(token);
            return;
        }
        for f in frames {
            self.handle_frame(token, f);
        }
    }

    fn handle_frame(&mut self, token: usize, frame: Frame) {
        match frame {
            Frame::Oversized => {
                let started = Instant::now();
                let Some(at) = self.push_slot(token) else { return };
                let resp = Response::Error(format!(
                    "protocol: request line exceeds {} bytes",
                    self.max_line
                ));
                self.finish_slot(at, resp, TrafficClass::Control, started);
            }
            Frame::Line(line) => {
                // Blank lines are skipped without a reply (legacy
                // behavior).
                if line.trim().is_empty() {
                    return;
                }
                self.handle_line(token, line);
            }
        }
    }

    fn handle_line(&mut self, token: usize, line: String) {
        let started = Instant::now();
        let req = match Request::from_line(&line) {
            Ok(r) => r,
            Err(e) => {
                if let Some(at) = self.push_slot(token) {
                    self.finish_slot(
                        at,
                        Response::Error(e.to_string()),
                        TrafficClass::Control,
                        started,
                    );
                }
                return;
            }
        };
        let class = req.traffic_class();
        let Some(at) = self.push_slot(token) else { return };
        if self.coalesce {
            match req {
                Request::Score { model, x } => {
                    self.flush_learn_batchers();
                    let item = PendingRead { at, x, queued_at: started };
                    self.enqueue_read(model, CoalOp::Score, item);
                    return;
                }
                Request::PredictSnapshot { model, features } => {
                    self.flush_learn_batchers();
                    let item = PendingRead { at, x: features, queued_at: started };
                    self.enqueue_read(model, CoalOp::Predict, item);
                    return;
                }
                Request::Learn { model, features, label }
                    if self.learn_coalescable(&model) =>
                {
                    self.flush_read_batchers();
                    let item = PendingLearn { at, features, label, queued_at: started };
                    self.enqueue_learn(model, item);
                    return;
                }
                other => return self.dispatch_inline(other, at, class, started),
            }
        }
        self.dispatch_inline(req, at, class, started)
    }

    fn dispatch_inline(
        &mut self,
        req: Request,
        at: SlotRef,
        class: TrafficClass,
        started: Instant,
    ) {
        // Barrier: a non-coalescable op must observe (and be observed
        // by) every read already queued on this driver — flushing first
        // keeps effect order identical to a sequential per-request
        // loop. (Coalescing therefore only ever groups *consecutive*
        // coalescable reads.)
        self.flush_all_batchers();
        let is_shutdown = req == Request::Shutdown;
        let req = req.with_default_replica_mode(self.default_replica);
        let resp = dispatch(req, &self.registry, &self.xla_config);
        self.finish_slot(at, resp, class, started);
        if is_shutdown {
            self.shutdown.store(true, Ordering::SeqCst);
            for w in &self.wakes {
                w.wake();
            }
            if let Some(c) = self.conns.get_mut(at.token).and_then(|s| s.as_mut()) {
                if c.gen == at.gen {
                    c.closing = true;
                }
            }
        }
    }

    /// Whether `learn` traffic for this model should be parked and
    /// block-flushed: only models created with a mini-batch learn mode
    /// opted into block semantics — Online models (and unknown names)
    /// dispatch inline, unchanged.
    fn learn_coalescable(&self, model: &str) -> bool {
        self.registry
            .spec(model)
            .map(|s| matches!(s.gmm.learn_mode, crate::gmm::LearnMode::MiniBatch { .. }))
            .unwrap_or(false)
    }

    fn enqueue_learn(&mut self, model: String, item: PendingLearn) {
        let cfg = self.batch_cfg;
        let full = self
            .learn_batchers
            .entry(model.clone())
            .or_insert_with(|| Batcher::new(cfg))
            .push(item);
        if let Some(batch) = full {
            self.execute_learn_batch(&model, batch.items);
        }
    }

    fn execute_learn_batch(&mut self, model: &str, items: Vec<PendingLearn>) {
        let responses = coalesced_learn_responses(&self.registry, model, &items);
        debug_assert_eq!(responses.len(), items.len());
        for (item, resp) in items.into_iter().zip(responses) {
            self.finish_slot(item.at, resp, TrafficClass::Write, item.queued_at);
        }
    }

    fn enqueue_read(&mut self, model: String, op: CoalOp, item: PendingRead) {
        let cfg = self.batch_cfg;
        let full = self
            .batchers
            .entry((model.clone(), op))
            .or_insert_with(|| Batcher::new(cfg))
            .push(item);
        if let Some(batch) = full {
            self.execute_batch(&model, op, batch.items);
        }
    }

    /// Flush every batcher whose deadline has passed.
    fn poll_batchers(&mut self) {
        if !self.batchers.is_empty() {
            let mut due = Vec::new();
            for ((model, op), b) in self.batchers.iter_mut() {
                if let Some(batch) = b.poll() {
                    due.push((model.clone(), *op, batch.items));
                }
            }
            self.batchers.retain(|_, b| b.pending() > 0);
            for (model, op, items) in due {
                self.execute_batch(&model, op, items);
            }
        }
        if !self.learn_batchers.is_empty() {
            let mut due = Vec::new();
            for (model, b) in self.learn_batchers.iter_mut() {
                if let Some(batch) = b.poll() {
                    due.push((model.clone(), batch.items));
                }
            }
            self.learn_batchers.retain(|_, b| b.pending() > 0);
            for (model, items) in due {
                self.execute_learn_batch(&model, items);
            }
        }
    }

    /// Unconditional flush of parked reads (barrier before learns and
    /// inline ops; shutdown).
    fn flush_read_batchers(&mut self) {
        if self.batchers.is_empty() {
            return;
        }
        let mut due = Vec::new();
        for ((model, op), b) in self.batchers.iter_mut() {
            if let Some(batch) = b.flush() {
                due.push((model.clone(), *op, batch.items));
            }
        }
        self.batchers.clear();
        for (model, op, items) in due {
            self.execute_batch(&model, op, items);
        }
    }

    /// Unconditional flush of parked learns (barrier before reads and
    /// inline ops; shutdown).
    fn flush_learn_batchers(&mut self) {
        if self.learn_batchers.is_empty() {
            return;
        }
        let mut due = Vec::new();
        for (model, b) in self.learn_batchers.iter_mut() {
            if let Some(batch) = b.flush() {
                due.push((model.clone(), batch.items));
            }
        }
        self.learn_batchers.clear();
        for (model, items) in due {
            self.execute_learn_batch(&model, items);
        }
    }

    /// Unconditional flush (barrier before inline ops; shutdown). Learns
    /// first: any parked learns predate the op triggering the barrier,
    /// and at most one kind is pending anyway.
    fn flush_all_batchers(&mut self) {
        self.flush_learn_batchers();
        self.flush_read_batchers();
    }

    fn execute_batch(&mut self, model: &str, op: CoalOp, items: Vec<PendingRead>) {
        self.metrics.record_coalesced_batch(items.len() as u64);
        let responses = coalesced_responses(&self.registry, model, op, &items);
        debug_assert_eq!(responses.len(), items.len());
        for (item, resp) in items.into_iter().zip(responses) {
            self.finish_slot(item.at, resp, TrafficClass::Read, item.queued_at);
        }
    }

    /// Reserve the next in-order response slot for `token`.
    fn push_slot(&mut self, token: usize) -> Option<SlotRef> {
        let c = self.conns.get_mut(token)?.as_mut()?;
        let seq = c.next_seq;
        c.next_seq += 1;
        c.slots.push_back(None);
        Some(SlotRef { token, gen: c.gen, seq })
    }

    /// Record latency and fill the slot (no-op if the connection died
    /// or its token was reused meanwhile).
    fn finish_slot(
        &mut self,
        at: SlotRef,
        resp: Response,
        class: TrafficClass,
        started: Instant,
    ) {
        self.metrics.record_request_latency(class, started.elapsed());
        let Some(c) = self.conns.get_mut(at.token).and_then(|s| s.as_mut()) else {
            return;
        };
        if c.gen != at.gen {
            return;
        }
        let Some(idx) = at.seq.checked_sub(c.first_seq) else { return };
        if let Some(slot) = c.slots.get_mut(idx as usize) {
            let mut line = resp.to_json().to_string_compact();
            line.push('\n');
            *slot = Some(line);
        }
    }

    /// Move completed in-order responses into the write buffer and push
    /// as many bytes as the socket accepts.
    fn pump(&mut self, token: usize) {
        let mut dead = false;
        let done;
        {
            let Some(c) = self.conns.get_mut(token).and_then(|s| s.as_mut()) else {
                return;
            };
            while matches!(c.slots.front(), Some(Some(_))) {
                let line = c.slots.pop_front().flatten().expect("front checked Some");
                c.first_seq += 1;
                c.out.extend_from_slice(line.as_bytes());
            }
            while c.out_pos < c.out.len() {
                match c.stream.write(&c.out[c.out_pos..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => c.out_pos += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            if c.out_pos >= c.out.len() {
                c.out.clear();
                c.out_pos = 0;
            } else if c.out_pos > 64 * 1024 {
                // Reclaim the written prefix of a large backlog.
                c.out.drain(..c.out_pos);
                c.out_pos = 0;
            }
            done = c.closing && c.out.is_empty() && c.slots.is_empty();
        }
        if dead || done {
            self.drop_conn(token);
        }
    }
}

/// Execute one coalesced block against the blocked read surfaces,
/// producing responses **byte-identical** to per-request [`dispatch`]:
/// same lookup order (router before spec, so a dropped model yields the
/// identical "unknown model" text), same per-item validation strings,
/// and the PR 5 batch kernels' bitwise guarantee for the values.
fn coalesced_responses(
    registry: &Registry,
    model: &str,
    op: CoalOp,
    items: &[PendingRead],
) -> Vec<Response> {
    let all = |msg: String| -> Vec<Response> {
        items.iter().map(|_| Response::Error(msg.clone())).collect()
    };
    let router = match registry.router(model) {
        Ok(r) => r,
        Err(e) => return all(e.to_string()),
    };
    let spec = match registry.spec(model) {
        Ok(s) => s,
        Err(e) => return all(e.to_string()),
    };
    let mut responses: Vec<Option<Response>> = match op {
        CoalOp::Score => {
            let dim = spec.n_features + spec.n_classes;
            items
                .iter()
                .map(|it| {
                    (it.x.len() != dim).then(|| {
                        Response::Error(
                            CoordError::Protocol(format!(
                                "score expects the full joint vector ({dim} dims), got {}",
                                it.x.len()
                            ))
                            .to_string(),
                        )
                    })
                })
                .collect()
        }
        CoalOp::Predict => items
            .iter()
            .map(|it| {
                (it.x.len() != spec.n_features).then(|| {
                    Response::Error(
                        CoordError::Protocol(format!(
                            "expected {} features, got {}",
                            spec.n_features,
                            it.x.len()
                        ))
                        .to_string(),
                    )
                })
            })
            .collect(),
    };
    let valid: Vec<usize> = (0..items.len()).filter(|&i| responses[i].is_none()).collect();
    if !valid.is_empty() {
        let xs: Vec<Vec<f64>> = valid.iter().map(|&i| items[i].x.clone()).collect();
        match op {
            CoalOp::Score => match router.score_batch_read(&xs) {
                Ok(ds) => {
                    for (&i, density) in valid.iter().zip(ds) {
                        responses[i] = Some(Response::Density { density });
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for &i in &valid {
                        responses[i] = Some(Response::Error(msg.clone()));
                    }
                }
            },
            CoalOp::Predict => match router.predict_batch_read(&xs) {
                Ok(rows) => {
                    for (&i, scores) in valid.iter().zip(rows) {
                        let class = argmax(&scores);
                        responses[i] = Some(Response::Scores { scores, class });
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for &i in &valid {
                        responses[i] = Some(Response::Error(msg.clone()));
                    }
                }
            },
        }
    }
    responses.into_iter().map(|r| r.expect("every slot filled")).collect()
}

/// Execute one coalesced learn block, producing responses byte-identical
/// to per-request [`dispatch`]: same lookup order (router before spec),
/// same per-item validation strings. Valid rows are forwarded to the
/// router as one `learn_batch`, so a mini-batch shard stages them
/// through the blocked pipeline.
fn coalesced_learn_responses(
    registry: &Registry,
    model: &str,
    items: &[PendingLearn],
) -> Vec<Response> {
    let all = |msg: String| -> Vec<Response> {
        items.iter().map(|_| Response::Error(msg.clone())).collect()
    };
    let router = match registry.router(model) {
        Ok(r) => r,
        Err(e) => return all(e.to_string()),
    };
    let spec = match registry.spec(model) {
        Ok(s) => s,
        Err(e) => return all(e.to_string()),
    };
    let mut responses: Vec<Option<Response>> = items
        .iter()
        .map(|it| {
            if it.features.len() != spec.n_features {
                Some(Response::Error(
                    CoordError::Protocol(format!(
                        "expected {} features, got {}",
                        spec.n_features,
                        it.features.len()
                    ))
                    .to_string(),
                ))
            } else if it.label >= spec.n_classes {
                Some(Response::Error(
                    CoordError::Protocol(format!("label {} out of range", it.label))
                        .to_string(),
                ))
            } else {
                None
            }
        })
        .collect();
    let valid: Vec<usize> = (0..items.len()).filter(|&i| responses[i].is_none()).collect();
    if !valid.is_empty() {
        let xs: Vec<Vec<f64>> = valid.iter().map(|&i| items[i].features.clone()).collect();
        let labels: Vec<usize> = valid.iter().map(|&i| items[i].label).collect();
        match router.learn_batch(xs, labels) {
            Ok(()) => {
                for &i in &valid {
                    responses[i] = Some(Response::Ok);
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for &i in &valid {
                    responses[i] = Some(Response::Error(msg.clone()));
                }
            }
        }
    }
    responses.into_iter().map(|r| r.expect("every slot filled")).collect()
}

/// Argmax class of a score vector (0 for an empty one).
fn argmax(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Map a request onto the registry.
pub fn dispatch(req: Request, registry: &Registry, xla_config: &Option<String>) -> Response {
    match execute(req, registry, xla_config) {
        Ok(resp) => resp,
        Err(e) => Response::Error(e.to_string()),
    }
}

fn execute(req: Request, registry: &Registry, xla_config: &Option<String>) -> Result<Response> {
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Shutdown => Ok(Response::Ok),
        Request::CreateModel {
            model,
            n_features,
            n_classes,
            delta,
            beta,
            stds,
            shards,
            kernel_mode,
            search_mode,
            replica_mode,
            learn_mode,
            decay,
            max_age,
        } => {
            let gmm = GmmConfig::new(1)
                .with_delta(delta)
                .with_beta(beta)
                .with_kernel_mode(kernel_mode)
                .with_search_mode(search_mode)
                .with_replica_mode(replica_mode.unwrap_or(ReplicaMode::Off))
                .with_learn_mode(learn_mode)
                .with_decay(decay)
                .with_max_age(max_age);
            let mut spec = ModelSpec::new(&model, n_features, n_classes)
                .with_gmm(gmm)
                .with_stds(stds)
                .with_shards(shards, if shards > 1 { RoutingPolicy::RoundRobin } else { RoutingPolicy::RoundRobin });
            if let Some(x) = xla_config {
                spec = spec.with_xla(x);
            }
            registry.create(spec)?;
            Ok(Response::Ok)
        }
        Request::Learn { model, features, label } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features {
                return Err(CoordError::Protocol(format!(
                    "expected {} features, got {}",
                    spec.n_features,
                    features.len()
                )));
            }
            if label >= spec.n_classes {
                return Err(CoordError::Protocol(format!("label {label} out of range")));
            }
            router.learn(features, label)?;
            Ok(Response::Ok)
        }
        Request::LearnBatch { model, xs, labels } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if let Some(bad) = xs.iter().find(|x| x.len() != spec.n_features) {
                return Err(CoordError::Protocol(format!(
                    "learn_batch expects {}-dim rows, got {}",
                    spec.n_features,
                    bad.len()
                )));
            }
            if let Some(bad) = labels.iter().find(|&&l| l >= spec.n_classes) {
                return Err(CoordError::Protocol(format!("label {bad} out of range")));
            }
            router.learn_batch(xs, labels)?;
            Ok(Response::Ok)
        }
        Request::LearnReg { model, features, targets } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features || targets.len() != spec.n_classes {
                return Err(CoordError::Protocol(format!(
                    "expected {} features + {} targets",
                    spec.n_features, spec.n_classes
                )));
            }
            router.learn_reg(features, targets)?;
            Ok(Response::Ok)
        }
        Request::PredictReg { model, features } => {
            let router = registry.router(&model)?;
            Ok(Response::Targets { targets: router.predict_reg(&features)? })
        }
        Request::Predict { model, features } => {
            let router = registry.router(&model)?;
            let scores = router.predict(&features)?;
            let class = argmax(&scores);
            Ok(Response::Scores { scores, class })
        }
        Request::PredictSnapshot { model, features } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if features.len() != spec.n_features {
                return Err(CoordError::Protocol(format!(
                    "expected {} features, got {}",
                    spec.n_features,
                    features.len()
                )));
            }
            let scores = router.predict_read(&features)?;
            let class = argmax(&scores);
            Ok(Response::Scores { scores, class })
        }
        Request::Score { model, x } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            let dim = spec.n_features + spec.n_classes;
            if x.len() != dim {
                return Err(CoordError::Protocol(format!(
                    "score expects the full joint vector ({dim} dims), got {}",
                    x.len()
                )));
            }
            Ok(Response::Density { density: router.score_read(&x)? })
        }
        Request::ScoreBatch { model, xs } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            let dim = spec.n_features + spec.n_classes;
            if let Some(bad) = xs.iter().find(|x| x.len() != dim) {
                return Err(CoordError::Protocol(format!(
                    "score_batch expects {dim}-dim joint vectors, got {}",
                    bad.len()
                )));
            }
            Ok(Response::Densities { densities: router.score_batch_read(&xs)? })
        }
        Request::PredictBatch { model, xs } => {
            let router = registry.router(&model)?;
            let spec = registry.spec(&model)?;
            if let Some(bad) = xs.iter().find(|x| x.len() != spec.n_features) {
                return Err(CoordError::Protocol(format!(
                    "predict_batch expects {} features per row, got {}",
                    spec.n_features,
                    bad.len()
                )));
            }
            let scores = router.predict_batch_read(&xs)?;
            let classes = scores.iter().map(|s| argmax(s)).collect();
            Ok(Response::ScoresBatch { scores, classes })
        }
        Request::Stats { model } => Ok(Response::Stats(registry.stats(&model)?)),
        Request::Checkpoint { model } => {
            registry.checkpoint(&model)?;
            Ok(Response::Ok)
        }
        Request::DropModel { model } => {
            registry.drop_model(&model)?;
            Ok(Response::Ok)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::rng::Pcg64;
    use std::io::BufRead;
    use std::io::BufReader;

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (reader, stream)
    }

    fn roundtrip(
        reader: &mut BufReader<TcpStream>,
        writer: &mut TcpStream,
        req: &Request,
    ) -> Response {
        let mut line = req.to_json().to_string_compact();
        line.push('\n');
        writer.write_all(line.as_bytes()).unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Response::from_line(&buf).unwrap()
    }

    #[test]
    fn end_to_end_over_tcp() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Strict,
            search_mode: crate::gmm::SearchMode::Strict,
            replica_mode: None,
            learn_mode: crate::gmm::LearnMode::Online,
            decay: 1.0,
            max_age: 0,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);

        let mut rng = Pcg64::seed(1);
        for i in 0..120 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            assert_eq!(roundtrip(&mut reader, &mut writer, &req), Response::Ok);
        }

        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { scores, class } => {
                assert_eq!(class, 1);
                assert_eq!(scores.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }

        let resp =
            roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        match resp {
            Response::Stats(j) => {
                assert_eq!(j.get("learned").unwrap().as_usize(), Some(120));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Errors surface as protocol errors, not dropped connections.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "ghost".into(), features: vec![0.0, 0.0] },
        );
        assert!(matches!(resp, Response::Error(_)));

        server.shutdown();
    }

    #[test]
    fn read_ops_over_tcp() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry.clone(), ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Fast,
            search_mode: crate::gmm::SearchMode::TopC { c: 8 },
            replica_mode: None,
            learn_mode: crate::gmm::LearnMode::Online,
            decay: 1.0,
            max_age: 0,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);
        let mut rng = Pcg64::seed(4);
        for i in 0..64 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            assert_eq!(roundtrip(&mut reader, &mut writer, &req), Response::Ok);
        }
        // Drain the worker queue, then wait for the snapshot to catch up
        // (64 is a multiple of the default interval, but the idle
        // republish makes this robust regardless).
        let _ = roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        let router = registry.router("m").unwrap();
        router.shards()[0]
            .wait_snapshot_points(64, 1000)
            .expect("snapshot never published");

        // Snapshot-served single predict.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::PredictSnapshot { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { class, scores } => {
                assert_eq!(class, 1);
                assert_eq!(scores.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Batched class scores.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::PredictBatch {
                model: "m".into(),
                xs: vec![vec![6.0, 0.0], vec![0.0, 0.0]],
            },
        );
        match resp {
            Response::ScoresBatch { scores, classes } => {
                assert_eq!(scores.len(), 2);
                assert_eq!(classes, vec![1, 0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Joint densities (full joint vector: features + one-hot block).
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Score { model: "m".into(), x: vec![6.0, 0.0, 0.0, 1.0] },
        );
        match resp {
            Response::Density { density } => assert!(density.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::ScoreBatch {
                model: "m".into(),
                xs: vec![vec![6.0, 0.0, 0.0, 1.0], vec![0.0, 0.0, 1.0, 0.0]],
            },
        );
        match resp {
            Response::Densities { densities } => {
                assert_eq!(densities.len(), 2);
                assert!(densities.iter().all(|d| d.is_finite()));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Wrong arity on the read class is a protocol error.
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Score { model: "m".into(), x: vec![6.0, 0.0] },
        );
        assert!(matches!(resp, Response::Error(_)));
        server.shutdown();
    }

    #[test]
    fn learn_coalescing_stages_minibatch_models() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry.clone(), ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Strict,
            search_mode: crate::gmm::SearchMode::Strict,
            replica_mode: None,
            learn_mode: crate::gmm::LearnMode::MiniBatch { b: 16 },
            decay: 1.0,
            max_age: 0,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);

        // Pipeline every learn line in ONE write so the driver parks
        // consecutive learns into blocks; sequential roundtrips would
        // deadline-flush one-point batches and prove nothing.
        let mut rng = Pcg64::seed(9);
        let mut lines = String::new();
        for i in 0..96 {
            let c = i % 2;
            let req = Request::Learn {
                model: "m".into(),
                features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
                label: c,
            };
            lines.push_str(&req.to_json().to_string_compact());
            lines.push('\n');
        }
        writer.write_all(lines.as_bytes()).unwrap();
        for _ in 0..96 {
            let mut buf = String::new();
            reader.read_line(&mut buf).unwrap();
            assert_eq!(Response::from_line(&buf).unwrap(), Response::Ok);
        }

        // Every point applied, in fewer learn *operations* than points:
        // consecutive learns were coalesced into blocks.
        let resp =
            roundtrip(&mut reader, &mut writer, &Request::Stats { model: "m".into() });
        let stats = match resp {
            Response::Stats(j) => j,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(stats.get("learned").unwrap().as_usize(), Some(96));
        let coord = stats.get("coordinator").unwrap();
        assert_eq!(coord.get("points_learned").unwrap().as_usize(), Some(96));
        let ops = coord.get("learned").unwrap().as_usize().unwrap();
        assert!(ops < 96, "learns were not coalesced: {ops} ops for 96 points");

        // A read issued after the blocks observes the staged learning
        // (the inline dispatch barrier-flushes pending learns first).
        let resp = roundtrip(
            &mut reader,
            &mut writer,
            &Request::Predict { model: "m".into(), features: vec![6.0, 0.0] },
        );
        match resp {
            Response::Scores { class, .. } => assert_eq!(class, 1),
            other => panic!("unexpected {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_connection_handlers() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry.clone(), ServerConfig::default()).unwrap();
        // Two connections: one active (did a roundtrip), one idle that
        // never sends anything — both must be joined by shutdown().
        let (mut reader, mut writer) = client(server.local_addr);
        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);
        let _idle = TcpStream::connect(server.local_addr).unwrap();
        // Give the acceptor a beat to register both handlers.
        std::thread::sleep(Duration::from_millis(20));
        server.shutdown();
        // Handlers joined ⇒ every registry clone they held is gone.
        assert_eq!(Arc::strong_count(&registry), 1, "a handler outlived shutdown");
    }

    #[test]
    fn accept_balancing_tracks_driver_fds() {
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(Registry::new(metrics.clone()));
        let cfg = ServerConfig { drivers: 2, ..ServerConfig::default() };
        let server = serve(registry, cfg).unwrap();

        // Four live connections dealt least-loaded across two drivers
        // must split 2/2 (round-robin would too, but the gauges are
        // what we're really pinning down here).
        let conns: Vec<_> = (0..4).map(|_| client(server.local_addr)).collect();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let fds = metrics.snapshot().driver_fds;
            if fds.len() == 2 && fds.iter().sum::<u64>() == 4 {
                assert_eq!(fds, vec![2, 2], "accept dealing is unbalanced");
                break;
            }
            assert!(Instant::now() < deadline, "gauges never reached 4: {fds:?}");
            std::thread::sleep(Duration::from_millis(5));
        }

        // Dropping the clients must release every gauge.
        drop(conns);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let fds = metrics.snapshot().driver_fds;
            if fds.iter().sum::<u64>() == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "gauges never drained: {fds:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }

    #[test]
    fn server_default_replica_mode_applies_to_create() {
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(Registry::new(metrics.clone()));
        let cfg = ServerConfig {
            replica_mode: crate::gmm::ReplicaMode::f32_default(),
            ..ServerConfig::default()
        };
        let server = serve(registry.clone(), cfg).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);

        // Omitted replica_mode → server default (f32).
        let create = Request::CreateModel {
            model: "m".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Fast,
            search_mode: crate::gmm::SearchMode::Strict,
            replica_mode: None,
            learn_mode: crate::gmm::LearnMode::Online,
            decay: 1.0,
            max_age: 0,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);
        assert_eq!(
            registry.spec("m").unwrap().gmm.replica_mode,
            crate::gmm::ReplicaMode::f32_default()
        );

        // Explicit "off" from the client wins over the server default.
        let create = Request::CreateModel {
            model: "m_off".into(),
            n_features: 2,
            n_classes: 2,
            delta: 0.5,
            beta: 0.05,
            stds: vec![3.0, 3.0],
            shards: 1,
            kernel_mode: crate::linalg::KernelMode::Fast,
            search_mode: crate::gmm::SearchMode::Strict,
            replica_mode: Some(crate::gmm::ReplicaMode::Off),
            learn_mode: crate::gmm::LearnMode::Online,
            decay: 1.0,
            max_age: 0,
        };
        assert_eq!(roundtrip(&mut reader, &mut writer, &create), Response::Ok);
        assert_eq!(registry.spec("m_off").unwrap().gmm.replica_mode, crate::gmm::ReplicaMode::Off);
        server.shutdown();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
        let server = serve(registry, ServerConfig::default()).unwrap();
        let (mut reader, mut writer) = client(server.local_addr);
        writer.write_all(b"this is not json\n").unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        assert!(matches!(Response::from_line(&buf).unwrap(), Response::Error(_)));
        server.shutdown();
    }
}
