//! Model worker: one OS thread owning one model shard.
//!
//! Learning on a mixture is inherently sequential (each point mutates the
//! state the next point scores against), so a shard is a single thread
//! consuming a bounded command queue. Inference requests are micro-
//! batched ([`super::batcher`]); when AOT artifacts are available and the
//! shard's shape matches a manifest config, batched class-scoring runs on
//! the XLA path (the PJRT client is created *inside* the worker thread —
//! it is not `Send`).

use super::backpressure::{BoundedQueue, OverflowPolicy};
use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::{CoordError, Result};
use crate::engine::EngineConfig;
use crate::gmm::{
    Figmn, GmmConfig, IncrementalMixture, IndexCounters, ModelSnapshot, SupervisedGmm,
};
use crate::json::Json;
use crate::runtime::{PackedState, Runtime};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Single-writer / many-reader slot for the worker's published read
/// snapshot. The worker (sole writer) swaps in a fresh
/// `Arc<ModelSnapshot>` every `snapshot_interval` learn steps; readers
/// clone the `Arc` out — the critical section on either side is one
/// pointer copy, so read traffic never queues behind the learn path.
#[derive(Default)]
pub struct SnapshotCell {
    slot: Mutex<Option<Arc<ModelSnapshot>>>,
    publishes: AtomicU64,
}

impl SnapshotCell {
    pub fn new() -> SnapshotCell {
        SnapshotCell::default()
    }

    /// Latest published snapshot (`None` until the first publish).
    pub fn load(&self) -> Option<Arc<ModelSnapshot>> {
        self.slot.lock().unwrap().clone()
    }

    /// Number of publishes so far (tests / staleness accounting).
    pub fn publish_count(&self) -> u64 {
        self.publishes.load(Ordering::Acquire)
    }

    fn store(&self, snap: Arc<ModelSnapshot>) {
        *self.slot.lock().unwrap() = Some(snap);
        self.publishes.fetch_add(1, Ordering::Release);
    }
}

/// Commands accepted by a worker.
pub(crate) enum Command {
    Learn { features: Vec<f64>, label: usize },
    /// A block of labeled examples applied as one unit — a mini-batch
    /// model stages the whole block through the blocked learn pipeline.
    LearnBatch { xs: Vec<Vec<f64>>, labels: Vec<usize> },
    Predict { features: Vec<f64>, reply: mpsc::Sender<Vec<f64>> },
    /// Regression: continuous output block (n_classes doubles as the
    /// output arity).
    LearnReg { features: Vec<f64>, targets: Vec<f64> },
    PredictReg { features: Vec<f64>, reply: mpsc::Sender<Vec<f64>> },
    Stats { reply: mpsc::Sender<WorkerStats> },
    CheckpointJson { reply: mpsc::Sender<Json> },
    Shutdown,
}

/// Worker configuration.
#[derive(Clone)]
pub struct WorkerConfig {
    pub n_features: usize,
    pub n_classes: usize,
    pub gmm: GmmConfig,
    pub feature_stds: Vec<f64>,
    /// Command queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Overflow policy for the command queue.
    pub overflow: OverflowPolicy,
    pub batcher: BatcherConfig,
    /// Use the XLA predict artifact with this config name, if it matches
    /// this worker's shape and `artifacts/manifest.json` exists.
    pub xla_config: Option<String>,
    /// Component-sharded engine for the shard's model: `None` keeps the
    /// learn/score passes serial; `Some` splits the K components across
    /// a fixed thread pool (results are bit-identical either way).
    pub engine: Option<EngineConfig>,
    /// Republish the read-path snapshot every this many **applied
    /// points** (plus once whenever the queue goes idle with
    /// unpublished learns). A `learn_batch` of B points advances the
    /// cadence by B, not 1, so mini-batch traffic does not stretch
    /// staleness B-fold. Read staleness stays
    /// < `snapshot_interval` applied points while the stream flows —
    /// learns still waiting in the command queue add up to
    /// `queue_capacity` on top under backlog. `0` disables snapshot
    /// publishing entirely (write-only workloads skip the `O(K·D²)`
    /// copy per publish).
    pub snapshot_interval: usize,
}

impl WorkerConfig {
    pub fn new(n_features: usize, n_classes: usize, gmm: GmmConfig, feature_stds: Vec<f64>) -> Self {
        WorkerConfig {
            n_features,
            n_classes,
            gmm,
            feature_stds,
            queue_capacity: 1024,
            overflow: OverflowPolicy::Block,
            batcher: BatcherConfig::default(),
            xla_config: None,
            engine: None,
            snapshot_interval: DEFAULT_SNAPSHOT_INTERVAL,
        }
    }

    pub fn with_xla(mut self, config: impl Into<String>) -> Self {
        self.xla_config = Some(config.into());
        self
    }

    /// Attach a component-sharded engine to this shard's model.
    pub fn with_engine(mut self, engine: EngineConfig) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Set the snapshot republish interval (0 disables publishing).
    pub fn with_snapshot_interval(mut self, every: usize) -> Self {
        self.snapshot_interval = every;
        self
    }
}

/// Default points between snapshot republishes — small, so the
/// read path lags the write path by at most a few points.
pub const DEFAULT_SNAPSHOT_INTERVAL: usize = 8;

/// Statistics reported by a worker.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStats {
    pub components: usize,
    pub points: u64,
    pub learned: u64,
    pub predicted: u64,
    pub xla_batches: u64,
    /// Arena payload bytes of this shard's live mixture (packed layout;
    /// see `gmm::ComponentStore::model_bytes`).
    pub model_bytes: usize,
    /// f32 read-replica payload bytes of the latest published snapshot
    /// (0 when the model runs replica-off or nothing is published yet;
    /// see `gmm::ReplicaStore::replica_bytes`).
    pub replica_bytes: usize,
    /// Staleness-triggered full candidate-index rebuilds on this
    /// shard's model (all-zero for Strict-mode shards; see
    /// `gmm::IndexCounters`).
    pub index_rebuilds: u64,
    /// Incremental index-maintenance events (create appends + drift
    /// cell reassignments).
    pub index_incremental_updates: u64,
    /// χ²-fallback gate scans taken on the TopC learn path.
    pub fallback_gate_triggers: u64,
    /// Union rows streamed by the masked TopC blocked distance pass.
    pub masked_block_rows: u64,
}

impl WorkerStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("components", self.components.into()),
            ("points", (self.points as usize).into()),
            ("learned", (self.learned as usize).into()),
            ("predicted", (self.predicted as usize).into()),
            ("xla_batches", (self.xla_batches as usize).into()),
            ("model_bytes", self.model_bytes.into()),
            ("replica_bytes", self.replica_bytes.into()),
            ("index_rebuilds", (self.index_rebuilds as usize).into()),
            (
                "index_incremental_updates",
                (self.index_incremental_updates as usize).into(),
            ),
            ("fallback_gate_triggers", (self.fallback_gate_triggers as usize).into()),
            ("masked_block_rows", (self.masked_block_rows as usize).into()),
        ])
    }
}

/// Handle for submitting work to a running worker.
#[derive(Clone)]
pub struct WorkerHandle {
    queue: Arc<BoundedQueue<Command>>,
    snapshot: Arc<SnapshotCell>,
}

/// A spawned worker (join handle + command handle).
pub struct Worker {
    pub handle: WorkerHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Worker {
    /// Spawn a worker thread.
    pub fn spawn(cfg: WorkerConfig, metrics: Arc<Metrics>) -> Worker {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity, cfg.overflow));
        let snapshot = Arc::new(SnapshotCell::new());
        let q2 = queue.clone();
        let cell = snapshot.clone();
        let thread = std::thread::Builder::new()
            .name("figmn-worker".into())
            .spawn(move || worker_loop(cfg, q2, cell, metrics))
            .expect("spawn worker");
        Worker { handle: WorkerHandle { queue, snapshot }, thread: Some(thread) }
    }

    /// Signal shutdown and join.
    pub fn join(mut self) {
        self.handle.queue.push(Command::Shutdown);
        self.handle.queue.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.handle.queue.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl WorkerHandle {
    /// Enqueue a labeled example. `Err(Rejected)` if shed/closed.
    pub fn learn(&self, features: Vec<f64>, label: usize) -> Result<()> {
        if self.queue.push(Command::Learn { features, label }) {
            Ok(())
        } else {
            Err(CoordError::Rejected("worker queue"))
        }
    }

    /// Enqueue a block of labeled examples as one command. The shard
    /// applies the whole block before serving anything queued after it,
    /// and a mini-batch model runs it through the staged learn pipeline.
    pub fn learn_batch(&self, xs: Vec<Vec<f64>>, labels: Vec<usize>) -> Result<()> {
        if self.queue.push(Command::LearnBatch { xs, labels }) {
            Ok(())
        } else {
            Err(CoordError::Rejected("worker queue"))
        }
    }

    /// Request class scores (blocks for the reply).
    pub fn predict(&self, features: Vec<f64>) -> Result<Vec<f64>> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Command::Predict { features, reply: tx }) {
            return Err(CoordError::Rejected("worker queue"));
        }
        rx.recv().map_err(|_| CoordError::Rejected("worker died"))
    }

    /// Enqueue a regression example (targets in the output block).
    pub fn learn_reg(&self, features: Vec<f64>, targets: Vec<f64>) -> Result<()> {
        if self.queue.push(Command::LearnReg { features, targets }) {
            Ok(())
        } else {
            Err(CoordError::Rejected("worker queue"))
        }
    }

    /// Request reconstructed continuous targets (blocks for the reply).
    pub fn predict_reg(&self, features: Vec<f64>) -> Result<Vec<f64>> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Command::PredictReg { features, reply: tx }) {
            return Err(CoordError::Rejected("worker queue"));
        }
        rx.recv().map_err(|_| CoordError::Rejected("worker died"))
    }

    pub fn stats(&self) -> Result<WorkerStats> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Command::Stats { reply: tx }) {
            return Err(CoordError::Rejected("worker queue"));
        }
        rx.recv().map_err(|_| CoordError::Rejected("worker died"))
    }

    /// Snapshot the model as a JSON checkpoint document.
    pub fn checkpoint_json(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Command::CheckpointJson { reply: tx }) {
            return Err(CoordError::Rejected("worker queue"));
        }
        rx.recv().map_err(|_| CoordError::Rejected("worker died"))
    }

    /// Queue depth (for router load-aware policies and tests).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Latest published read snapshot — loaded directly from the shared
    /// cell, **not** through the command queue, so read traffic never
    /// waits behind queued learns. `None` until the worker has learned
    /// and published at least once (or when publishing is disabled).
    pub fn snapshot(&self) -> Option<Arc<ModelSnapshot>> {
        self.snapshot.load()
    }

    /// Number of snapshots the worker has published so far.
    pub fn snapshot_publishes(&self) -> u64 {
        self.snapshot.publish_count()
    }

    /// Poll (2 ms period, at most `max_tries` polls) until the published
    /// snapshot covers at least `points` learn steps — a read-after-write
    /// barrier for tests, benches, and catch-up waits. `None` if the
    /// snapshot never catches up within the budget.
    pub fn wait_snapshot_points(
        &self,
        points: u64,
        max_tries: usize,
    ) -> Option<Arc<ModelSnapshot>> {
        for _ in 0..max_tries {
            if let Some(s) = self.snapshot() {
                if s.points_seen() >= points {
                    return Some(s);
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        None
    }
}

struct XlaPath {
    runtime: Runtime,
    config: String,
    capacity: usize,
    batch: usize,
}

/// Copy the model out and swap it into the shared cell (one `O(K·D²)`
/// clone per `snapshot_interval` learns — the price of lock-free reads).
fn publish_snapshot(
    clf: &SupervisedGmm<Figmn>,
    cell: &SnapshotCell,
    metrics: &Metrics,
    dirty: &mut usize,
) {
    if let Some(snap) = clf.snapshot() {
        metrics.record_snapshot_publish(*dirty as u64);
        cell.store(Arc::new(snap));
        *dirty = 0;
    }
}

fn worker_loop(
    cfg: WorkerConfig,
    queue: Arc<BoundedQueue<Command>>,
    snapshot_cell: Arc<SnapshotCell>,
    metrics: Arc<Metrics>,
) {
    let joint_dim = cfg.n_features + cfg.n_classes;
    let mut joint_cfg = GmmConfig::new(joint_dim)
        .with_delta(cfg.gmm.delta)
        .with_beta(cfg.gmm.beta)
        .with_max_components(cfg.gmm.max_components)
        .with_kernel_mode(cfg.gmm.kernel_mode)
        .with_search_mode(cfg.gmm.search_mode)
        .with_replica_mode(cfg.gmm.replica_mode)
        .with_learn_mode(cfg.gmm.learn_mode)
        .with_decay(cfg.gmm.decay)
        .with_max_age(cfg.gmm.max_age);
    joint_cfg = if cfg.gmm.prune {
        joint_cfg.with_pruning(cfg.gmm.v_min, cfg.gmm.sp_min)
    } else {
        joint_cfg.without_pruning()
    };
    let mut stds = cfg.feature_stds.clone();
    stds.extend(std::iter::repeat(0.5).take(cfg.n_classes));
    let mut model = Figmn::new(joint_cfg, &stds);
    if let Some(engine) = cfg.engine {
        model.set_engine(Some(engine));
    }
    let mut clf = SupervisedGmm::from_model(model, cfg.n_features, cfg.n_classes);

    // Optional XLA inference path — the runtime must be built on this
    // thread (PjRtClient is Rc-based).
    let xla: Option<XlaPath> = cfg.xla_config.as_ref().and_then(|name| {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            log::warn!("xla scoring requested but no artifacts at {dir:?}");
            return None;
        }
        let runtime = Runtime::open(dir).ok()?;
        let meta = runtime.manifest().find(name, crate::runtime::ArtifactKind::Predict)?.clone();
        if meta.dim != joint_dim || meta.n_known != cfg.n_features {
            log::warn!(
                "xla config '{name}' shape (D={}, i={}) != worker (D={joint_dim}, i={})",
                meta.dim,
                meta.n_known,
                cfg.n_features
            );
            return None;
        }
        Some(XlaPath { runtime, config: name.clone(), capacity: meta.capacity, batch: meta.batch })
    });

    let mut learned: u64 = 0;
    let mut predicted: u64 = 0;
    let mut xla_batches: u64 = 0;
    // Candidate-index counters as of the last hub publish: the model
    // reports monotone totals, the hub wants additive deltas (so
    // multi-shard totals stay meaningful).
    let mut idx_published = IndexCounters::default();
    let publish_index_counters =
        |clf: &SupervisedGmm<Figmn>, prev: &mut IndexCounters, metrics: &Metrics| {
            let cur = clf.model().index_counters();
            metrics.record_index_counters(IndexCounters {
                rebuilds: cur.rebuilds - prev.rebuilds,
                incremental_updates: cur.incremental_updates - prev.incremental_updates,
                fallback_gate_triggers: cur.fallback_gate_triggers - prev.fallback_gate_triggers,
                masked_block_rows: cur.masked_block_rows - prev.masked_block_rows,
            });
            *prev = cur;
        };
    // Points applied since the last snapshot publish (the read path's
    // staleness); republished every `snapshot_interval` points and on
    // idle. Counted in points, not learn commands, so a learn_batch of
    // B advances the cadence by B.
    let mut dirty: usize = 0;
    let publish_every = cfg.snapshot_interval;
    let mut batcher: Batcher<(Vec<f64>, mpsc::Sender<Vec<f64>>)> = Batcher::new(cfg.batcher);

    let flush = |batch: Vec<(Vec<f64>, mpsc::Sender<Vec<f64>>)>,
                 clf: &SupervisedGmm<Figmn>,
                 xla: &Option<XlaPath>,
                 xla_batches: &mut u64,
                 predicted: &mut u64,
                 metrics: &Metrics| {
        let started = Instant::now();
        let n = batch.len();
        if clf.num_components() == 0 {
            // Nothing learned yet: answer uniform scores instead of
            // panicking the shard (predict-before-learn is legal traffic).
            let uniform = vec![1.0 / cfg.n_classes as f64; cfg.n_classes];
            for (_, reply) in batch {
                let _ = reply.send(uniform.clone());
            }
            *predicted += n as u64;
            metrics.record_predict(started, n);
            return;
        }
        // XLA path only when the batch fits and the model fits capacity.
        let use_xla = xla.as_ref().filter(|x| {
            n <= x.batch && clf.model().num_components() <= x.capacity && n > 0
        });
        if let Some(x) = use_xla {
            if let Ok(exec) = x.runtime.predict_exec(&x.config) {
                let state = PackedState::from_figmn(clf.model(), x.capacity);
                let mut xs = vec![0.0f32; x.batch * cfg.n_features];
                for (i, (f, _)) in batch.iter().enumerate() {
                    for (j, &v) in f.iter().enumerate() {
                        xs[i * cfg.n_features + j] = v as f32;
                    }
                }
                if let Ok(recon) = exec.predict(&xs, &state) {
                    let o = cfg.n_classes;
                    for (i, (_, reply)) in batch.into_iter().enumerate() {
                        let raw: Vec<f64> =
                            recon[i * o..(i + 1) * o].iter().map(|&v| v as f64).collect();
                        let _ = reply.send(normalize_scores(raw));
                    }
                    *xla_batches += 1;
                    *predicted += n as u64;
                    metrics.record_predict(started, n);
                    return;
                }
            }
        }
        // Native fallback.
        for (f, reply) in batch {
            let _ = reply.send(clf.class_scores(&f));
        }
        *predicted += n as u64;
        metrics.record_predict(started, n);
    };

    loop {
        // Sleep at most until the batcher deadline.
        let wait = batcher.time_to_deadline().unwrap_or(Duration::from_millis(50));
        let cmd = queue.pop_timeout(wait);
        match cmd {
            Some(Command::Learn { features, label }) => {
                // Order: serve queued predictions against the pre-update
                // model, then learn.
                if let Some(b) = batcher.flush() {
                    flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
                }
                let started = Instant::now();
                let before = clf.num_components();
                clf.train_one(&features, label);
                if clf.num_components() > before {
                    metrics.record_component_created();
                }
                learned += 1;
                metrics.record_learn(started);
                publish_index_counters(&clf, &mut idx_published, &metrics);
                dirty += 1;
                if publish_every > 0 && dirty >= publish_every {
                    publish_snapshot(&clf, &snapshot_cell, &metrics, &mut dirty);
                }
            }
            Some(Command::LearnBatch { xs, labels }) => {
                if let Some(b) = batcher.flush() {
                    flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
                }
                let started = Instant::now();
                let n = xs.len();
                let well_formed = labels.len() == n
                    && xs.iter().all(|x| x.len() == cfg.n_features)
                    && labels.iter().all(|&l| l < cfg.n_classes);
                if n > 0 && well_formed {
                    let before = clf.num_components();
                    clf.train_batch(&xs, &labels);
                    for _ in before..clf.num_components() {
                        metrics.record_component_created();
                    }
                    learned += n as u64;
                    metrics.record_learn_block(started, n);
                    publish_index_counters(&clf, &mut idx_published, &metrics);
                    dirty += n;
                    if publish_every > 0 && dirty >= publish_every {
                        publish_snapshot(&clf, &snapshot_cell, &metrics, &mut dirty);
                    }
                } // else: malformed block — counted nowhere, rejected upstream
            }
            Some(Command::Predict { features, reply }) => {
                if let Some(b) = batcher.push((features, reply)) {
                    flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
                }
            }
            Some(Command::LearnReg { features, targets }) => {
                if let Some(b) = batcher.flush() {
                    flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
                }
                let started = Instant::now();
                if targets.len() == cfg.n_classes && features.len() == cfg.n_features {
                    let mut joint = features;
                    joint.extend_from_slice(&targets);
                    clf.train_joint(&joint);
                    learned += 1;
                    metrics.record_learn(started);
                    publish_index_counters(&clf, &mut idx_published, &metrics);
                    dirty += 1;
                    if publish_every > 0 && dirty >= publish_every {
                        publish_snapshot(&clf, &snapshot_cell, &metrics, &mut dirty);
                    }
                } // else: malformed record — counted nowhere, rejected upstream
            }
            Some(Command::PredictReg { features, reply }) => {
                // Regression replies bypass the classification batcher
                // (no clipping semantics to share).
                let started = Instant::now();
                let out = if clf.num_components() == 0 {
                    vec![0.0; cfg.n_classes]
                } else {
                    clf.predict_targets(&features)
                };
                let _ = reply.send(out);
                predicted += 1;
                metrics.record_predict(started, 1);
            }
            Some(Command::Stats { reply }) => {
                let idx = clf.model().index_counters();
                let _ = reply.send(WorkerStats {
                    components: clf.num_components(),
                    points: clf.model().points_seen(),
                    learned,
                    predicted,
                    xla_batches,
                    model_bytes: clf.model().model_bytes(),
                    replica_bytes: snapshot_cell.load().map_or(0, |s| s.replica_bytes()),
                    index_rebuilds: idx.rebuilds,
                    index_incremental_updates: idx.incremental_updates,
                    fallback_gate_triggers: idx.fallback_gate_triggers,
                    masked_block_rows: idx.masked_block_rows,
                });
            }
            Some(Command::CheckpointJson { reply }) => {
                let _ = reply.send(clf.model().to_json());
            }
            Some(Command::Shutdown) => break,
            None => {
                // Timeout (batcher deadline) or closed-and-drained.
                if let Some(b) = batcher.poll() {
                    flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
                }
                // Idle republish: when the stream pauses mid-interval
                // the snapshot still catches up, so staleness is also
                // bounded in wall time (one queue timeout).
                if publish_every > 0 && dirty > 0 {
                    publish_snapshot(&clf, &snapshot_cell, &metrics, &mut dirty);
                }
                if queue.is_closed() && queue.is_empty() {
                    break;
                }
            }
        }
    }
    // Final drain of pending predictions.
    if let Some(b) = batcher.flush() {
        flush(b.items, &clf, &xla, &mut xla_batches, &mut predicted, &metrics);
    }
}

/// Clip-and-normalize reconstructed one-hot activations into scores
/// (mirrors `SupervisedGmm::class_scores`).
fn normalize_scores(raw: Vec<f64>) -> Vec<f64> {
    let mut scores: Vec<f64> = raw.iter().map(|&v| v.max(0.0)).collect();
    let total: f64 = scores.iter().sum();
    if total <= 0.0 {
        let best = raw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut t = 0.0;
        for (s, &r) in scores.iter_mut().zip(raw.iter()) {
            *s = (r - best).exp();
            t += *s;
        }
        for s in &mut scores {
            *s /= t;
        }
    } else {
        for s in &mut scores {
            *s /= total;
        }
    }
    scores
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn blob_point(rng: &mut Pcg64, class: usize) -> Vec<f64> {
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        vec![centers[class][0] + rng.normal() * 0.7, centers[class][1] + rng.normal() * 0.7]
    }

    fn spawn_blob_worker() -> (Worker, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let cfg = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]);
        (Worker::spawn(cfg, metrics.clone()), metrics)
    }

    #[test]
    fn learns_and_predicts() {
        let (worker, metrics) = spawn_blob_worker();
        let mut rng = Pcg64::seed(1);
        for i in 0..300 {
            let c = i % 3;
            worker.handle.learn(blob_point(&mut rng, c), c).unwrap();
        }
        // Predictions are serialized behind learns, so this sees the
        // fully-trained model.
        let mut correct = 0;
        for i in 0..60 {
            let c = i % 3;
            let scores = worker.handle.predict(blob_point(&mut rng, c)).unwrap();
            assert_eq!(scores.len(), 3);
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == c {
                correct += 1;
            }
        }
        assert!(correct >= 55, "correct {correct}/60");
        let stats = worker.handle.stats().unwrap();
        assert_eq!(stats.learned, 300);
        assert_eq!(stats.predicted, 60);
        assert!(stats.components >= 3);
        assert_eq!(metrics.snapshot().learned, 300);
        worker.join();
    }

    #[test]
    fn learn_batch_matches_pointwise_online_and_counts_points() {
        // An Online-mode shard fed one learn_batch must end bit-identical
        // to a shard fed the same points one learn at a time, and the
        // snapshot cadence must count the block's points, not "1 call".
        let (batched, metrics) = spawn_blob_worker();
        let (pointwise, _m) = spawn_blob_worker();
        let mut rng = Pcg64::seed(11);
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..60 {
            xs.push(blob_point(&mut rng, i % 3));
            labels.push(i % 3);
        }
        // Ten blocks of six points each, default snapshot interval 8:
        // a points-counted cadence crosses the interval roughly every
        // other block (~4+ interval publishes); the old calls-counted
        // cadence would have seen only 10 dirty steps → 1 publish.
        for (chunk_x, chunk_c) in xs.chunks(6).zip(labels.chunks(6)) {
            batched.handle.learn_batch(chunk_x.to_vec(), chunk_c.to_vec()).unwrap();
        }
        for (x, &c) in xs.iter().zip(&labels) {
            pointwise.handle.learn(x.clone(), c).unwrap();
        }
        for i in 0..10 {
            let x = blob_point(&mut rng, i % 3);
            assert_eq!(
                batched.handle.predict(x.clone()).unwrap(),
                pointwise.handle.predict(x).unwrap()
            );
        }
        let stats = batched.handle.stats().unwrap();
        assert_eq!(stats.learned, 60, "worker stats count points, not calls");
        assert_eq!(stats.points, 60);
        let m = metrics.snapshot();
        assert_eq!(m.learned, 10, "ten learn operations");
        assert_eq!(m.points_learned, 60, "…of 60 points");
        assert!(m.snapshots_published >= 4, "published {}", m.snapshots_published);
        assert!(
            batched.handle.wait_snapshot_points(60, 1000).is_some(),
            "snapshot must catch up to the whole stream"
        );
        batched.join();
        pointwise.join();
    }

    #[test]
    fn minibatch_worker_learn_batch_stages_blocks() {
        // A MiniBatch-mode shard accepts learn_batch traffic and trains
        // a usable classifier through the staged pipeline.
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1)
            .with_delta(0.5)
            .with_beta(0.05)
            .without_pruning()
            .with_learn_mode(crate::gmm::LearnMode::MiniBatch { b: 16 });
        let cfg = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]);
        let worker = Worker::spawn(cfg, metrics);
        let mut rng = Pcg64::seed(12);
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..300 {
            xs.push(blob_point(&mut rng, i % 3));
            labels.push(i % 3);
        }
        for (chunk_x, chunk_c) in xs.chunks(50).zip(labels.chunks(50)) {
            worker.handle.learn_batch(chunk_x.to_vec(), chunk_c.to_vec()).unwrap();
        }
        let mut correct = 0;
        for i in 0..60 {
            let c = i % 3;
            let scores = worker.handle.predict(blob_point(&mut rng, c)).unwrap();
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == c {
                correct += 1;
            }
        }
        assert!(correct >= 50, "correct {correct}/60");
        assert_eq!(worker.handle.stats().unwrap().learned, 300);
        worker.join();
    }

    #[test]
    fn topc_shard_surfaces_index_counters() {
        // A TopC mini-batch shard reports its candidate-index counters
        // through stats and folds deltas into the hub metrics.
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1)
            .with_delta(0.5)
            .with_beta(0.05)
            .without_pruning()
            .with_search_mode(crate::gmm::SearchMode::TopC { c: 2 })
            .with_learn_mode(crate::gmm::LearnMode::MiniBatch { b: 8 });
        let cfg = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]);
        let worker = Worker::spawn(cfg, metrics.clone());
        let mut rng = Pcg64::seed(21);
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..120 {
            xs.push(blob_point(&mut rng, i % 3));
            labels.push(i % 3);
        }
        for (chunk_x, chunk_c) in xs.chunks(8).zip(labels.chunks(8)) {
            worker.handle.learn_batch(chunk_x.to_vec(), chunk_c.to_vec()).unwrap();
        }
        let stats = worker.handle.stats().unwrap();
        assert!(
            stats.index_incremental_updates > 0,
            "creates/drift must register as incremental maintenance"
        );
        assert!(stats.masked_block_rows > 0, "blocks must take the masked TopC pass");
        let j = stats.to_json().to_string_compact();
        assert!(j.contains("\"index_rebuilds\""), "{j}");
        let m = metrics.snapshot();
        assert_eq!(m.index_incremental_updates, stats.index_incremental_updates);
        assert_eq!(m.masked_block_rows, stats.masked_block_rows);
        assert_eq!(m.index_rebuilds, stats.index_rebuilds);
        worker.join();
    }

    #[test]
    fn checkpoint_json_is_loadable() {
        let (worker, _m) = spawn_blob_worker();
        let mut rng = Pcg64::seed(2);
        for i in 0..60 {
            worker.handle.learn(blob_point(&mut rng, i % 3), i % 3).unwrap();
        }
        let j = worker.handle.checkpoint_json().unwrap();
        let restored = Figmn::from_json(&j).expect("checkpoint must round-trip");
        assert!(restored.num_components() >= 3);
        worker.join();
    }

    #[test]
    fn regression_path_learns_a_function() {
        // y = 2x − 1 through the worker's learn_reg/predict_reg ops
        // (n_classes doubles as output arity = 1).
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.1).with_beta(0.2).without_pruning();
        let cfg = WorkerConfig::new(1, 1, gmm, vec![1.0]);
        let worker = Worker::spawn(cfg, metrics);
        let mut rng = Pcg64::seed(4);
        for _ in 0..2000 {
            let x = rng.uniform_in(-2.0, 2.0);
            worker.handle.learn_reg(vec![x], vec![2.0 * x - 1.0]).unwrap();
        }
        for &x in &[-1.5, 0.0, 1.5] {
            let y = worker.handle.predict_reg(vec![x]).unwrap()[0];
            assert!((y - (2.0 * x - 1.0)).abs() < 0.15, "f({x}) = {y}");
        }
        worker.join();
    }

    #[test]
    fn engine_backed_worker_matches_serial() {
        // Same stream into a serial and an engine-backed shard: the
        // determinism guarantee says predictions agree bit-for-bit.
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let serial = Worker::spawn(
            WorkerConfig::new(2, 3, gmm.clone(), vec![3.0, 3.0]),
            Arc::new(Metrics::new()),
        );
        let pooled = Worker::spawn(
            WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]).with_engine(EngineConfig::new(2)),
            Arc::new(Metrics::new()),
        );
        let mut rng = Pcg64::seed(9);
        for i in 0..120 {
            let x = blob_point(&mut rng, i % 3);
            serial.handle.learn(x.clone(), i % 3).unwrap();
            pooled.handle.learn(x, i % 3).unwrap();
        }
        for i in 0..20 {
            let x = blob_point(&mut rng, i % 3);
            assert_eq!(
                serial.handle.predict(x.clone()).unwrap(),
                pooled.handle.predict(x).unwrap()
            );
        }
        assert_eq!(
            serial.handle.stats().unwrap().components,
            pooled.handle.stats().unwrap().components
        );
        serial.join();
        pooled.join();
    }

    #[test]
    fn publishes_snapshots_on_interval_and_idle() {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let cfg = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]).with_snapshot_interval(4);
        let worker = Worker::spawn(cfg, metrics.clone());
        let mut rng = Pcg64::seed(8);
        for i in 0..8 {
            worker.handle.learn(blob_point(&mut rng, i % 3), i % 3).unwrap();
        }
        // stats() serializes behind the learns; the snapshot then catches
        // up to all 8 points via the interval or the idle republish.
        let stats = worker.handle.stats().unwrap();
        assert_eq!(stats.learned, 8);
        let snap = worker
            .handle
            .wait_snapshot_points(8, 1000)
            .expect("snapshot never caught up to the stream");
        assert_eq!(snap.points_seen(), 8);
        assert!(worker.handle.snapshot_publishes() >= 1);
        assert_eq!(
            metrics.snapshot().snapshots_published,
            worker.handle.snapshot_publishes()
        );
        // With the queue drained, the snapshot and the sequential
        // predict path see the same model — scores match bit-for-bit.
        let x = blob_point(&mut rng, 1);
        assert_eq!(snap.class_scores(&x), worker.handle.predict(x).unwrap());
        worker.join();
    }

    #[test]
    fn snapshot_publishing_can_be_disabled() {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let cfg = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]).with_snapshot_interval(0);
        let worker = Worker::spawn(cfg, metrics);
        let mut rng = Pcg64::seed(9);
        for i in 0..12 {
            worker.handle.learn(blob_point(&mut rng, i % 3), i % 3).unwrap();
        }
        let _ = worker.handle.stats().unwrap();
        assert!(worker.handle.snapshot().is_none());
        assert_eq!(worker.handle.snapshot_publishes(), 0);
        worker.join();
    }

    #[test]
    fn predict_before_learn_returns_uniform() {
        let (worker, _m) = spawn_blob_worker();
        let scores = worker.handle.predict(vec![1.0, 2.0]).unwrap();
        assert_eq!(scores, vec![1.0 / 3.0; 3]);
        // The shard survives and can still learn afterwards.
        worker.handle.learn(vec![0.0, 0.0], 0).unwrap();
        assert_eq!(worker.handle.stats().unwrap().learned, 1);
        worker.join();
    }

    #[test]
    fn shutdown_drains_pending_predictions() {
        let (worker, _m) = spawn_blob_worker();
        let mut rng = Pcg64::seed(3);
        for i in 0..30 {
            worker.handle.learn(blob_point(&mut rng, i % 3), i % 3).unwrap();
        }
        // Issue predictions and immediately shut down; replies must still
        // arrive (flush-on-shutdown).
        let handle = worker.handle.clone();
        let p1 = std::thread::spawn(move || handle.predict(vec![0.0, 0.0]));
        std::thread::sleep(Duration::from_millis(5));
        worker.join();
        let scores = p1.join().unwrap();
        assert!(scores.is_ok());
    }
}
