//! Incremental, bounded line framing for the multiplexed server.
//!
//! The event-loop server reads whatever bytes a socket has ready and
//! feeds them here; the framer re-assembles newline-delimited request
//! lines across arbitrarily split reads while enforcing a hard cap on
//! the bytes a single line may buffer. A line that exceeds the cap
//! produces exactly one [`Frame::Oversized`] event (the server answers
//! it with a protocol-error `Response`) and the framer discards input
//! until the offending line's newline, then resynchronizes — one abusive
//! line never desynchronizes or disconnects an otherwise healthy client.
//!
//! This module is pure (no I/O, no FFI), so its unit tests run under
//! miri alongside the arena and candidate-index suites (see ci.yml).

/// Default per-connection line cap: 1 MiB. A `score_batch` of 32 rows at
/// D = 3072 is ~1.1 MB of JSON floats, so anything bigger than this is
/// either abuse or a workload that should be chunked client-side.
pub const DEFAULT_MAX_LINE_BYTES: usize = 1 << 20;

/// One framing event produced by [`LineFramer::feed`].
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete line (without its trailing newline), lossily decoded —
    /// non-UTF-8 bytes become replacement characters and then fail JSON
    /// parsing downstream, exactly like any other malformed request.
    Line(String),
    /// A line exceeded the cap. Emitted once per oversized line, at the
    /// moment the cap is crossed; the rest of the line is discarded.
    Oversized,
}

/// Incremental line-splitting state machine with a bounded buffer.
pub struct LineFramer {
    max_line: usize,
    buf: Vec<u8>,
    /// Inside an oversized line: drop bytes until its newline.
    discarding: bool,
}

impl LineFramer {
    pub fn new(max_line: usize) -> Self {
        assert!(max_line >= 1);
        LineFramer { max_line, buf: Vec::new(), discarding: false }
    }

    /// Consume one chunk of socket bytes, appending every completed
    /// frame to `out`.
    pub fn feed(&mut self, chunk: &[u8], out: &mut Vec<Frame>) {
        let mut rest = chunk;
        while !rest.is_empty() {
            let nl = rest.iter().position(|&b| b == b'\n');
            if self.discarding {
                match nl {
                    Some(i) => {
                        // The oversized line ends here; resynchronize.
                        self.discarding = false;
                        rest = &rest[i + 1..];
                    }
                    None => return, // still inside the oversized line
                }
                continue;
            }
            match nl {
                Some(i) => {
                    if self.buf.len() + i > self.max_line {
                        out.push(Frame::Oversized);
                        self.buf.clear();
                    } else {
                        self.buf.extend_from_slice(&rest[..i]);
                        let line = std::mem::take(&mut self.buf);
                        out.push(Frame::Line(
                            String::from_utf8_lossy(&line).into_owned(),
                        ));
                    }
                    rest = &rest[i + 1..];
                }
                None => {
                    if self.buf.len() + rest.len() > self.max_line {
                        // Cap crossed mid-line: report once, then discard
                        // until this line's newline shows up.
                        out.push(Frame::Oversized);
                        self.buf.clear();
                        self.discarding = true;
                    } else {
                        self.buf.extend_from_slice(rest);
                    }
                    return;
                }
            }
        }
    }

    /// EOF: the final unterminated line, if any (the legacy
    /// thread-per-connection server served an EOF-truncated request, and
    /// the event loop keeps that behavior).
    pub fn finish(&mut self) -> Option<Frame> {
        self.discarding = false;
        if self.buf.is_empty() {
            return None;
        }
        let line = std::mem::take(&mut self.buf);
        Some(Frame::Line(String::from_utf8_lossy(&line).into_owned()))
    }

    /// Bytes currently buffered for an incomplete line.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_all(f: &mut LineFramer, chunks: &[&[u8]]) -> Vec<Frame> {
        let mut out = Vec::new();
        for c in chunks {
            f.feed(c, &mut out);
        }
        out
    }

    #[test]
    fn whole_lines_in_one_chunk() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[b"alpha\nbeta\n"]);
        assert_eq!(
            out,
            vec![Frame::Line("alpha".into()), Frame::Line("beta".into())]
        );
        assert_eq!(f.buffered(), 0);
    }

    #[test]
    fn line_split_across_many_feeds() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[b"{\"op\":", b"\"pi", b"ng\"}", b"\n"]);
        assert_eq!(out, vec![Frame::Line("{\"op\":\"ping\"}".into())]);
    }

    #[test]
    fn newline_split_from_payload() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[b"one", b"\ntwo\nthr", b"ee\n"]);
        assert_eq!(
            out,
            vec![
                Frame::Line("one".into()),
                Frame::Line("two".into()),
                Frame::Line("three".into())
            ]
        );
    }

    #[test]
    fn empty_lines_are_preserved() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[b"\n\nx\n"]);
        assert_eq!(
            out,
            vec![
                Frame::Line(String::new()),
                Frame::Line(String::new()),
                Frame::Line("x".into())
            ]
        );
    }

    #[test]
    fn oversized_line_reports_once_and_resyncs() {
        let mut f = LineFramer::new(8);
        // 12 bytes without a newline: cap crossed → one Oversized.
        let out = feed_all(&mut f, &[b"0123456789ab"]);
        assert_eq!(out, vec![Frame::Oversized]);
        // More of the same line: silent discard, no duplicate event.
        let out = feed_all(&mut f, &[b"cdefgh"]);
        assert!(out.is_empty());
        // Its newline ends the discard; the next line parses normally.
        let out = feed_all(&mut f, &[b"ij\nok\n"]);
        assert_eq!(out, vec![Frame::Line("ok".into())]);
    }

    #[test]
    fn oversized_line_completed_within_one_chunk() {
        let mut f = LineFramer::new(4);
        let out = feed_all(&mut f, &[b"toolong\nfine\n"]);
        assert_eq!(out, vec![Frame::Oversized, Frame::Line("fine".into())]);
    }

    #[test]
    fn exactly_at_the_cap_is_accepted() {
        let mut f = LineFramer::new(5);
        let out = feed_all(&mut f, &[b"12345\n123456\n"]);
        assert_eq!(out, vec![Frame::Line("12345".into()), Frame::Oversized]);
    }

    #[test]
    fn oversized_accumulated_across_feeds() {
        let mut f = LineFramer::new(6);
        let mut out = Vec::new();
        f.feed(b"abc", &mut out);
        f.feed(b"def", &mut out); // exactly 6 buffered: still fine
        assert!(out.is_empty());
        assert_eq!(f.buffered(), 6);
        f.feed(b"g", &mut out); // 7th byte crosses the cap
        assert_eq!(out, vec![Frame::Oversized]);
        f.feed(b"\nz\n", &mut out);
        assert_eq!(out, vec![Frame::Oversized, Frame::Line("z".into())]);
    }

    #[test]
    fn finish_returns_trailing_partial_line() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[b"done\npartial"]);
        assert_eq!(out, vec![Frame::Line("done".into())]);
        assert_eq!(f.finish(), Some(Frame::Line("partial".into())));
        assert_eq!(f.finish(), None);
    }

    #[test]
    fn finish_while_discarding_yields_nothing() {
        let mut f = LineFramer::new(4);
        let out = feed_all(&mut f, &[b"oversized-without-newline"]);
        assert_eq!(out, vec![Frame::Oversized]);
        assert_eq!(f.finish(), None);
    }

    #[test]
    fn invalid_utf8_is_lossy_not_fatal() {
        let mut f = LineFramer::new(64);
        let out = feed_all(&mut f, &[&[0xff, 0xfe, b'\n']]);
        match &out[..] {
            [Frame::Line(s)] => assert!(!s.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }
}
