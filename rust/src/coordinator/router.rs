//! Record routing across model shards.
//!
//! Three policies, matching the three reasons to shard an online learner:
//!
//! - [`RoutingPolicy::RoundRobin`] — throughput: spread learn traffic
//!   evenly; each shard sees a 1/S subsample (online bagging-ish).
//! - [`RoutingPolicy::FeatureHash`] — locality: the same region of input
//!   space always lands on the same shard (piecewise experts).
//! - [`RoutingPolicy::Broadcast`] — redundancy/ensemble: every shard
//!   learns every record; predictions average across shards.
//!
//! Prediction always fans out to every shard and averages the score
//! vectors (for RoundRobin/FeatureHash the shards are partial models;
//! averaging is the natural ensemble read-out).
//!
//! ## Read/write traffic classes
//!
//! The router splits traffic into two classes:
//!
//! - **Write class** — `learn`/`learn_batch`/`learn_reg` plus the sequential
//!   `predict`/`predict_reg`: everything goes through the shard
//!   workers' command queues, so a predict observes every learn queued
//!   before it (read-your-writes).
//! - **Read class** — `score_read`/`score_batch_read`/`predict_read`/
//!   `predict_batch_read`: served from each shard's latest published
//!   [`ModelSnapshot`] (optionally on a [`ScorerPool`]), never touching
//!   the command queues. Reads may lag writes by fewer than the
//!   worker's `snapshot_interval` learn steps (the staleness
//!   contract); within one snapshot, results are deterministic and
//!   bit-identical to the serial model at that version. Until a first
//!   snapshot exists, predicts fall back to the write class and scores
//!   error out.
//!
//! The event-loop server's read coalescer maps single-query `score`/
//! snapshot-`predict` requests onto `score_batch_read`/
//! `predict_batch_read`. That substitution is sound because the batch
//! surfaces are per-element bit-identical to their single-query
//! counterparts: the blocked kernels guarantee it per shard (PR 5),
//! the merge here sums shard results element-wise in fixed shard order
//! before one divide (identical arithmetic for a length-1 and a
//! length-B batch), and the `NO_SNAPSHOT` fallback applies the same
//! per-item sequential predict in both shapes.

use super::metrics::Metrics;
use super::scorer::{execute, ReadKind, ReadResult, ScorerPool};
use super::worker::WorkerHandle;
use super::{CoordError, Result};
use crate::gmm::ModelSnapshot;
use std::sync::Arc;

/// Shard-selection policy for learn traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    FeatureHash,
    Broadcast,
}

/// Routes one model's traffic over its shard workers.
pub struct Router {
    shards: Vec<WorkerHandle>,
    policy: RoutingPolicy,
    next: std::sync::atomic::AtomicUsize,
    /// Scorer pool for the read class (`None` = compute snapshot reads
    /// inline on the calling thread — same results, no fan-out).
    scorers: Option<Arc<ScorerPool>>,
    metrics: Option<Arc<Metrics>>,
    /// Expected request shapes `(n_features, joint_dim)` for validating
    /// read-class requests even before the first snapshot is published
    /// (the registry wires this from the model spec).
    shape: Option<(usize, usize)>,
}

impl Router {
    pub fn new(shards: Vec<WorkerHandle>, policy: RoutingPolicy) -> Self {
        assert!(!shards.is_empty(), "router needs ≥1 shard");
        Router {
            shards,
            policy,
            next: std::sync::atomic::AtomicUsize::new(0),
            scorers: None,
            metrics: None,
            shape: None,
        }
    }

    /// Attach the read path: snapshot reads run on `scorers` and are
    /// counted in `metrics` (the registry wires this at create time).
    pub fn with_read_path(mut self, scorers: Arc<ScorerPool>, metrics: Arc<Metrics>) -> Self {
        self.scorers = Some(scorers);
        self.metrics = Some(metrics);
        self
    }

    /// Record the model's feature/class split so read-class requests are
    /// shape-validated even before the first snapshot exists (otherwise a
    /// malformed fallback predict could panic a shard worker).
    pub fn with_shape(mut self, n_features: usize, n_classes: usize) -> Self {
        self.shape = Some((n_features, n_features + n_classes));
        self
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[WorkerHandle] {
        &self.shards
    }

    /// Which shard a learn record goes to (None = all).
    fn pick(&self, features: &[f64]) -> Option<usize> {
        match self.policy {
            RoutingPolicy::Broadcast => None,
            RoutingPolicy::RoundRobin => Some(
                self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.shards.len(),
            ),
            RoutingPolicy::FeatureHash => Some(feature_hash(features) % self.shards.len()),
        }
    }

    /// Route one labeled record.
    pub fn learn(&self, features: Vec<f64>, label: usize) -> Result<()> {
        match self.pick(&features) {
            Some(i) => self.shards[i].learn(features, label),
            None => {
                for s in &self.shards {
                    s.learn(features.clone(), label)?;
                }
                Ok(())
            }
        }
    }

    /// Route one block of labeled records as a unit. RoundRobin sends
    /// the whole block to one shard (the block, not the point, is the
    /// routing unit — splitting it would undo the staged mini-batch
    /// pipeline); Broadcast copies it to every shard; FeatureHash
    /// partitions rows by their feature hash (each point lands on the
    /// same shard it would have reached point-by-point) and forwards
    /// each shard its sub-block.
    pub fn learn_batch(&self, xs: Vec<Vec<f64>>, labels: Vec<usize>) -> Result<()> {
        if xs.is_empty() {
            return Ok(());
        }
        match self.policy {
            RoutingPolicy::Broadcast => {
                for s in &self.shards {
                    s.learn_batch(xs.clone(), labels.clone())?;
                }
                Ok(())
            }
            RoutingPolicy::RoundRobin => {
                let i = self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                    % self.shards.len();
                self.shards[i].learn_batch(xs, labels)
            }
            RoutingPolicy::FeatureHash => {
                let n = self.shards.len();
                let mut parts: Vec<(Vec<Vec<f64>>, Vec<usize>)> =
                    (0..n).map(|_| (Vec::new(), Vec::new())).collect();
                for (x, l) in xs.into_iter().zip(labels) {
                    let i = feature_hash(&x) % n;
                    parts[i].0.push(x);
                    parts[i].1.push(l);
                }
                for (i, (px, pl)) in parts.into_iter().enumerate() {
                    if !px.is_empty() {
                        self.shards[i].learn_batch(px, pl)?;
                    }
                }
                Ok(())
            }
        }
    }

    /// Route one regression record.
    pub fn learn_reg(&self, features: Vec<f64>, targets: Vec<f64>) -> Result<()> {
        match self.pick(&features) {
            Some(i) => self.shards[i].learn_reg(features, targets),
            None => {
                for s in &self.shards {
                    s.learn_reg(features.clone(), targets.clone())?;
                }
                Ok(())
            }
        }
    }

    /// Fan out a regression prediction and average shard targets.
    pub fn predict_reg(&self, features: &[f64]) -> Result<Vec<f64>> {
        let mut acc: Option<Vec<f64>> = None;
        let mut n = 0usize;
        for s in &self.shards {
            match s.stats() {
                Ok(st) if st.components == 0 => continue,
                Err(_) => continue,
                _ => {}
            }
            if let Ok(t) = s.predict_reg(features.to_vec()) {
                n += 1;
                match &mut acc {
                    None => acc = Some(t),
                    Some(a) => {
                        for (x, y) in a.iter_mut().zip(t.iter()) {
                            *x += y;
                        }
                    }
                }
            }
        }
        let mut out = acc.ok_or(CoordError::Rejected("no shard could predict"))?;
        for v in &mut out {
            *v /= n as f64;
        }
        Ok(out)
    }

    /// Fan out a prediction and average shard scores. Shards that have
    /// seen no data yet are skipped; errors only if every shard fails.
    pub fn predict(&self, features: &[f64]) -> Result<Vec<f64>> {
        let mut acc: Option<Vec<f64>> = None;
        let mut n = 0usize;
        for s in &self.shards {
            // A shard with zero components cannot predict.
            match s.stats() {
                Ok(st) if st.components == 0 => continue,
                Err(_) => continue,
                _ => {}
            }
            match s.predict(features.to_vec()) {
                Ok(scores) => {
                    n += 1;
                    match &mut acc {
                        None => acc = Some(scores),
                        Some(a) => {
                            for (x, y) in a.iter_mut().zip(scores.iter()) {
                                *x += y;
                            }
                        }
                    }
                }
                Err(_) => continue,
            }
        }
        let mut scores = acc.ok_or(CoordError::Rejected("no shard could predict"))?;
        for v in &mut scores {
            *v /= n as f64;
        }
        Ok(scores)
    }

    // ---- read traffic class (snapshot-served) ----

    /// Latest published snapshot of every shard that has one.
    fn shard_snapshots(&self) -> Vec<Arc<ModelSnapshot>> {
        self.shards.iter().filter_map(|s| s.snapshot()).collect()
    }

    /// Any one published snapshot (for validating request shapes).
    fn any_snapshot(&self) -> Option<Arc<ModelSnapshot>> {
        self.shards.iter().find_map(|s| s.snapshot())
    }

    /// Expected feature-vector length for read requests, from a live
    /// snapshot or the configured shape.
    fn expected_features(&self) -> Option<usize> {
        self.any_snapshot()
            .map(|s| s.n_features())
            .or_else(|| self.shape.map(|(f, _)| f))
    }

    /// Expected joint-vector length for read requests.
    fn expected_dim(&self) -> Option<usize> {
        self.any_snapshot()
            .map(|s| s.dim())
            .or_else(|| self.shape.map(|(_, d)| d))
    }

    /// Reject a malformed read request up front — a wrong-dimension
    /// vector must become a clean protocol error here, not a panic
    /// inside a scorer thread (or, via the fallback, a shard worker).
    fn check_read_dim(&self, got: usize, want: Option<usize>, what: &str) -> Result<()> {
        if let Some(want) = want {
            if got != want {
                return Err(CoordError::Protocol(format!(
                    "{what}: expected {want} dims, got {got}"
                )));
            }
        }
        Ok(())
    }

    /// Fan one read out to every published shard snapshot: all jobs are
    /// submitted before any reply is awaited, so shards score in
    /// parallel on the scorer pool (inline, serially, without one).
    fn fan_read(&self, mk: impl Fn() -> ReadKind) -> Result<Vec<ReadResult>> {
        let snaps = self.shard_snapshots();
        if snaps.is_empty() {
            return Err(CoordError::Rejected(NO_SNAPSHOT));
        }
        if let Some(m) = &self.metrics {
            m.record_snapshot_read();
            // Density surfaces on replica-carrying snapshots are served
            // from the f32 arenas — count those reads separately so
            // operators can see which tier their traffic hits.
            if snaps.iter().any(|s| s.has_replica()) {
                m.record_replica_read();
            }
        }
        match &self.scorers {
            Some(pool) => {
                let rxs: Vec<_> = snaps
                    .into_iter()
                    .map(|s| pool.submit(s, mk()))
                    .collect::<Result<_>>()?;
                rxs.into_iter()
                    .map(|rx| rx.recv().map_err(|_| CoordError::Rejected("scorer died")))
                    .collect()
            }
            None => Ok(snaps.iter().map(|s| execute(s, mk())).collect()),
        }
    }

    /// Average per-point densities across shard results. A shard that
    /// replied [`ReadResult::Failed`] (protocol mismatch) is skipped;
    /// when *no* shard produced densities, the first failure reason is
    /// surfaced as a protocol error to the client.
    fn merge_densities(results: Vec<ReadResult>, expect_len: usize) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; expect_len];
        let mut n = 0usize;
        let mut failure: Option<String> = None;
        for r in results {
            match r {
                ReadResult::Densities(d) if d.len() == expect_len => {
                    n += 1;
                    for (a, v) in acc.iter_mut().zip(d.iter()) {
                        *a += v;
                    }
                }
                ReadResult::Failed(msg) => {
                    failure.get_or_insert(msg);
                }
                _ => {}
            }
        }
        if n == 0 {
            return Err(match failure {
                Some(msg) => CoordError::Protocol(msg),
                None => CoordError::Rejected("no shard could score"),
            });
        }
        for a in &mut acc {
            *a /= n as f64;
        }
        Ok(acc)
    }

    /// Average per-point score vectors across shard results (same
    /// failure semantics as [`Router::merge_densities`]).
    fn merge_scores(results: Vec<ReadResult>, expect_len: usize) -> Result<Vec<Vec<f64>>> {
        let mut acc: Option<Vec<Vec<f64>>> = None;
        let mut n = 0usize;
        let mut failure: Option<String> = None;
        for r in results {
            match r {
                ReadResult::Scores(rows) if rows.len() == expect_len => {
                    n += 1;
                    match &mut acc {
                        None => acc = Some(rows),
                        Some(a) => {
                            for (ar, row) in a.iter_mut().zip(rows.iter()) {
                                for (x, y) in ar.iter_mut().zip(row.iter()) {
                                    *x += y;
                                }
                            }
                        }
                    }
                }
                ReadResult::Failed(msg) => {
                    failure.get_or_insert(msg);
                }
                _ => {}
            }
        }
        let mut out = acc.ok_or(match failure {
            Some(msg) => CoordError::Protocol(msg),
            None => CoordError::Rejected("no shard could predict"),
        })?;
        for row in &mut out {
            for v in row {
                *v /= n as f64;
            }
        }
        Ok(out)
    }

    /// Joint log-density served from the latest snapshots (read class;
    /// averaged across shards). Errors until a snapshot is published.
    pub fn score_read(&self, x: &[f64]) -> Result<f64> {
        self.check_read_dim(x.len(), self.expected_dim(), "score")?;
        let x = x.to_vec();
        let results = self.fan_read(|| ReadKind::Score { x: x.clone() })?;
        Ok(Self::merge_densities(results, 1)?[0])
    }

    /// Batched [`Router::score_read`].
    pub fn score_batch_read(&self, xs: &[Vec<f64>]) -> Result<Vec<f64>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let want = self.expected_dim();
        for row in xs {
            self.check_read_dim(row.len(), want, "score_batch")?;
        }
        // One shared copy of the batch; shards clone only the Arc.
        let shared = Arc::new(xs.to_vec());
        let results = self.fan_read(|| ReadKind::ScoreBatch { xs: shared.clone() })?;
        Self::merge_densities(results, xs.len())
    }

    /// Class scores served from the latest snapshots (read class). When
    /// no shard has published yet, falls back to the sequential
    /// [`Router::predict`] so predict-before-first-snapshot still works;
    /// other read-path failures surface as errors.
    pub fn predict_read(&self, features: &[f64]) -> Result<Vec<f64>> {
        self.check_read_dim(features.len(), self.expected_features(), "predict")?;
        let f = features.to_vec();
        match self.fan_read(|| ReadKind::ClassScores { features: f.clone() }) {
            Ok(results) => Ok(Self::merge_scores(results, 1)?.pop().expect("len 1")),
            Err(CoordError::Rejected(r)) if r == NO_SNAPSHOT => {
                if let Some(m) = &self.metrics {
                    m.record_snapshot_fallback();
                }
                self.predict(features)
            }
            Err(e) => Err(e),
        }
    }

    /// Batched [`Router::predict_read`] (same fallback semantics).
    pub fn predict_batch_read(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let want = self.expected_features();
        for row in xs {
            self.check_read_dim(row.len(), want, "predict_batch")?;
        }
        // One shared copy of the batch; shards clone only the Arc.
        let shared = Arc::new(xs.to_vec());
        match self.fan_read(|| ReadKind::ClassScoresBatch { xs: shared.clone() }) {
            Ok(results) => Self::merge_scores(results, xs.len()),
            Err(CoordError::Rejected(r)) if r == NO_SNAPSHOT => {
                if let Some(m) = &self.metrics {
                    m.record_snapshot_fallback();
                }
                xs.iter().map(|x| self.predict(x)).collect()
            }
            Err(e) => Err(e),
        }
    }
}

/// Sentinel reason for "the read class has nothing published yet" —
/// the only fan-out failure the predict paths fall back on.
const NO_SNAPSHOT: &str = "no snapshot published";

/// FNV-1a over the raw feature bytes — stable, order-sensitive.
fn feature_hash(features: &[f64]) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for f in features {
        for b in f.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::worker::{Worker, WorkerConfig};
    use crate::gmm::GmmConfig;
    use crate::rng::Pcg64;
    use std::sync::Arc;

    fn spawn_shards(n: usize) -> (Vec<Worker>, Vec<WorkerHandle>) {
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..n {
            let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
            let w = Worker::spawn(WorkerConfig::new(2, 2, gmm, vec![3.0, 3.0]), metrics.clone());
            handles.push(w.handle.clone());
            workers.push(w);
        }
        (workers, handles)
    }

    fn wait_settled(handles: &[WorkerHandle]) {
        // stats() is processed in-order behind all learns.
        for h in handles {
            let _ = h.stats();
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let (workers, handles) = spawn_shards(3);
        let router = Router::new(handles.clone(), RoutingPolicy::RoundRobin);
        let mut rng = Pcg64::seed(1);
        for i in 0..90 {
            let c = i % 2;
            router.learn(vec![rng.normal(), c as f64 * 7.0 + rng.normal()], c).unwrap();
        }
        wait_settled(&handles);
        for h in &handles {
            assert_eq!(h.stats().unwrap().learned, 30);
        }
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn learn_batch_routes_blocks_whole_and_partitions_by_hash() {
        // RoundRobin: each block lands whole on exactly one shard.
        let (workers, handles) = spawn_shards(3);
        let router = Router::new(handles.clone(), RoutingPolicy::RoundRobin);
        let mut rng = Pcg64::seed(21);
        for _ in 0..6 {
            let mut xs = Vec::new();
            let mut labels = Vec::new();
            for i in 0..10 {
                let c = i % 2;
                xs.push(vec![rng.normal(), c as f64 * 7.0 + rng.normal()]);
                labels.push(c);
            }
            router.learn_batch(xs, labels).unwrap();
        }
        wait_settled(&handles);
        for h in &handles {
            assert_eq!(h.stats().unwrap().learned, 20, "2 blocks × 10 points each");
        }
        drop(router);
        for w in workers {
            w.join();
        }
        // FeatureHash: a block's rows land on the same shards they
        // would have reached point-by-point.
        let (workers, handles) = spawn_shards(3);
        let (ctl_workers, ctl_handles) = spawn_shards(3);
        let batched = Router::new(handles.clone(), RoutingPolicy::FeatureHash);
        let pointwise = Router::new(ctl_handles.clone(), RoutingPolicy::FeatureHash);
        let mut rng = Pcg64::seed(22);
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..30 {
            xs.push(vec![rng.normal(), rng.normal()]);
            labels.push(i % 2);
        }
        batched.learn_batch(xs.clone(), labels.clone()).unwrap();
        for (x, &c) in xs.iter().zip(&labels) {
            pointwise.learn(x.clone(), c).unwrap();
        }
        wait_settled(&handles);
        wait_settled(&ctl_handles);
        for (b, p) in handles.iter().zip(&ctl_handles) {
            assert_eq!(b.stats().unwrap().learned, p.stats().unwrap().learned);
        }
        drop(batched);
        drop(pointwise);
        for w in workers.into_iter().chain(ctl_workers) {
            w.join();
        }
    }

    #[test]
    fn feature_hash_is_sticky() {
        let (workers, handles) = spawn_shards(4);
        let router = Router::new(handles.clone(), RoutingPolicy::FeatureHash);
        // The same vector must always go to the same shard.
        for _ in 0..20 {
            router.learn(vec![1.25, -3.5], 0).unwrap();
        }
        wait_settled(&handles);
        let counts: Vec<u64> = handles.iter().map(|h| h.stats().unwrap().learned).collect();
        assert_eq!(counts.iter().sum::<u64>(), 20);
        assert_eq!(counts.iter().filter(|&&c| c > 0).count(), 1, "counts {counts:?}");
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn broadcast_reaches_all() {
        let (workers, handles) = spawn_shards(2);
        let router = Router::new(handles.clone(), RoutingPolicy::Broadcast);
        let mut rng = Pcg64::seed(2);
        for i in 0..40 {
            let c = i % 2;
            router
                .learn(vec![c as f64 * 6.0 + rng.normal(), c as f64 * 6.0 + rng.normal()], c)
                .unwrap();
        }
        wait_settled(&handles);
        for h in &handles {
            assert_eq!(h.stats().unwrap().learned, 40);
        }
        // Ensemble prediction works and is a distribution.
        let scores = router.predict(&[0.0, 0.0]).unwrap();
        assert_eq!(scores.len(), 2);
        assert!((scores.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn read_class_matches_sequential_path_when_caught_up() {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let w = Worker::spawn(
            WorkerConfig::new(2, 2, gmm, vec![3.0, 3.0]).with_snapshot_interval(4),
            metrics.clone(),
        );
        let handle = w.handle.clone();
        let pool = Arc::new(crate::coordinator::scorer::ScorerPool::new(2));
        let router = Router::new(vec![handle.clone()], RoutingPolicy::RoundRobin)
            .with_read_path(pool, metrics.clone());
        let mut rng = Pcg64::seed(11);
        for i in 0..12 {
            let c = i % 2;
            router
                .learn(vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5], c)
                .unwrap();
        }
        let _ = handle.stats().unwrap();
        handle.wait_snapshot_points(12, 1000).expect("snapshot never caught up");
        // With the queue drained and the snapshot caught up, the read
        // class and the sequential path agree bit-for-bit.
        let probe = vec![6.0, 0.0];
        assert_eq!(router.predict_read(&probe).unwrap(), router.predict(&probe).unwrap());
        let snap = handle.snapshot().unwrap();
        let joint = vec![6.0, 0.0, 1.0, 0.0];
        assert!(router.score_read(&joint).unwrap() == snap.log_density(&joint));
        assert_eq!(
            router.score_batch_read(&[joint.clone()]).unwrap(),
            vec![snap.log_density(&joint)]
        );
        let rows = router.predict_batch_read(&[probe.clone(), probe.clone()]).unwrap();
        assert_eq!(rows[0], rows[1]);
        assert_eq!(rows[0], router.predict_read(&probe).unwrap());
        assert!(metrics.snapshot().snapshot_reads >= 4);
        // Malformed reads are clean protocol errors, not scorer panics.
        assert!(matches!(router.predict_read(&[1.0]), Err(CoordError::Protocol(_))));
        assert!(matches!(router.score_read(&[1.0]), Err(CoordError::Protocol(_))));
        drop(router);
        w.join();
    }

    #[test]
    fn predict_read_falls_back_before_first_snapshot() {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let w = Worker::spawn(
            WorkerConfig::new(2, 2, gmm, vec![3.0, 3.0]).with_snapshot_interval(0),
            metrics.clone(),
        );
        let handle = w.handle.clone();
        let pool = Arc::new(crate::coordinator::scorer::ScorerPool::new(1));
        let router = Router::new(vec![handle.clone()], RoutingPolicy::RoundRobin)
            .with_read_path(pool, metrics.clone())
            .with_shape(2, 2);
        let mut rng = Pcg64::seed(12);
        for i in 0..10 {
            let c = i % 2;
            router
                .learn(vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5], c)
                .unwrap();
        }
        let _ = handle.stats().unwrap();
        // Publishing disabled → predicts fall back to the write path…
        assert_eq!(router.predict_read(&[6.0, 0.0]).unwrap(), router.predict(&[6.0, 0.0]).unwrap());
        assert!(metrics.snapshot().snapshot_fallbacks >= 1);
        // …and pure density reads (no sequential equivalent) error out.
        assert!(router.score_read(&[6.0, 0.0, 1.0, 0.0]).is_err());
        // Even with no snapshot, the configured shape rejects malformed
        // reads before they can reach (and panic) the shard worker.
        assert!(matches!(router.predict_read(&[1.0]), Err(CoordError::Protocol(_))));
        drop(router);
        w.join();
    }

    /// Regression: when every shard replies `Failed` (protocol
    /// mismatch), the client gets the failure reason as a clean
    /// protocol error — previously a mismatch could only surface as a
    /// dead-scorer disconnect.
    #[test]
    fn merge_surfaces_shard_failure_reason() {
        let results = vec![ReadResult::Failed("predict: model has no class split".into())];
        match Router::merge_scores(results, 1) {
            Err(CoordError::Protocol(msg)) => assert!(msg.contains("no class split")),
            other => panic!("expected protocol error, got {other:?}"),
        }
        let results = vec![ReadResult::Failed("score: expected 4 dims, got 1".into())];
        match Router::merge_densities(results, 1) {
            Err(CoordError::Protocol(msg)) => assert!(msg.contains("expected 4 dims")),
            other => panic!("expected protocol error, got {other:?}"),
        }
        // A healthy shard still wins over a failed one.
        let results = vec![
            ReadResult::Failed("score: expected 4 dims, got 1".into()),
            ReadResult::Densities(vec![-1.0]),
        ];
        assert_eq!(Router::merge_densities(results, 1).unwrap(), vec![-1.0]);
    }

    #[test]
    fn predict_skips_empty_shards() {
        let (workers, handles) = spawn_shards(2);
        // Train only shard 0.
        let mut rng = Pcg64::seed(3);
        for i in 0..30 {
            let c = i % 2;
            handles[0]
                .learn(vec![c as f64 * 6.0 + rng.normal(), rng.normal()], c)
                .unwrap();
        }
        let router = Router::new(handles.clone(), RoutingPolicy::RoundRobin);
        let scores = router.predict(&[0.0, 0.0]).unwrap();
        assert_eq!(scores.len(), 2);
        drop(router);
        for w in workers {
            w.join();
        }
    }
}
