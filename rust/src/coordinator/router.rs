//! Record routing across model shards.
//!
//! Three policies, matching the three reasons to shard an online learner:
//!
//! - [`RoutingPolicy::RoundRobin`] — throughput: spread learn traffic
//!   evenly; each shard sees a 1/S subsample (online bagging-ish).
//! - [`RoutingPolicy::FeatureHash`] — locality: the same region of input
//!   space always lands on the same shard (piecewise experts).
//! - [`RoutingPolicy::Broadcast`] — redundancy/ensemble: every shard
//!   learns every record; predictions average across shards.
//!
//! Prediction always fans out to every shard and averages the score
//! vectors (for RoundRobin/FeatureHash the shards are partial models;
//! averaging is the natural ensemble read-out).

use super::worker::WorkerHandle;
use super::{CoordError, Result};

/// Shard-selection policy for learn traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    FeatureHash,
    Broadcast,
}

/// Routes one model's traffic over its shard workers.
pub struct Router {
    shards: Vec<WorkerHandle>,
    policy: RoutingPolicy,
    next: std::sync::atomic::AtomicUsize,
}

impl Router {
    pub fn new(shards: Vec<WorkerHandle>, policy: RoutingPolicy) -> Self {
        assert!(!shards.is_empty(), "router needs ≥1 shard");
        Router { shards, policy, next: std::sync::atomic::AtomicUsize::new(0) }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[WorkerHandle] {
        &self.shards
    }

    /// Which shard a learn record goes to (None = all).
    fn pick(&self, features: &[f64]) -> Option<usize> {
        match self.policy {
            RoutingPolicy::Broadcast => None,
            RoutingPolicy::RoundRobin => Some(
                self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.shards.len(),
            ),
            RoutingPolicy::FeatureHash => Some(feature_hash(features) % self.shards.len()),
        }
    }

    /// Route one labeled record.
    pub fn learn(&self, features: Vec<f64>, label: usize) -> Result<()> {
        match self.pick(&features) {
            Some(i) => self.shards[i].learn(features, label),
            None => {
                for s in &self.shards {
                    s.learn(features.clone(), label)?;
                }
                Ok(())
            }
        }
    }

    /// Route one regression record.
    pub fn learn_reg(&self, features: Vec<f64>, targets: Vec<f64>) -> Result<()> {
        match self.pick(&features) {
            Some(i) => self.shards[i].learn_reg(features, targets),
            None => {
                for s in &self.shards {
                    s.learn_reg(features.clone(), targets.clone())?;
                }
                Ok(())
            }
        }
    }

    /// Fan out a regression prediction and average shard targets.
    pub fn predict_reg(&self, features: &[f64]) -> Result<Vec<f64>> {
        let mut acc: Option<Vec<f64>> = None;
        let mut n = 0usize;
        for s in &self.shards {
            match s.stats() {
                Ok(st) if st.components == 0 => continue,
                Err(_) => continue,
                _ => {}
            }
            if let Ok(t) = s.predict_reg(features.to_vec()) {
                n += 1;
                match &mut acc {
                    None => acc = Some(t),
                    Some(a) => {
                        for (x, y) in a.iter_mut().zip(t.iter()) {
                            *x += y;
                        }
                    }
                }
            }
        }
        let mut out = acc.ok_or(CoordError::Rejected("no shard could predict"))?;
        for v in &mut out {
            *v /= n as f64;
        }
        Ok(out)
    }

    /// Fan out a prediction and average shard scores. Shards that have
    /// seen no data yet are skipped; errors only if every shard fails.
    pub fn predict(&self, features: &[f64]) -> Result<Vec<f64>> {
        let mut acc: Option<Vec<f64>> = None;
        let mut n = 0usize;
        for s in &self.shards {
            // A shard with zero components cannot predict.
            match s.stats() {
                Ok(st) if st.components == 0 => continue,
                Err(_) => continue,
                _ => {}
            }
            match s.predict(features.to_vec()) {
                Ok(scores) => {
                    n += 1;
                    match &mut acc {
                        None => acc = Some(scores),
                        Some(a) => {
                            for (x, y) in a.iter_mut().zip(scores.iter()) {
                                *x += y;
                            }
                        }
                    }
                }
                Err(_) => continue,
            }
        }
        let mut scores = acc.ok_or(CoordError::Rejected("no shard could predict"))?;
        for v in &mut scores {
            *v /= n as f64;
        }
        Ok(scores)
    }
}

/// FNV-1a over the raw feature bytes — stable, order-sensitive.
fn feature_hash(features: &[f64]) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for f in features {
        for b in f.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::worker::{Worker, WorkerConfig};
    use crate::gmm::GmmConfig;
    use crate::rng::Pcg64;
    use std::sync::Arc;

    fn spawn_shards(n: usize) -> (Vec<Worker>, Vec<WorkerHandle>) {
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..n {
            let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
            let w = Worker::spawn(WorkerConfig::new(2, 2, gmm, vec![3.0, 3.0]), metrics.clone());
            handles.push(w.handle.clone());
            workers.push(w);
        }
        (workers, handles)
    }

    fn wait_settled(handles: &[WorkerHandle]) {
        // stats() is processed in-order behind all learns.
        for h in handles {
            let _ = h.stats();
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let (workers, handles) = spawn_shards(3);
        let router = Router::new(handles.clone(), RoutingPolicy::RoundRobin);
        let mut rng = Pcg64::seed(1);
        for i in 0..90 {
            let c = i % 2;
            router.learn(vec![rng.normal(), c as f64 * 7.0 + rng.normal()], c).unwrap();
        }
        wait_settled(&handles);
        for h in &handles {
            assert_eq!(h.stats().unwrap().learned, 30);
        }
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn feature_hash_is_sticky() {
        let (workers, handles) = spawn_shards(4);
        let router = Router::new(handles.clone(), RoutingPolicy::FeatureHash);
        // The same vector must always go to the same shard.
        for _ in 0..20 {
            router.learn(vec![1.25, -3.5], 0).unwrap();
        }
        wait_settled(&handles);
        let counts: Vec<u64> = handles.iter().map(|h| h.stats().unwrap().learned).collect();
        assert_eq!(counts.iter().sum::<u64>(), 20);
        assert_eq!(counts.iter().filter(|&&c| c > 0).count(), 1, "counts {counts:?}");
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn broadcast_reaches_all() {
        let (workers, handles) = spawn_shards(2);
        let router = Router::new(handles.clone(), RoutingPolicy::Broadcast);
        let mut rng = Pcg64::seed(2);
        for i in 0..40 {
            let c = i % 2;
            router
                .learn(vec![c as f64 * 6.0 + rng.normal(), c as f64 * 6.0 + rng.normal()], c)
                .unwrap();
        }
        wait_settled(&handles);
        for h in &handles {
            assert_eq!(h.stats().unwrap().learned, 40);
        }
        // Ensemble prediction works and is a distribution.
        let scores = router.predict(&[0.0, 0.0]).unwrap();
        assert_eq!(scores.len(), 2);
        assert!((scores.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        drop(router);
        for w in workers {
            w.join();
        }
    }

    #[test]
    fn predict_skips_empty_shards() {
        let (workers, handles) = spawn_shards(2);
        // Train only shard 0.
        let mut rng = Pcg64::seed(3);
        for i in 0..30 {
            let c = i % 2;
            handles[0]
                .learn(vec![c as f64 * 6.0 + rng.normal(), rng.normal()], c)
                .unwrap();
        }
        let router = Router::new(handles.clone(), RoutingPolicy::RoundRobin);
        let scores = router.predict(&[0.0, 0.0]).unwrap();
        assert_eq!(scores.len(), 2);
        drop(router);
        for w in workers {
            w.join();
        }
    }
}
