//! Size-or-deadline micro-batching.
//!
//! Inference traffic benefits from batching (the XLA scoring artifact
//! consumes fixed B×D tiles; even the native path amortizes per-call
//! overhead), but a lone request must not wait forever — the classic
//! dynamic-batching trade-off. [`Batcher`] accumulates items until either
//! `max_batch` items are pending or the oldest item has waited
//! `max_delay`, then emits a [`Batch`]. Ablation:
//! `benches/ablation_batching.rs`.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// A flushed batch plus the queueing age of its oldest element.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    pub oldest_age: Duration,
}

/// Deterministic, pull-style batcher (no internal threads — the worker
/// loop drives it, keeping the whole pipeline testable without clocks).
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Batcher { cfg, pending: Vec::with_capacity(cfg.max_batch), oldest: None }
    }

    /// Add an item; returns a batch if this push filled it.
    pub fn push(&mut self, item: T) -> Option<Batch<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
        if self.pending.len() >= self.cfg.max_batch {
            return self.flush();
        }
        None
    }

    /// Flush if the deadline for the oldest pending item has passed.
    pub fn poll(&mut self) -> Option<Batch<T>> {
        match self.oldest {
            Some(t) if t.elapsed() >= self.cfg.max_delay && !self.pending.is_empty() => {
                self.flush()
            }
            _ => None,
        }
    }

    /// Unconditional flush (e.g. on shutdown).
    pub fn flush(&mut self) -> Option<Batch<T>> {
        if self.pending.is_empty() {
            return None;
        }
        let oldest_age = self.oldest.map(|t| t.elapsed()).unwrap_or_default();
        self.oldest = None;
        Some(Batch { items: std::mem::take(&mut self.pending), oldest_age })
    }

    /// How long the worker may sleep before the deadline fires.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest.map(|t| self.cfg.max_delay.saturating_sub(t.elapsed()))
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_delay: Duration::from_secs(10) });
        assert!(b.push(1).is_none());
        assert!(b.push(2).is_none());
        let batch = b.push(3).expect("third push must flush");
        assert_eq!(batch.items, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b =
            Batcher::new(BatcherConfig { max_batch: 100, max_delay: Duration::from_millis(5) });
        b.push(7);
        assert!(b.poll().is_none(), "deadline not reached yet");
        std::thread::sleep(Duration::from_millis(8));
        let batch = b.poll().expect("deadline must flush");
        assert_eq!(batch.items, vec![7]);
        assert!(batch.oldest_age >= Duration::from_millis(5));
    }

    #[test]
    fn empty_never_flushes() {
        let mut b = Batcher::<i32>::new(BatcherConfig::default());
        assert!(b.poll().is_none());
        assert!(b.flush().is_none());
        assert!(b.time_to_deadline().is_none());
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b =
            Batcher::new(BatcherConfig { max_batch: 10, max_delay: Duration::from_millis(50) });
        b.push(1);
        let d1 = b.time_to_deadline().unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let d2 = b.time_to_deadline().unwrap();
        assert!(d2 < d1);
    }

    #[test]
    fn flush_resets_age_tracking() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, max_delay: Duration::from_secs(1) });
        b.push(1);
        b.push(2);
        assert_eq!(b.pending(), 0);
        assert!(b.time_to_deadline().is_none());
        b.push(3);
        assert!(b.time_to_deadline().is_some());
    }
}
