//! Scorer pool — the read half of the coordinator's read–write split.
//!
//! Learn traffic is inherently sequential per model shard (each point
//! mutates the state the next point scores against), but scoring is
//! pure: any number of threads can serve `score`/`predict` requests
//! from the same immutable [`ModelSnapshot`]. This module supplies
//! those threads: a fixed pool consuming a bounded queue of
//! [`ReadJob`]s, each carrying an `Arc` to the snapshot it must score
//! against (loaded by the router from the worker's [`SnapshotCell`]
//! *before* enqueueing, so a job is pinned to one model version and
//! never blocks on the learner).
//!
//! Staleness contract: a read served from a snapshot lags the write
//! path by fewer than `snapshot_interval` learn steps (plus one queue
//! timeout when the stream pauses) — see `WorkerConfig::snapshot_interval`.
//!
//! Batch read jobs ([`ReadKind::ScoreBatch`] /
//! [`ReadKind::ClassScoresBatch`]) execute through the snapshot's
//! **query-blocked** batch surfaces (`ModelSnapshot::score_batch` /
//! `class_scores_batch`): each packed component row is streamed once
//! per 32-query block instead of once per point, so a batch read stops
//! paying the per-point matrix re-stream that made the old read path
//! bandwidth-bound at large `D`. Results are unchanged — blocking is
//! bit-identical to mapping the per-point scorers. The event-loop
//! server leans on exactly this guarantee: its per-driver coalescers
//! gather concurrent single-query reads for one model into these batch
//! jobs, so high-concurrency serving rides the blocked kernels without
//! changing a single response byte.
//!
//! [`SnapshotCell`]: super::worker::SnapshotCell

use super::backpressure::{BoundedQueue, OverflowPolicy};
use super::{CoordError, Result};
use crate::gmm::ModelSnapshot;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;

/// What a read job computes against its snapshot. Batch payloads ride
/// in an `Arc` so a multi-shard fan-out shares one copy of the batch
/// instead of cloning it per shard.
pub(crate) enum ReadKind {
    /// Joint log-density of one full joint vector.
    Score { x: Vec<f64> },
    /// Joint log-densities of a batch.
    ScoreBatch { xs: Arc<Vec<Vec<f64>>> },
    /// Classifier scores for one feature vector.
    ClassScores { features: Vec<f64> },
    /// Classifier scores for a batch of feature vectors.
    ClassScoresBatch { xs: Arc<Vec<Vec<f64>>> },
}

/// Result of a read job.
pub(crate) enum ReadResult {
    /// One density per input point (length 1 for `Score`).
    Densities(Vec<f64>),
    /// One score vector per input point (length 1 for `ClassScores`).
    Scores(Vec<Vec<f64>>),
    /// The job could not run against this snapshot (protocol mismatch:
    /// wrong dimensionality, a class-scores request against a model
    /// with no class split, an empty snapshot). A failed job is a clean
    /// *reply* — the router surfaces it to the client as an error
    /// `Response` — never a panic inside a scorer thread.
    Failed(String),
}

/// Run one read job — shared by the pool threads and the router's
/// inline path (no pool attached), so both produce identical results.
///
/// Every request-shape mismatch is validated *before* touching the
/// scoring paths (whose asserts would otherwise panic the thread), so a
/// protocol mismatch comes back as [`ReadResult::Failed`].
pub(crate) fn execute(snap: &ModelSnapshot, kind: ReadKind) -> ReadResult {
    if snap.num_components() == 0 {
        return ReadResult::Failed("snapshot has no components".into());
    }
    let check_dim = |got: usize, want: usize, what: &str| -> Option<ReadResult> {
        if got != want {
            Some(ReadResult::Failed(format!("{what}: expected {want} dims, got {got}")))
        } else {
            None
        }
    };
    match kind {
        ReadKind::Score { x } => {
            if let Some(fail) = check_dim(x.len(), snap.dim(), "score") {
                return fail;
            }
            ReadResult::Densities(vec![snap.log_density(&x)])
        }
        ReadKind::ScoreBatch { xs } => {
            for row in xs.iter() {
                if let Some(fail) = check_dim(row.len(), snap.dim(), "score_batch") {
                    return fail;
                }
            }
            ReadResult::Densities(snap.score_batch(&xs))
        }
        ReadKind::ClassScores { features } => {
            if snap.n_classes() == 0 {
                return ReadResult::Failed("predict: model has no class split".into());
            }
            if let Some(fail) = check_dim(features.len(), snap.n_features(), "predict") {
                return fail;
            }
            ReadResult::Scores(vec![snap.class_scores(&features)])
        }
        ReadKind::ClassScoresBatch { xs } => {
            if snap.n_classes() == 0 {
                return ReadResult::Failed("predict_batch: model has no class split".into());
            }
            for row in xs.iter() {
                if let Some(fail) = check_dim(row.len(), snap.n_features(), "predict_batch") {
                    return fail;
                }
            }
            ReadResult::Scores(snap.class_scores_batch(&xs))
        }
    }
}

pub(crate) struct ReadJob {
    snap: Arc<ModelSnapshot>,
    kind: ReadKind,
    reply: mpsc::Sender<ReadResult>,
}

/// A fixed pool of scorer threads serving snapshot reads. One pool is
/// shared by every model in a [`super::Registry`]; scorers are
/// stateless (all model state rides in on the job's snapshot `Arc`).
pub struct ScorerPool {
    queue: Arc<BoundedQueue<ReadJob>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ScorerPool {
    /// Spawn `threads` scorer threads (at least 1).
    pub fn new(threads: usize) -> ScorerPool {
        let n = threads.max(1);
        // Deep enough that transient bursts queue instead of shedding;
        // Block keeps the read edge lossless under sustained overload.
        let queue = Arc::new(BoundedQueue::new(1024, OverflowPolicy::Block));
        let handles = (0..n)
            .map(|i| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("figmn-scorer-{i}"))
                    .spawn(move || {
                        while let Some(job) = q.pop() {
                            let ReadJob { snap, kind, reply } = job;
                            // Contain panics (malformed input reaching a
                            // scoring assert): the reply sender drops, so
                            // the requester gets a clean "scorer died"
                            // error while this thread keeps serving.
                            if let Ok(result) =
                                catch_unwind(AssertUnwindSafe(|| execute(&snap, kind)))
                            {
                                // The requester may have given up (recv
                                // dropped) — sending then fails harmlessly.
                                let _ = reply.send(result);
                            }
                        }
                    })
                    .expect("spawn scorer")
            })
            .collect();
        ScorerPool { queue, threads: handles }
    }

    /// Scorer threads in the pool.
    pub fn threads(&self) -> usize {
        self.threads.len()
    }

    /// Enqueue a job against `snap`; returns the reply channel so a
    /// caller can fan out one job per shard before collecting any.
    pub(crate) fn submit(
        &self,
        snap: Arc<ModelSnapshot>,
        kind: ReadKind,
    ) -> Result<mpsc::Receiver<ReadResult>> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(ReadJob { snap, kind, reply: tx }) {
            return Err(CoordError::Rejected("scorer queue"));
        }
        Ok(rx)
    }
}

impl Drop for ScorerPool {
    fn drop(&mut self) {
        self.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};

    fn snapshot() -> Arc<ModelSnapshot> {
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        for i in 0..40 {
            let t = (i % 10) as f64 * 0.1;
            m.learn(&[t, -t]);
            m.learn(&[10.0 + t, 10.0 - t]);
        }
        Arc::new(m.snapshot())
    }

    #[test]
    fn pool_results_match_inline_execution() {
        let snap = snapshot();
        let pool = ScorerPool::new(3);
        assert_eq!(pool.threads(), 3);
        let xs = Arc::new(vec![vec![0.0, 0.0], vec![10.0, 10.0], vec![5.0, 5.0]]);
        let rx = pool
            .submit(snap.clone(), ReadKind::ScoreBatch { xs: xs.clone() })
            .unwrap();
        let got = match rx.recv().unwrap() {
            ReadResult::Densities(d) => d,
            _ => panic!("wrong result kind"),
        };
        assert_eq!(got, snap.score_batch(&xs));
    }

    #[test]
    fn malformed_read_is_a_failed_reply_not_a_dead_scorer() {
        let snap = snapshot();
        let pool = ScorerPool::new(1);
        // Wrong-dimension input must come back as a clean Failed reply
        // (previously it tripped a scoring assert and the requester saw
        // only a disconnect), and the same (only) scorer thread must
        // keep serving afterwards.
        let rx = pool
            .submit(snap.clone(), ReadKind::Score { x: vec![1.0] })
            .unwrap();
        match rx.recv().expect("malformed job must reply, not die") {
            ReadResult::Failed(msg) => assert!(msg.contains("expected 2 dims"), "got: {msg}"),
            _ => panic!("expected a Failed reply"),
        }
        let rx = pool
            .submit(snap.clone(), ReadKind::Score { x: vec![0.0, 0.0] })
            .unwrap();
        match rx.recv().expect("pool must survive a failed job") {
            ReadResult::Densities(d) => assert!(d[0].is_finite()),
            _ => panic!("wrong result kind"),
        }
    }

    /// Regression for the read-path protocol mismatch: a class-scores
    /// request against a joint-density snapshot (no class split) used to
    /// panic inside the scorer thread — the client saw "scorer died".
    /// It must instead produce an error reply the router can forward as
    /// an error `Response`, with the thread still alive.
    #[test]
    fn class_scores_without_split_is_failed_reply() {
        let snap = snapshot(); // plain Figmn snapshot: n_classes == 0
        assert_eq!(snap.n_classes(), 0);
        let pool = ScorerPool::new(1);
        let rx = pool
            .submit(snap.clone(), ReadKind::ClassScores { features: vec![0.0, 0.0] })
            .unwrap();
        match rx.recv().expect("mismatched job must reply, not die") {
            ReadResult::Failed(msg) => assert!(msg.contains("no class split"), "got: {msg}"),
            _ => panic!("expected a Failed reply"),
        }
        let xs = Arc::new(vec![vec![0.0, 0.0]]);
        let rx = pool
            .submit(snap.clone(), ReadKind::ClassScoresBatch { xs })
            .unwrap();
        match rx.recv().unwrap() {
            ReadResult::Failed(msg) => assert!(msg.contains("no class split")),
            _ => panic!("expected a Failed reply"),
        }
        // The same scorer thread still serves well-formed traffic.
        let rx = pool
            .submit(snap.clone(), ReadKind::Score { x: vec![0.0, 0.0] })
            .unwrap();
        match rx.recv().expect("pool must survive protocol mismatches") {
            ReadResult::Densities(d) => assert!(d[0].is_finite()),
            _ => panic!("wrong result kind"),
        }
    }

    #[test]
    fn many_concurrent_submitters() {
        let snap = snapshot();
        let pool = Arc::new(ScorerPool::new(2));
        let mut clients = Vec::new();
        for c in 0..6 {
            let pool = pool.clone();
            let snap = snap.clone();
            clients.push(std::thread::spawn(move || {
                let expect = snap.log_density(&[c as f64, c as f64]);
                for _ in 0..50 {
                    let rx = pool
                        .submit(snap.clone(), ReadKind::Score { x: vec![c as f64, c as f64] })
                        .unwrap();
                    match rx.recv().unwrap() {
                        ReadResult::Densities(d) => assert!(d[0] == expect),
                        _ => panic!("wrong result kind"),
                    }
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
    }
}
