//! Durable model checkpoints: one JSON file per (model, shard), written
//! atomically (tmp + rename) so a crash mid-write never corrupts the
//! last good checkpoint.

use super::{CoordError, Result};
use crate::gmm::Figmn;
use crate::json::{parse, Json};
use std::path::{Path, PathBuf};

/// A checkpoint directory.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(CheckpointStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, model: &str, shard: usize) -> PathBuf {
        // Sanitize the model name into a filename.
        let safe: String = model
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.dir.join(format!("{safe}.shard{shard}.json"))
    }

    /// Write a checkpoint document; returns the final path.
    pub fn save(&self, model: &str, shard: usize, doc: &Json) -> Result<String> {
        let path = self.path_for(model, shard);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.to_string_compact())?;
        std::fs::rename(&tmp, &path)?;
        Ok(path.to_string_lossy().into_owned())
    }

    /// Load one shard's model.
    pub fn load(&self, model: &str, shard: usize) -> Result<Figmn> {
        let path = self.path_for(model, shard);
        let text = std::fs::read_to_string(&path)?;
        let doc = parse(&text).map_err(|e| CoordError::Protocol(e.to_string()))?;
        Figmn::from_json(&doc).map_err(CoordError::Protocol)
    }

    /// List checkpointed (model, shard) pairs.
    pub fn list(&self) -> Result<Vec<(String, usize)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".json") {
                if let Some(pos) = stem.rfind(".shard") {
                    if let Ok(shard) = stem[pos + 6..].parse::<usize>() {
                        out.push((stem[..pos].to_string(), shard));
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::{GmmConfig, IncrementalMixture};
    use crate::rng::Pcg64;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("figmn-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn trained() -> Figmn {
        let mut m = Figmn::new(GmmConfig::new(2).with_delta(0.5).with_beta(0.1), &[2.0, 2.0]);
        let mut rng = Pcg64::seed(1);
        for _ in 0..80 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 6.0 };
            m.learn(&[c + rng.normal(), c + rng.normal()]);
        }
        m
    }

    #[test]
    fn save_load_round_trip() {
        let store = CheckpointStore::new(tmpdir("rt")).unwrap();
        let m = trained();
        let path = store.save("my-model", 0, &m.to_json()).unwrap();
        assert!(std::path::Path::new(&path).exists());
        let loaded = store.load("my-model", 0).unwrap();
        assert_eq!(loaded.num_components(), m.num_components());
        assert_eq!(store.list().unwrap(), vec![("my-model".to_string(), 0)]);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn sanitizes_model_names() {
        let store = CheckpointStore::new(tmpdir("san")).unwrap();
        let m = trained();
        let path = store.save("evil/../name", 0, &m.to_json()).unwrap();
        assert!(!path.contains(".."));
        assert!(store.load("evil/../name", 0).is_ok());
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn missing_checkpoint_errors() {
        let store = CheckpointStore::new(tmpdir("miss")).unwrap();
        assert!(store.load("ghost", 0).is_err());
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let store = CheckpointStore::new(tmpdir("corrupt")).unwrap();
        let m = trained();
        let path = store.save("m", 0, &m.to_json()).unwrap();
        std::fs::write(&path, "{not json").unwrap();
        assert!(store.load("m", 0).is_err());
        std::fs::remove_dir_all(store.dir()).unwrap();
    }
}
