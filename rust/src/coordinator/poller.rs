//! Minimal readiness polling over raw `poll(2)`, plus the wake channel
//! the event-loop server uses instead of timeout-based busy polling.
//!
//! The offline vendor set has no `mio`/`libc` crate, but `std` already
//! links the platform libc, so a two-symbol `extern "C"` block is all a
//! readiness loop needs: `poll` for the drivers and `{get,set}rlimit`
//! for the high-connection-count bench. Everything else stays on
//! `std::net`.
//!
//! [`WakePair`] is the self-pipe idiom built from a loopback TCP pair
//! (`pipe(2)` would drag in more FFI surface): the reading end sits in a
//! driver's poll set, and any thread holding the [`WakeHandle`] can make
//! that driver's `poll` return immediately by writing one byte. This is
//! what makes shutdown race-free regardless of the *serving* listener's
//! bind address — the old implementation poked `TcpStream::connect(local_addr)`
//! at the serving socket itself, which is not connectable-as-advertised
//! when bound to `0.0.0.0`. The wake pair is always loopback and never
//! depends on the serving address at all.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::{c_int, c_ulong};
use std::os::unix::io::RawFd;
use std::sync::Arc;

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// `struct pollfd` — layout fixed by POSIX.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> Self {
        PollFd { fd, events, revents: 0 }
    }

    pub fn fd(&self) -> RawFd {
        self.fd
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLHUP | POLLERR) != 0
    }

    pub fn invalid(&self) -> bool {
        self.revents & POLLNVAL != 0
    }
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
}

/// Block until any fd is ready or `timeout_ms` elapses (-1 = no
/// timeout). Returns the number of ready fds; EINTR counts as zero
/// ready (callers loop anyway).
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    // SAFETY: `fds` is a valid, exclusively borrowed slice of
    // repr(C) pollfd records for the duration of the call.
    let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
    if rc < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(err);
    }
    Ok(rc as usize)
}

/// Cross-thread wakeup for a poll loop: a connected loopback TCP pair.
/// The reader participates in the poll set; `WakeHandle::wake` writes a
/// byte from any thread. Cheap (one fd pair per driver) and entirely
/// `std::net`.
pub struct WakePair {
    reader: TcpStream,
    writer: Arc<TcpStream>,
}

impl WakePair {
    pub fn new() -> io::Result<WakePair> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let writer = TcpStream::connect(listener.local_addr()?)?;
        let ours = writer.local_addr()?;
        // Accept until we see our own connection (anything else on the
        // ephemeral port — a stray scanner — is dropped).
        let reader = loop {
            let (s, peer) = listener.accept()?;
            if peer == ours {
                break s;
            }
        };
        reader.set_nonblocking(true)?;
        writer.set_nodelay(true)?;
        Ok(WakePair { reader, writer: Arc::new(writer) })
    }

    pub fn handle(&self) -> WakeHandle {
        WakeHandle(self.writer.clone())
    }

    pub fn reader_fd(&self) -> RawFd {
        use std::os::unix::io::AsRawFd;
        self.reader.as_raw_fd()
    }

    /// Swallow every pending wake byte (level-triggered poll would
    /// otherwise report the reader ready forever).
    pub fn drain(&mut self) {
        let mut sink = [0u8; 64];
        while matches!(self.reader.read(&mut sink), Ok(n) if n > 0) {}
    }
}

/// Clonable, Send + Sync wake trigger.
#[derive(Clone)]
pub struct WakeHandle(Arc<TcpStream>);

impl WakeHandle {
    pub fn wake(&self) {
        // A full socket buffer means wakes are already pending — the
        // failure is harmless and must not block the caller.
        let _ = (&*self.0).write(&[1u8]);
    }
}

// ---- RLIMIT_NOFILE (for the high-connection-count bench) ----

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

#[cfg(target_os = "macos")]
const RLIMIT_NOFILE: c_int = 8;
#[cfg(not(target_os = "macos"))]
const RLIMIT_NOFILE: c_int = 7;

extern "C" {
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

/// Best-effort raise of the fd soft limit to at least `want`; returns
/// the soft limit actually in force afterwards. The serving-concurrency
/// bench calls this before opening thousands of sockets.
pub fn raise_nofile(want: u64) -> u64 {
    let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: `lim` is a valid repr(C) rlimit out-parameter.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 0;
    }
    if lim.rlim_cur >= want {
        return lim.rlim_cur;
    }
    let target = want.min(lim.rlim_max);
    let new = RLimit { rlim_cur: target, rlim_max: lim.rlim_max };
    // SAFETY: `new` is a valid repr(C) rlimit.
    if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
        target
    } else {
        lim.rlim_cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::os::unix::io::AsRawFd;
    use std::time::{Duration, Instant};

    fn tcp_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn poll_reports_readable_after_write() {
        let (mut a, b) = tcp_pair();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        // Nothing written yet: an immediate poll sees nothing.
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        assert!(!fds[0].readable());
        a.write_all(b"x").unwrap();
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
    }

    #[test]
    fn poll_timeout_elapses_without_events() {
        let (_a, b) = tcp_pair();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let t0 = Instant::now();
        assert_eq!(poll_fds(&mut fds, 30).unwrap(), 0);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn wake_pair_unblocks_poll_and_drains() {
        let mut wake = WakePair::new().unwrap();
        let handle = wake.handle();
        let fd = wake.reader_fd();
        let waker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            handle.wake();
        });
        let mut fds = [PollFd::new(fd, POLLIN)];
        let t0 = Instant::now();
        assert_eq!(poll_fds(&mut fds, 5000).unwrap(), 1);
        assert!(t0.elapsed() < Duration::from_secs(4), "wake must beat the timeout");
        waker.join().unwrap();
        wake.drain();
        // Drained: the reader is quiet again.
        let mut fds = [PollFd::new(fd, POLLIN)];
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn wake_handle_is_cheap_to_spam() {
        let mut wake = WakePair::new().unwrap();
        let handle = wake.handle();
        // Far more wakes than the socket buffer holds: must never block.
        for _ in 0..100_000 {
            handle.wake();
        }
        wake.drain();
        let mut fds = [PollFd::new(wake.reader_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn raise_nofile_is_monotone() {
        let before = raise_nofile(0);
        assert!(before > 0);
        let after = raise_nofile(before);
        assert!(after >= before);
    }
}
