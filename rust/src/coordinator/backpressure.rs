//! Bounded queues with explicit overflow policy — every hop in the
//! coordinator uses one, so a slow worker stalls (or sheds) the ingest
//! edge instead of ballooning memory.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// What to do when a push finds the queue full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Block the producer until space frees up (lossless, propagates
    /// backpressure upstream).
    Block,
    /// Reject the new item (load shedding; callers observe `false`).
    DropNewest,
    /// Evict the oldest queued item to make room (bounded staleness).
    DropOldest,
}

/// MPMC bounded queue (mutex + condvars; adequate for the coordinator's
/// hop counts — see benches/ablation_batching.rs for measured overhead).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    policy: OverflowPolicy,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    dropped: u64,
    pushed: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize, policy: OverflowPolicy) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
                dropped: 0,
                pushed: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            policy,
        }
    }

    /// Push an item. Returns `false` if the item was shed (DropNewest on
    /// a full queue) or the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        if g.items.len() >= self.capacity {
            match self.policy {
                OverflowPolicy::Block => {
                    while g.items.len() >= self.capacity && !g.closed {
                        g = self.not_full.wait(g).unwrap();
                    }
                    if g.closed {
                        return false;
                    }
                }
                OverflowPolicy::DropNewest => {
                    g.dropped += 1;
                    return false;
                }
                OverflowPolicy::DropOldest => {
                    g.items.pop_front();
                    g.dropped += 1;
                }
            }
        }
        g.items.push_back(item);
        g.pushed += 1;
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a timeout; `None` on timeout or on closed-and-drained.
    /// Use [`BoundedQueue::pop`] to distinguish — this is for loops that
    /// also service deadlines (the worker's batcher).
    pub fn pop_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.items.pop_front();
        if item.is_some() {
            drop(g);
            self.not_full.notify_one();
        }
        item
    }

    /// Close: producers start failing, consumers drain whatever remains.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether [`BoundedQueue::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (pushed, dropped) counters since creation.
    pub fn stats(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.pushed, g.dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4, OverflowPolicy::Block);
        for i in 0..4 {
            assert!(q.push(i));
        }
        for i in 0..4 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn drop_newest_sheds() {
        let q = BoundedQueue::new(2, OverflowPolicy::DropNewest);
        assert!(q.push(1));
        assert!(q.push(2));
        assert!(!q.push(3)); // shed
        assert_eq!(q.stats(), (2, 1));
        assert_eq!(q.try_pop(), Some(1));
    }

    #[test]
    fn drop_oldest_evicts() {
        let q = BoundedQueue::new(2, OverflowPolicy::DropOldest);
        q.push(1);
        q.push(2);
        assert!(q.push(3));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.stats(), (3, 1));
    }

    #[test]
    fn block_policy_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1, OverflowPolicy::Block));
        q.push(1);
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(1));
        assert!(producer.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_wakes_consumers_and_rejects_producers() {
        let q = Arc::new(BoundedQueue::<i32>::new(2, OverflowPolicy::Block));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
        assert!(!q.push(9));
    }

    #[test]
    fn drains_after_close() {
        let q = BoundedQueue::new(4, OverflowPolicy::Block);
        q.push(1);
        q.push(2);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_under_contention() {
        let q = Arc::new(BoundedQueue::new(8, OverflowPolicy::Block));
        let mut producers = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..100 {
                    q.push(t * 1000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 400);
    }
}
