//! The coordinator's wire protocol: line-delimited JSON requests and
//! responses (one object per line), shared by the TCP server and any
//! in-process client.

use super::metrics::TrafficClass;
use super::CoordError;
use crate::gmm::{LearnMode, ReplicaMode, SearchMode};
use crate::json::{parse, Json};
use crate::linalg::KernelMode;

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Create a named model shard group.
    CreateModel {
        model: String,
        n_features: usize,
        n_classes: usize,
        delta: f64,
        beta: f64,
        /// Per-feature std estimates (σ_ini = δ·std).
        stds: Vec<f64>,
        /// Number of worker shards (ensemble size), ≥ 1.
        shards: usize,
        /// Packed-kernel implementation for every shard's model
        /// (`"strict"` default / `"fast"`; see
        /// [`crate::linalg::KernelMode`]).
        kernel_mode: KernelMode,
        /// Component-axis search strategy for every shard's model
        /// (`"strict"` default / `"topc:C"`; see
        /// [`crate::gmm::SearchMode`]).
        search_mode: SearchMode,
        /// Snapshot read-replica mode for every shard's model
        /// (`"off"` / `"f32"` / `"f32:TOL"`; see
        /// [`crate::gmm::ReplicaMode`]). `None` when the client omitted
        /// the field — the server then applies its own default, so a
        /// `--replica-mode` serve flag covers clients that predate the
        /// field without overriding clients that set it explicitly.
        replica_mode: Option<ReplicaMode>,
        /// Write-path staging for every shard's model (`"online"`
        /// default / `"minibatch:B"`; see [`crate::gmm::LearnMode`]).
        learn_mode: LearnMode,
        /// Per-point `sp` decay factor in `(0, 1]`; `1.0` (default)
        /// disables decay bit-exactly.
        decay: f64,
        /// Evict components not refreshed within this many points;
        /// `0` (default) disables age-based eviction.
        max_age: u64,
    },
    /// Present one labeled example.
    Learn { model: String, features: Vec<f64>, label: usize },
    /// Present a block of labeled examples in one request. Routed and
    /// queued as a unit, so a mini-batch model stages the whole block
    /// through the blocked learn pipeline instead of point-by-point.
    LearnBatch { model: String, xs: Vec<Vec<f64>>, labels: Vec<usize> },
    /// Request class scores for one example (write/sequential class:
    /// observes every learn queued before it).
    Predict { model: String, features: Vec<f64> },
    /// Request class scores from the snapshot read path (`{"op":
    /// "predict","snapshot":true}`): served lock-free from the latest
    /// published model snapshot, lagging learns by fewer than the
    /// model's `snapshot_interval` points; falls back to the sequential
    /// path until a first snapshot exists.
    PredictSnapshot { model: String, features: Vec<f64> },
    /// Joint log-density of one full joint vector (features + output
    /// block), served from the snapshot read path.
    Score { model: String, x: Vec<f64> },
    /// Batched [`Request::Score`].
    ScoreBatch { model: String, xs: Vec<Vec<f64>> },
    /// Batched class scores, served from the snapshot read path.
    PredictBatch { model: String, xs: Vec<Vec<f64>> },
    /// Present one regression example (continuous targets — the paper's
    /// autoassociative mode, §1/§2.4).
    LearnReg { model: String, features: Vec<f64>, targets: Vec<f64> },
    /// Request reconstructed targets for one example.
    PredictReg { model: String, features: Vec<f64> },
    /// Model + coordinator statistics.
    Stats { model: String },
    /// Persist the model to the checkpoint directory.
    Checkpoint { model: String },
    /// Drop the model.
    DropModel { model: String },
    /// Liveness probe.
    Ping,
    /// Graceful server shutdown.
    Shutdown,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Pong,
    Scores { scores: Vec<f64>, class: usize },
    /// Reconstructed continuous targets.
    Targets { targets: Vec<f64> },
    /// Joint log-density (snapshot read path).
    Density { density: f64 },
    /// Batched joint log-densities.
    Densities { densities: Vec<f64> },
    /// Batched class scores + argmax classes.
    ScoresBatch { scores: Vec<Vec<f64>>, classes: Vec<usize> },
    Stats(Json),
    Error(String),
}

impl Request {
    /// Which latency histogram this request feeds (see
    /// [`crate::coordinator::metrics::Metrics::record_request_latency`]):
    /// snapshot-served ops are `Read`, worker-queue ops are `Write`,
    /// lifecycle/introspection is `Control`.
    pub fn traffic_class(&self) -> TrafficClass {
        match self {
            Request::Score { .. }
            | Request::ScoreBatch { .. }
            | Request::PredictSnapshot { .. }
            | Request::PredictBatch { .. } => TrafficClass::Read,
            Request::Learn { .. }
            | Request::LearnBatch { .. }
            | Request::LearnReg { .. }
            | Request::Predict { .. }
            | Request::PredictReg { .. } => TrafficClass::Write,
            Request::CreateModel { .. }
            | Request::Stats { .. }
            | Request::Checkpoint { .. }
            | Request::DropModel { .. }
            | Request::Ping
            | Request::Shutdown => TrafficClass::Control,
        }
    }

    /// Fill in a server-side default for `create_model` requests that
    /// left `replica_mode` unset. Explicit client choices — including
    /// an explicit `"off"` — are never overridden. No-op for every
    /// other request variant.
    pub fn with_default_replica_mode(mut self, default: ReplicaMode) -> Request {
        if let Request::CreateModel { replica_mode, .. } = &mut self {
            replica_mode.get_or_insert(default);
        }
        self
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::CreateModel {
                model,
                n_features,
                n_classes,
                delta,
                beta,
                stds,
                shards,
                kernel_mode,
                search_mode,
                replica_mode,
                learn_mode,
                decay,
                max_age,
            } => {
                let mut fields = vec![
                    ("op", "create_model".into()),
                    ("model", model.as_str().into()),
                    ("n_features", (*n_features).into()),
                    ("n_classes", (*n_classes).into()),
                    ("delta", (*delta).into()),
                    ("beta", (*beta).into()),
                    ("stds", Json::num_array(stds)),
                    ("shards", (*shards).into()),
                    ("kernel_mode", kernel_mode.as_str().into()),
                    ("search_mode", search_mode.to_wire().into()),
                    ("learn_mode", learn_mode.to_wire().into()),
                    ("decay", (*decay).into()),
                    ("max_age", (*max_age as usize).into()),
                ];
                // Emitted only when set, so "client left it to the
                // server default" survives a round trip.
                if let Some(mode) = replica_mode {
                    fields.push(("replica_mode", mode.to_wire().into()));
                }
                Json::obj(fields)
            }
            Request::Learn { model, features, label } => Json::obj(vec![
                ("op", "learn".into()),
                ("model", model.as_str().into()),
                ("features", Json::num_array(features)),
                ("label", (*label).into()),
            ]),
            Request::LearnBatch { model, xs, labels } => Json::obj(vec![
                ("op", "learn_batch".into()),
                ("model", model.as_str().into()),
                ("xs", Json::Arr(xs.iter().map(|x| Json::num_array(x)).collect())),
                (
                    "labels",
                    Json::Arr(labels.iter().map(|&l| Json::from(l)).collect()),
                ),
            ]),
            Request::Predict { model, features } => Json::obj(vec![
                ("op", "predict".into()),
                ("model", model.as_str().into()),
                ("features", Json::num_array(features)),
            ]),
            Request::PredictSnapshot { model, features } => Json::obj(vec![
                ("op", "predict".into()),
                ("model", model.as_str().into()),
                ("features", Json::num_array(features)),
                ("snapshot", true.into()),
            ]),
            Request::Score { model, x } => Json::obj(vec![
                ("op", "score".into()),
                ("model", model.as_str().into()),
                ("x", Json::num_array(x)),
            ]),
            Request::ScoreBatch { model, xs } => Json::obj(vec![
                ("op", "score_batch".into()),
                ("model", model.as_str().into()),
                ("xs", Json::Arr(xs.iter().map(|x| Json::num_array(x)).collect())),
            ]),
            Request::PredictBatch { model, xs } => Json::obj(vec![
                ("op", "predict_batch".into()),
                ("model", model.as_str().into()),
                ("xs", Json::Arr(xs.iter().map(|x| Json::num_array(x)).collect())),
            ]),
            Request::LearnReg { model, features, targets } => Json::obj(vec![
                ("op", "learn_reg".into()),
                ("model", model.as_str().into()),
                ("features", Json::num_array(features)),
                ("targets", Json::num_array(targets)),
            ]),
            Request::PredictReg { model, features } => Json::obj(vec![
                ("op", "predict_reg".into()),
                ("model", model.as_str().into()),
                ("features", Json::num_array(features)),
            ]),
            Request::Stats { model } => {
                Json::obj(vec![("op", "stats".into()), ("model", model.as_str().into())])
            }
            Request::Checkpoint { model } => {
                Json::obj(vec![("op", "checkpoint".into()), ("model", model.as_str().into())])
            }
            Request::DropModel { model } => {
                Json::obj(vec![("op", "drop_model".into()), ("model", model.as_str().into())])
            }
            Request::Ping => Json::obj(vec![("op", "ping".into())]),
            Request::Shutdown => Json::obj(vec![("op", "shutdown".into())]),
        }
    }

    pub fn from_line(line: &str) -> Result<Request, CoordError> {
        let doc = parse(line).map_err(|e| CoordError::Protocol(e.to_string()))?;
        let op = doc
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| CoordError::Protocol("missing op".into()))?;
        let model = || -> Result<String, CoordError> {
            doc.get("model")
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| CoordError::Protocol("missing model".into()))
        };
        let features = || -> Result<Vec<f64>, CoordError> {
            doc.get("features")
                .and_then(Json::to_f64_vec)
                .ok_or_else(|| CoordError::Protocol("missing features".into()))
        };
        let rows = |key: &str| -> Result<Vec<Vec<f64>>, CoordError> {
            doc.get(key)
                .and_then(Json::as_array)
                .and_then(|a| a.iter().map(Json::to_f64_vec).collect::<Option<Vec<_>>>())
                .ok_or_else(|| CoordError::Protocol(format!("missing/malformed {key}")))
        };
        match op {
            "create_model" => {
                let get_n = |k: &str| {
                    doc.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| CoordError::Protocol(format!("missing {k}")))
                };
                let get_f = |k: &str, dflt: f64| {
                    doc.get(k).and_then(Json::as_f64).unwrap_or(dflt)
                };
                let n_features = get_n("n_features")?;
                // Optional kernel mode: absent → Strict; present but
                // unknown → protocol error (don't silently train in
                // the wrong mode).
                let kernel_mode = match doc.get("kernel_mode") {
                    None => KernelMode::Strict,
                    Some(v) => v.as_str().and_then(KernelMode::parse).ok_or_else(|| {
                        CoordError::Protocol("bad kernel_mode (want \"strict\"/\"fast\")".into())
                    })?,
                };
                // Optional search mode, same contract: absent → Strict
                // (exact full-K); present but unknown → protocol error.
                let search_mode = match doc.get("search_mode") {
                    None => SearchMode::Strict,
                    Some(v) => v.as_str().and_then(SearchMode::parse).ok_or_else(|| {
                        CoordError::Protocol(
                            "bad search_mode (want \"strict\"/\"topc:C\")".into(),
                        )
                    })?,
                };
                // Optional replica mode: absent → None (server default
                // decides); present but unknown → protocol error.
                let replica_mode = match doc.get("replica_mode") {
                    None => None,
                    Some(v) => Some(v.as_str().and_then(ReplicaMode::parse).ok_or_else(
                        || {
                            CoordError::Protocol(
                                "bad replica_mode (want \"off\"/\"f32\"/\"f32:TOL\")".into(),
                            )
                        },
                    )?),
                };
                // Optional learn mode, same contract: absent → Online
                // (the pre-mini-batch behavior); present but unknown →
                // protocol error.
                let learn_mode = match doc.get("learn_mode") {
                    None => LearnMode::Online,
                    Some(v) => v.as_str().and_then(LearnMode::parse).ok_or_else(|| {
                        CoordError::Protocol(
                            "bad learn_mode (want \"online\"/\"minibatch:B\")".into(),
                        )
                    })?,
                };
                // Optional drift knobs: absent → disabled; present but
                // out of range → protocol error.
                let decay = match doc.get("decay") {
                    None => 1.0,
                    Some(v) => v
                        .as_f64()
                        .filter(|d| *d > 0.0 && *d <= 1.0)
                        .ok_or_else(|| {
                            CoordError::Protocol("bad decay (want a value in (0, 1])".into())
                        })?,
                };
                let max_age = match doc.get("max_age") {
                    None => 0,
                    Some(v) => v.as_usize().ok_or_else(|| {
                        CoordError::Protocol("bad max_age (want a point count)".into())
                    })? as u64,
                };
                Ok(Request::CreateModel {
                    model: model()?,
                    n_features,
                    n_classes: get_n("n_classes")?,
                    delta: get_f("delta", 0.1),
                    beta: get_f("beta", 0.05),
                    stds: doc
                        .get("stds")
                        .and_then(Json::to_f64_vec)
                        .unwrap_or_else(|| vec![1.0; n_features]),
                    shards: doc.get("shards").and_then(Json::as_usize).unwrap_or(1),
                    kernel_mode,
                    search_mode,
                    replica_mode,
                    learn_mode,
                    decay,
                    max_age,
                })
            }
            "learn" => Ok(Request::Learn {
                model: model()?,
                features: features()?,
                label: doc
                    .get("label")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| CoordError::Protocol("missing label".into()))?,
            }),
            "learn_batch" => {
                let xs = rows("xs")?;
                let labels: Vec<usize> = doc
                    .get("labels")
                    .and_then(Json::as_array)
                    .and_then(|a| {
                        a.iter().map(Json::as_usize).collect::<Option<Vec<_>>>()
                    })
                    .ok_or_else(|| {
                        CoordError::Protocol("missing/malformed labels".into())
                    })?;
                if labels.len() != xs.len() {
                    return Err(CoordError::Protocol(format!(
                        "learn_batch: {} rows but {} labels",
                        xs.len(),
                        labels.len()
                    )));
                }
                Ok(Request::LearnBatch { model: model()?, xs, labels })
            }
            "predict" => {
                let snapshot =
                    doc.get("snapshot").and_then(Json::as_bool).unwrap_or(false);
                if snapshot {
                    Ok(Request::PredictSnapshot { model: model()?, features: features()? })
                } else {
                    Ok(Request::Predict { model: model()?, features: features()? })
                }
            }
            "score" => Ok(Request::Score {
                model: model()?,
                x: doc
                    .get("x")
                    .and_then(Json::to_f64_vec)
                    .ok_or_else(|| CoordError::Protocol("missing x".into()))?,
            }),
            "score_batch" => Ok(Request::ScoreBatch { model: model()?, xs: rows("xs")? }),
            "predict_batch" => Ok(Request::PredictBatch { model: model()?, xs: rows("xs")? }),
            "learn_reg" => Ok(Request::LearnReg {
                model: model()?,
                features: features()?,
                targets: doc
                    .get("targets")
                    .and_then(Json::to_f64_vec)
                    .ok_or_else(|| CoordError::Protocol("missing targets".into()))?,
            }),
            "predict_reg" => Ok(Request::PredictReg { model: model()?, features: features()? }),
            "stats" => Ok(Request::Stats { model: model()? }),
            "checkpoint" => Ok(Request::Checkpoint { model: model()? }),
            "drop_model" => Ok(Request::DropModel { model: model()? }),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(CoordError::Protocol(format!("unknown op '{other}'"))),
        }
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok => Json::obj(vec![("ok", true.into())]),
            Response::Pong => Json::obj(vec![("ok", true.into()), ("pong", true.into())]),
            Response::Scores { scores, class } => Json::obj(vec![
                ("ok", true.into()),
                ("scores", Json::num_array(scores)),
                ("class", (*class).into()),
            ]),
            Response::Targets { targets } => Json::obj(vec![
                ("ok", true.into()),
                ("targets", Json::num_array(targets)),
            ]),
            Response::Density { density } => Json::obj(vec![
                ("ok", true.into()),
                ("density", (*density).into()),
            ]),
            Response::Densities { densities } => Json::obj(vec![
                ("ok", true.into()),
                ("densities", Json::num_array(densities)),
            ]),
            Response::ScoresBatch { scores, classes } => Json::obj(vec![
                ("ok", true.into()),
                ("batch", Json::Arr(scores.iter().map(|s| Json::num_array(s)).collect())),
                (
                    "classes",
                    Json::Arr(classes.iter().map(|&c| Json::from(c)).collect()),
                ),
            ]),
            Response::Stats(j) => {
                Json::obj(vec![("ok", true.into()), ("stats", j.clone())])
            }
            Response::Error(msg) => {
                Json::obj(vec![("ok", false.into()), ("error", msg.as_str().into())])
            }
        }
    }

    pub fn from_line(line: &str) -> Result<Response, CoordError> {
        let doc = parse(line).map_err(|e| CoordError::Protocol(e.to_string()))?;
        let ok = doc.get("ok").and_then(Json::as_bool).unwrap_or(false);
        if !ok {
            return Ok(Response::Error(
                doc.get("error").and_then(Json::as_str).unwrap_or("unknown").to_string(),
            ));
        }
        if doc.get("pong").is_some() {
            return Ok(Response::Pong);
        }
        if let Some(scores) = doc.get("scores").and_then(Json::to_f64_vec) {
            let class = doc.get("class").and_then(Json::as_usize).unwrap_or(0);
            return Ok(Response::Scores { scores, class });
        }
        if let Some(density) = doc.get("density").and_then(Json::as_f64) {
            return Ok(Response::Density { density });
        }
        if let Some(densities) = doc.get("densities").and_then(Json::to_f64_vec) {
            return Ok(Response::Densities { densities });
        }
        if let Some(batch) = doc.get("batch").and_then(Json::as_array) {
            let scores: Option<Vec<Vec<f64>>> =
                batch.iter().map(Json::to_f64_vec).collect();
            let scores =
                scores.ok_or_else(|| CoordError::Protocol("malformed batch".into()))?;
            let classes: Vec<usize> = doc
                .get("classes")
                .and_then(Json::as_array)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            return Ok(Response::ScoresBatch { scores, classes });
        }
        if let Some(targets) = doc.get("targets").and_then(Json::to_f64_vec) {
            return Ok(Response::Targets { targets });
        }
        if let Some(stats) = doc.get("stats") {
            return Ok(Response::Stats(stats.clone()));
        }
        Ok(Response::Ok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trip() {
        let reqs = vec![
            Request::CreateModel {
                model: "m".into(),
                n_features: 2,
                n_classes: 3,
                delta: 0.5,
                beta: 0.01,
                stds: vec![1.0, 2.0],
                shards: 2,
                kernel_mode: KernelMode::Fast,
                search_mode: SearchMode::TopC { c: 16 },
                replica_mode: Some(ReplicaMode::f32_default()),
                learn_mode: LearnMode::MiniBatch { b: 32 },
                decay: 0.995,
                max_age: 5000,
            },
            Request::CreateModel {
                model: "m2".into(),
                n_features: 2,
                n_classes: 3,
                delta: 0.5,
                beta: 0.01,
                stds: vec![1.0, 2.0],
                shards: 1,
                kernel_mode: KernelMode::Strict,
                search_mode: SearchMode::Strict,
                // The omitted-field state must survive a round trip too.
                replica_mode: None,
                learn_mode: LearnMode::Online,
                decay: 1.0,
                max_age: 0,
            },
            Request::Learn { model: "m".into(), features: vec![0.5, -1.0], label: 2 },
            Request::LearnBatch {
                model: "m".into(),
                xs: vec![vec![0.5, -1.0], vec![0.25, 2.0]],
                labels: vec![2, 0],
            },
            Request::Predict { model: "m".into(), features: vec![0.0, 1.0] },
            Request::PredictSnapshot { model: "m".into(), features: vec![0.0, 1.0] },
            Request::Score { model: "m".into(), x: vec![0.0, 1.0, 0.5] },
            Request::ScoreBatch {
                model: "m".into(),
                xs: vec![vec![0.0, 1.0, 0.5], vec![1.0, 0.0, 0.5]],
            },
            Request::PredictBatch {
                model: "m".into(),
                xs: vec![vec![0.0, 1.0], vec![1.0, 0.0]],
            },
            Request::LearnReg {
                model: "m".into(),
                features: vec![0.5],
                targets: vec![1.5, -2.0],
            },
            Request::PredictReg { model: "m".into(), features: vec![0.5] },
            Request::Stats { model: "m".into() },
            Request::Checkpoint { model: "m".into() },
            Request::DropModel { model: "m".into() },
            Request::Ping,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_json().to_string_compact();
            let back = Request::from_line(&line).unwrap();
            assert_eq!(r, back, "via {line}");
        }
    }

    #[test]
    fn response_round_trip() {
        let resps = vec![
            Response::Ok,
            Response::Pong,
            Response::Scores { scores: vec![0.2, 0.8], class: 1 },
            Response::Targets { targets: vec![3.25, -1.0] },
            Response::Density { density: -12.5 },
            Response::Densities { densities: vec![-1.0, -2.5] },
            Response::ScoresBatch {
                scores: vec![vec![0.9, 0.1], vec![0.25, 0.75]],
                classes: vec![0, 1],
            },
            Response::Error("boom".into()),
        ];
        for r in resps {
            let line = r.to_json().to_string_compact();
            let back = Response::from_line(&line).unwrap();
            assert_eq!(r, back, "via {line}");
        }
    }

    #[test]
    fn create_model_defaults() {
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel {
                stds,
                shards,
                delta,
                kernel_mode,
                search_mode,
                replica_mode,
                learn_mode,
                decay,
                max_age,
                ..
            } => {
                assert_eq!(stds, vec![1.0; 3]);
                assert_eq!(shards, 1);
                assert!(delta > 0.0);
                assert_eq!(kernel_mode, KernelMode::Strict);
                assert_eq!(search_mode, SearchMode::Strict);
                assert_eq!(replica_mode, None, "absent field leaves the server default");
                assert_eq!(learn_mode, LearnMode::Online);
                assert_eq!(decay, 1.0);
                assert_eq!(max_age, 0);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn create_model_learn_mode_and_drift_knobs_parse_and_reject_bad() {
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"learn_mode":"minibatch:8","decay":0.99,"max_age":1000}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel { learn_mode, decay, max_age, .. } => {
                assert_eq!(learn_mode, LearnMode::MiniBatch { b: 8 });
                assert_eq!(decay, 0.99);
                assert_eq!(max_age, 1000);
            }
            _ => panic!("wrong variant"),
        }
        // Unknown modes and out-of-range knobs are protocol errors, not
        // silent online/no-decay fallbacks.
        for bad in [
            r#""learn_mode":"turbo""#,
            r#""learn_mode":"minibatch:0""#,
            r#""learn_mode":7"#,
            r#""decay":0"#,
            r#""decay":1.5"#,
            r#""decay":"fast""#,
            r#""max_age":"soon""#,
        ] {
            let line = format!(
                r#"{{"op":"create_model","model":"m","n_features":3,"n_classes":2,{bad}}}"#
            );
            assert!(Request::from_line(&line).is_err(), "{line}");
        }
    }

    #[test]
    fn learn_batch_rejects_length_mismatch_and_missing_labels() {
        assert!(Request::from_line(
            r#"{"op":"learn_batch","model":"m","xs":[[1.0],[2.0]],"labels":[0]}"#,
        )
        .is_err());
        assert!(Request::from_line(
            r#"{"op":"learn_batch","model":"m","xs":[[1.0]]}"#,
        )
        .is_err());
        assert!(Request::from_line(
            r#"{"op":"learn_batch","model":"m","xs":[[1.0]],"labels":[-1]}"#,
        )
        .is_err());
    }

    #[test]
    fn create_model_search_mode_parses_and_rejects_unknown() {
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"search_mode":"topc:32"}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel { search_mode, .. } => {
                assert_eq!(search_mode, SearchMode::TopC { c: 32 })
            }
            _ => panic!("wrong variant"),
        }
        // Unknown strategies and degenerate C are protocol errors, not
        // silent strict fallbacks.
        for bad in ["\"near\"", "\"topc:0\"", "\"topc:\"", "7"] {
            let line = format!(
                r#"{{"op":"create_model","model":"m","n_features":3,"n_classes":2,"search_mode":{bad}}}"#
            );
            assert!(Request::from_line(&line).is_err(), "{line}");
        }
    }

    #[test]
    fn create_model_kernel_mode_parses_and_rejects_unknown() {
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"kernel_mode":"fast"}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel { kernel_mode, .. } => {
                assert_eq!(kernel_mode, KernelMode::Fast)
            }
            _ => panic!("wrong variant"),
        }
        assert!(Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"kernel_mode":"warp"}"#,
        )
        .is_err());
    }

    #[test]
    fn create_model_replica_mode_parses_and_rejects_unknown() {
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"replica_mode":"f32:0.005"}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel { replica_mode, .. } => {
                assert_eq!(replica_mode, Some(ReplicaMode::F32 { tol: 0.005 }))
            }
            _ => panic!("wrong variant"),
        }
        // An explicit "off" is distinct from an absent field: it pins
        // the model to replica-off even under a server f32 default.
        let r = Request::from_line(
            r#"{"op":"create_model","model":"m","n_features":3,"n_classes":2,"replica_mode":"off"}"#,
        )
        .unwrap();
        match r {
            Request::CreateModel { replica_mode, .. } => {
                assert_eq!(replica_mode, Some(ReplicaMode::Off))
            }
            _ => panic!("wrong variant"),
        }
        // Unknown modes and degenerate tolerances are protocol errors,
        // not silent off fallbacks.
        for bad in ["\"f16\"", "\"f32:0\"", "\"f32:\"", "\"f32:nan\"", "7"] {
            let line = format!(
                r#"{{"op":"create_model","model":"m","n_features":3,"n_classes":2,"replica_mode":{bad}}}"#
            );
            assert!(Request::from_line(&line).is_err(), "{line}");
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(Request::from_line("not json").is_err());
        assert!(Request::from_line(r#"{"op":"zap"}"#).is_err());
        assert!(Request::from_line(r#"{"op":"learn","model":"m"}"#).is_err());
        assert!(Request::from_line(r#"{"op":"learn","features":[1],"label":0}"#).is_err());
        assert!(Request::from_line(r#"{"op":"score","model":"m"}"#).is_err());
        assert!(Request::from_line(r#"{"op":"score_batch","model":"m","xs":[1]}"#).is_err());
        assert!(Request::from_line(r#"{"op":"predict_batch","model":"m"}"#).is_err());
    }

    #[test]
    fn traffic_classes_partition_the_ops() {
        use TrafficClass::*;
        let cases = vec![
            (Request::Score { model: "m".into(), x: vec![] }, Read),
            (Request::ScoreBatch { model: "m".into(), xs: vec![] }, Read),
            (Request::PredictSnapshot { model: "m".into(), features: vec![] }, Read),
            (Request::PredictBatch { model: "m".into(), xs: vec![] }, Read),
            (Request::Learn { model: "m".into(), features: vec![], label: 0 }, Write),
            (Request::LearnBatch { model: "m".into(), xs: vec![], labels: vec![] }, Write),
            (Request::LearnReg { model: "m".into(), features: vec![], targets: vec![] }, Write),
            (Request::Predict { model: "m".into(), features: vec![] }, Write),
            (Request::PredictReg { model: "m".into(), features: vec![] }, Write),
            (Request::Stats { model: "m".into() }, Control),
            (Request::Checkpoint { model: "m".into() }, Control),
            (Request::DropModel { model: "m".into() }, Control),
            (Request::Ping, Control),
            (Request::Shutdown, Control),
        ];
        for (req, want) in cases {
            assert_eq!(req.traffic_class(), want, "{req:?}");
        }
    }

    #[test]
    fn predict_snapshot_flag_selects_read_class() {
        let r = Request::from_line(
            r#"{"op":"predict","model":"m","features":[1.0],"snapshot":true}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::PredictSnapshot { .. }));
        let r = Request::from_line(
            r#"{"op":"predict","model":"m","features":[1.0],"snapshot":false}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::Predict { .. }));
    }
}
