//! Descriptive statistics: means, standard deviations, and the streaming
//! Welford accumulator used for dataset statistics and bench reporting.

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() as f64 - 1.0);
    var.sqrt()
}

/// Per-column standard deviations of a row-major data matrix — used for
/// the paper's `σ_ini = δ·std(x)` initialization (Eq. 13).
///
/// Uses the *population* (n denominator) convention, matching the
/// streaming estimate an online learner would form; columns with zero
/// spread get std 1.0 so `σ_ini` stays positive (the paper's "estimate is
/// fine" escape hatch, §2.2).
pub fn column_stds(rows: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rows.is_empty(), "column_stds: empty dataset");
    let d = rows[0].len();
    let n = rows.len() as f64;
    let mut means = vec![0.0; d];
    for r in rows {
        for (m, v) in means.iter_mut().zip(r.iter()) {
            *m += v;
        }
    }
    for m in &mut means {
        *m /= n;
    }
    let mut vars = vec![0.0; d];
    for r in rows {
        for j in 0..d {
            let e = r[j] - means[j];
            vars[j] += e * e;
        }
    }
    vars.iter()
        .map(|v| {
            let s = (v / n).sqrt();
            if s > 0.0 {
                s
            } else {
                1.0
            }
        })
        .collect()
}

/// Welford's online mean/variance — numerically stable single-pass
/// accumulator, used by the coordinator's metrics and stream statistics.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n as f64 - 1.0)
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_rel;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_rel(mean(&xs), 5.0, 1e-15);
        assert_rel(std_dev(&xs), 2.13809, 1e-5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.5, -0.3, 2.2, 8.1, 0.0, -4.4];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_rel(w.mean(), mean(&xs), 1e-14);
        assert_rel(w.std_dev(), std_dev(&xs), 1e-12);
        assert_eq!(w.min(), -4.4);
        assert_eq!(w.max(), 8.1);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin() * 3.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        a.merge(&b);
        assert_rel(a.mean(), all.mean(), 1e-12);
        assert_rel(a.variance(), all.variance(), 1e-12);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn column_stds_constant_column_gets_one() {
        let rows = vec![vec![1.0, 5.0], vec![1.0, 7.0], vec![1.0, 9.0]];
        let s = column_stds(&rows);
        assert_eq!(s[0], 1.0);
        assert!(s[1] > 1.0);
    }
}
