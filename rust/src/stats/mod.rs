//! Statistical substrate.
//!
//! The IGMN update criterion needs the χ² quantile `χ²_{D,1−β}` (paper
//! §2.1); the evaluation methodology needs paired t-tests at p = 0.05
//! (Tables 2–4) and descriptive statistics. No statistics crate is in the
//! offline vendor set, so the special functions are implemented here:
//! Lanczos log-gamma, regularized incomplete gamma (series + continued
//! fraction), the χ² quantile via bracketed Newton, and the Student-t CDF
//! via the regularized incomplete beta function.

mod descriptive;
mod gamma;
mod student;

pub use descriptive::{column_stds, mean, std_dev, Welford};
pub use gamma::{chi2_cdf, chi2_quantile, ln_gamma, reg_gamma_lower, reg_gamma_upper};
pub use student::{paired_t_test, student_t_cdf, PairedTResult};
