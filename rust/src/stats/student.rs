//! Student-t distribution and the paired t-test used for the paper's
//! significance marks (`○`/`●` in Tables 2–4, p = 0.05).

use super::gamma::ln_gamma;

/// Regularized incomplete beta function `I_x(a, b)` via the Lentz
/// continued fraction (double precision).
pub fn reg_inc_beta(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "reg_inc_beta domain");
    assert!((0.0..=1.0).contains(&x), "reg_inc_beta: x in [0,1]");
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // Use the symmetry that keeps the continued fraction convergent.
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - ln_front.exp() * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    h
}

/// Student-t CDF with `nu` degrees of freedom.
pub fn student_t_cdf(nu: f64, t: f64) -> f64 {
    assert!(nu > 0.0);
    let x = nu / (nu + t * t);
    let p = 0.5 * reg_inc_beta(0.5 * nu, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Result of a two-sided paired t-test.
#[derive(Debug, Clone, Copy)]
pub struct PairedTResult {
    pub t_stat: f64,
    pub dof: f64,
    /// Two-sided p-value.
    pub p_value: f64,
    /// Mean of the differences `a − b`.
    pub mean_diff: f64,
}

impl PairedTResult {
    /// Significance mark matching the paper's table convention at the
    /// given α: `'●'` = significant decrease (b < a), `'○'` = significant
    /// increase (b > a), `' '` otherwise.
    pub fn mark(&self, alpha: f64) -> char {
        if self.p_value >= alpha || !self.p_value.is_finite() {
            ' '
        } else if self.mean_diff > 0.0 {
            '●' // second sample significantly smaller
        } else {
            '○'
        }
    }
}

/// Two-sided paired t-test over paired samples `a` and `b`.
///
/// Degenerate inputs (fewer than 2 pairs, or zero variance of the
/// differences) report `p = 1` when the means agree and `p = 0` when a
/// constant nonzero difference makes the outcome certain.
pub fn paired_t_test(a: &[f64], b: &[f64]) -> PairedTResult {
    assert_eq!(a.len(), b.len(), "paired_t_test: unpaired samples");
    let n = a.len();
    if n < 2 {
        return PairedTResult { t_stat: 0.0, dof: 0.0, p_value: 1.0, mean_diff: 0.0 };
    }
    let diffs: Vec<f64> = a.iter().zip(b.iter()).map(|(x, y)| x - y).collect();
    let mean = diffs.iter().sum::<f64>() / n as f64;
    let var = diffs.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / (n as f64 - 1.0);
    let dof = n as f64 - 1.0;
    if var <= 0.0 {
        let p = if mean == 0.0 { 1.0 } else { 0.0 };
        return PairedTResult { t_stat: if mean == 0.0 { 0.0 } else { f64::INFINITY }, dof, p_value: p, mean_diff: mean };
    }
    let se = (var / n as f64).sqrt();
    let t = mean / se;
    let p = 2.0 * (1.0 - student_t_cdf(dof, t.abs()));
    PairedTResult { t_stat: t, dof, p_value: p.clamp(0.0, 1.0), mean_diff: mean }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_rel;

    #[test]
    fn t_cdf_symmetry() {
        for &nu in &[1.0, 5.0, 30.0] {
            for &t in &[0.0, 0.7, 2.1] {
                assert_rel(student_t_cdf(nu, t) + student_t_cdf(nu, -t), 1.0, 1e-12);
            }
        }
    }

    #[test]
    fn t_cdf_reference_values() {
        // R: pt(2.0, 10) = 0.9633060, pt(1.0, 1) = 0.75
        assert_rel(student_t_cdf(10.0, 2.0), 0.963306, 1e-5);
        assert_rel(student_t_cdf(1.0, 1.0), 0.75, 1e-10);
        // Large nu → normal: pt(1.96, 1e6) ≈ 0.975
        assert!((student_t_cdf(1e6, 1.959964) - 0.975).abs() < 1e-4);
    }

    #[test]
    fn inc_beta_complement() {
        for &(a, b, x) in &[(2.0, 3.0, 0.4), (0.5, 0.5, 0.7), (10.0, 2.0, 0.9)] {
            assert_rel(reg_inc_beta(a, b, x) + reg_inc_beta(b, a, 1.0 - x), 1.0, 1e-12);
        }
    }

    #[test]
    fn paired_t_obvious_difference() {
        let a = [10.0, 11.0, 10.5, 10.2, 10.8];
        let b = [1.0, 1.2, 0.9, 1.1, 1.0];
        let r = paired_t_test(&a, &b);
        assert!(r.p_value < 0.001);
        assert_eq!(r.mark(0.05), '●'); // b significantly smaller
    }

    #[test]
    fn paired_t_no_difference() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let r = paired_t_test(&a, &a);
        assert_eq!(r.p_value, 1.0);
        assert_eq!(r.mark(0.05), ' ');
    }

    #[test]
    fn paired_t_reference_value() {
        // scipy.stats.ttest_rel([1,2,3,4,5],[2,2,3,4,7]) →
        // t = -1.5, p = 0.2080
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 3.0, 4.0, 7.0];
        let r = paired_t_test(&a, &b);
        assert_rel(r.t_stat, -1.5, 1e-10);
        assert_rel(r.p_value, 0.20800, 1e-4);
    }

    #[test]
    fn mark_direction() {
        let slow = [2.0, 2.1, 2.2, 1.9, 2.0];
        let fast = [1.0, 1.1, 1.0, 0.9, 1.0];
        assert_eq!(paired_t_test(&slow, &fast).mark(0.05), '●');
        assert_eq!(paired_t_test(&fast, &slow).mark(0.05), '○');
    }
}
