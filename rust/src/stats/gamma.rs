//! Gamma-family special functions and the χ² distribution.

/// Lanczos approximation of `ln Γ(x)` for `x > 0`.
///
/// g = 7, n = 9 coefficients; |relative error| < 1e-13 over the domain
/// used in this crate (degrees of freedom up to several thousand).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain: x > 0, got {x}");
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1−x) = π/sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma `P(a, x) = γ(a,x)/Γ(a)`.
///
/// Series expansion for `x < a+1`, continued fraction otherwise
/// (Numerical Recipes style, to double precision).
pub fn reg_gamma_lower(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "reg_gamma_lower domain (a={a}, x={x})");
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series: P(a,x) = e^{-x} x^a / Γ(a) · Σ x^n / (a·(a+1)…(a+n))
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-16 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        1.0 - reg_gamma_upper_cf(a, x)
    }
}

/// Regularized upper incomplete gamma `Q(a, x) = 1 − P(a, x)`.
pub fn reg_gamma_upper(a: f64, x: f64) -> f64 {
    if x < a + 1.0 {
        1.0 - reg_gamma_lower(a, x)
    } else {
        reg_gamma_upper_cf(a, x)
    }
}

/// Continued-fraction evaluation of Q(a,x), valid for `x ≥ a+1`.
fn reg_gamma_upper_cf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// χ² CDF with `k` degrees of freedom.
pub fn chi2_cdf(k: f64, x: f64) -> f64 {
    assert!(k > 0.0);
    if x <= 0.0 {
        return 0.0;
    }
    reg_gamma_lower(0.5 * k, 0.5 * x)
}

/// χ² quantile: smallest `x` with `CDF(k, x) ≥ p`.
///
/// This is the paper's update threshold `χ²_{D,1−β}` (§2.1). Solved by a
/// Wilson–Hilferty initial guess refined with bracketed Newton; accurate to
/// ~1e-10 relative over `k ∈ [1, 10⁴]`, `p ∈ (1e-12, 1−1e-12)`.
pub fn chi2_quantile(k: f64, p: f64) -> f64 {
    assert!(k > 0.0, "chi2_quantile: dof must be positive");
    assert!((0.0..1.0).contains(&p), "chi2_quantile: p in [0,1), got {p}");
    if p == 0.0 {
        return 0.0;
    }
    // Wilson–Hilferty: χ²ₖ ≈ k·(1 − 2/(9k) + z·sqrt(2/(9k)))³
    let z = normal_quantile(p);
    let t = 1.0 - 2.0 / (9.0 * k) + z * (2.0 / (9.0 * k)).sqrt();
    let mut x = (k * t * t * t).max(1e-8);

    // Newton with bracketing on the CDF.
    let (mut lo, mut hi) = (0.0_f64, f64::INFINITY);
    for _ in 0..100 {
        let f = chi2_cdf(k, x) - p;
        if f > 0.0 {
            hi = hi.min(x);
        } else {
            lo = lo.max(x);
        }
        // pdf(k, x)
        let ln_pdf = (0.5 * k - 1.0) * x.ln() - 0.5 * x - 0.5 * k * 2.0_f64.ln() - ln_gamma(0.5 * k);
        let pdf = ln_pdf.exp();
        let step = if pdf > 1e-300 { f / pdf } else { 0.0 };
        let mut next = x - step;
        if !(next > lo && (hi.is_infinite() || next < hi)) {
            next = if hi.is_finite() { 0.5 * (lo + hi) } else { lo * 2.0 + 1.0 };
        }
        if (next - x).abs() <= 1e-12 * x.max(1.0) {
            return next;
        }
        x = next;
    }
    x
}

/// Standard normal quantile (Acklam's rational approximation, |ε|<1.15e-9,
/// plenty for the Wilson–Hilferty seed which Newton then polishes).
fn normal_quantile(p: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&p) && p > 0.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_rel;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert_rel(ln_gamma(5.0), 24.0_f64.ln(), 1e-12);
        assert_rel(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-12);
        // Recurrence Γ(x+1) = x·Γ(x)
        for &x in &[0.3, 1.7, 9.2, 123.4] {
            assert_rel(ln_gamma(x + 1.0), ln_gamma(x) + x.ln(), 1e-12);
        }
    }

    #[test]
    fn incomplete_gamma_complementary() {
        for &a in &[0.5, 1.0, 3.7, 50.0, 392.0] {
            for &x in &[0.1, 1.0, a, 2.0 * a + 3.0] {
                let p = reg_gamma_lower(a, x);
                let q = reg_gamma_upper(a, x);
                assert_rel(p + q, 1.0, 1e-12);
                assert!((0.0..=1.0).contains(&p));
            }
        }
    }

    #[test]
    fn chi2_cdf_known() {
        // χ²₂ CDF(x) = 1 − e^{−x/2} exactly.
        for &x in &[0.5, 1.0, 3.0, 10.0] {
            assert_rel(chi2_cdf(2.0, x), 1.0 - (-x / 2.0f64).exp(), 1e-12);
        }
        // Median of χ²₁ ≈ 0.4549
        assert!((chi2_cdf(1.0, 0.454936) - 0.5).abs() < 1e-5);
    }

    #[test]
    fn chi2_quantile_round_trip() {
        for &k in &[1.0, 2.0, 9.0, 34.0, 784.0, 3072.0] {
            for &p in &[0.001, 0.05, 0.5, 0.9, 0.999] {
                let x = chi2_quantile(k, p);
                assert_rel(chi2_cdf(k, x), p, 1e-8);
            }
        }
    }

    #[test]
    fn chi2_quantile_reference_values() {
        // R: qchisq(0.95, 10) = 18.30704, qchisq(0.9, 9) = 14.68366,
        //    qchisq(0.99, 1) = 6.634897
        assert_rel(chi2_quantile(10.0, 0.95), 18.307038, 1e-6);
        assert_rel(chi2_quantile(9.0, 0.9), 14.683657, 1e-6);
        assert_rel(chi2_quantile(1.0, 0.99), 6.634897, 1e-6);
    }

    #[test]
    fn chi2_quantile_monotone_in_p() {
        let k = 34.0;
        let mut prev = 0.0;
        for i in 1..20 {
            let p = i as f64 / 20.0;
            let x = chi2_quantile(k, p);
            assert!(x > prev);
            prev = x;
        }
    }

    #[test]
    fn paper_threshold_beta() {
        // Paper §2.1: threshold χ²_{D,1−β} with e.g. β=0.1. Sanity at D=4
        // (iris): must be a modest positive number and increase with D.
        let t4 = chi2_quantile(4.0, 1.0 - 0.1);
        let t784 = chi2_quantile(784.0, 1.0 - 0.1);
        assert!(t4 > 6.0 && t4 < 9.0, "t4={t4}"); // qchisq(.9,4)=7.779
        assert!(t784 > 784.0, "t784={t784}");
    }
}
