//! PJRT/XLA runtime — loads and executes the AOT artifacts produced by
//! `python/compile/aot.py` (`make artifacts`). Python never runs here:
//! the interchange is HLO **text** (see aot.py's module docstring for
//! why), compiled once per process by the PJRT CPU client and cached.
//!
//! Threading note: the `xla` crate's `PjRtClient` is `Rc`-based (neither
//! `Send` nor `Sync`), so a [`Runtime`] is confined to the thread that
//! created it. The coordinator gives each worker thread its own runtime.

mod exec;
mod manifest;
mod state;

pub use exec::{LearnExec, LearnOutput, PredictExec, ScoreExec, ScoreOutput};
pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};
pub use state::PackedState;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Errors from artifact loading/execution.
#[derive(Debug)]
pub enum RuntimeError {
    Io(std::io::Error),
    Manifest(String),
    Xla(String),
    MissingArtifact { config: String, kind: ArtifactKind },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Io(e) => write!(f, "io: {e}"),
            RuntimeError::Manifest(m) => write!(f, "manifest: {m}"),
            RuntimeError::Xla(m) => write!(f, "xla: {m}"),
            RuntimeError::MissingArtifact { config, kind } => {
                write!(f, "no '{kind:?}' artifact for config '{config}' (run `make artifacts`)")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// A PJRT CPU client plus a compile-once cache over the artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<(String, ArtifactKind), Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The default artifact directory: `$FIGMN_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("FIGMN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_executable(
        &self,
        config: &str,
        kind: ArtifactKind,
    ) -> Result<(Rc<xla::PjRtLoadedExecutable>, ArtifactMeta)> {
        let meta = self
            .manifest
            .find(config, kind)
            .ok_or_else(|| RuntimeError::MissingArtifact { config: config.to_string(), kind })?
            .clone();
        let key = (config.to_string(), kind);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok((exe.clone(), meta));
        }
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok((exe, meta))
    }

    /// Typed scoring entry point for a shape config.
    pub fn score_exec(&self, config: &str) -> Result<ScoreExec> {
        let (exe, meta) = self.load_executable(config, ArtifactKind::Score)?;
        Ok(ScoreExec::new(exe, meta))
    }

    /// Typed learn-step entry point for a shape config.
    pub fn learn_exec(&self, config: &str) -> Result<LearnExec> {
        let (exe, meta) = self.load_executable(config, ArtifactKind::Learn)?;
        Ok(LearnExec::new(exe, meta))
    }

    /// Typed conditional-inference entry point for a shape config.
    pub fn predict_exec(&self, config: &str) -> Result<PredictExec> {
        let (exe, meta) = self.load_executable(config, ArtifactKind::Predict)?;
        Ok(PredictExec::new(exe, meta))
    }
}

#[cfg(test)]
pub(crate) fn artifacts_available() -> bool {
    Runtime::default_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_runtime_and_list() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        assert!(rt.manifest().artifacts().len() >= 4);
        assert!(rt.manifest().find("quickstart", ArtifactKind::Learn).is_some());
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        if !artifacts_available() {
            return;
        }
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let err = rt.score_exec("no-such-config").err().expect("must fail");
        assert!(matches!(err, RuntimeError::MissingArtifact { .. }));
    }
}
