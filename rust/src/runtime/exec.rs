//! Typed wrappers over the compiled PJRT executables.
//!
//! Argument order must match `aot.py` exactly; shapes are validated here
//! so a mismatched artifact fails loudly at the boundary rather than
//! deep inside XLA.

use super::manifest::ArtifactMeta;
use super::state::PackedState;
use super::{Result, RuntimeError};
use std::rc::Rc;

fn literal_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Build an N-d f32 literal. (§Perf RT-1 note: a single-copy
/// `create_from_shape_and_untyped_data` variant was ~25% faster on small
/// shapes but triggered nondeterministic `shape_util` CHECK failures in
/// xla_extension 0.5.1 — reverted to the proven vec1+reshape pair.)
fn literal_nd(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Output of [`ScoreExec::score`].
#[derive(Debug, Clone)]
pub struct ScoreOutput {
    /// (B×K) row-major squared Mahalanobis distances.
    pub d2: Vec<f32>,
    /// (B×K) row-major log-likelihoods `ln p(x|j)`.
    pub log_liks: Vec<f32>,
    /// (B×K) row-major posteriors `p(j|x)`.
    pub posteriors: Vec<f32>,
    pub batch: usize,
    pub capacity: usize,
}

/// Batched scoring (Eqs. 2–3/22) on the XLA path.
///
/// §Perf RT-2 note: a device-resident-state variant (upload the K·D²
/// tensors once via `buffer_from_host_literal`, then `execute_b` per
/// batch) measured 2–3× faster marshalling but segfaults
/// nondeterministically — the crate's `execute_b` on the CPU client
/// aliases input buffers into outputs, so dropping results invalidates
/// the cached state. Reverted; literal-per-call is the safe floor on
/// this binding.
pub struct ScoreExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
}

impl ScoreExec {
    pub(super) fn new(exe: Rc<xla::PjRtLoadedExecutable>, meta: ArtifactMeta) -> Self {
        ScoreExec { exe, meta }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Score exactly `meta.batch` points (pad the tail of a short final
    /// batch with zeros and ignore those rows).
    pub fn score(&self, xs: &[f32], state: &PackedState) -> Result<ScoreOutput> {
        let (b, d, k) = (self.meta.batch, self.meta.dim, self.meta.capacity);
        if xs.len() != b * d {
            return Err(RuntimeError::Manifest(format!(
                "score: xs must be {b}×{d} = {} floats, got {}",
                b * d,
                xs.len()
            )));
        }
        check_state(state, k, d)?;
        let args = [
            literal_nd(xs, &[b as i64, d as i64])?,
            literal_nd(&state.mus, &[k as i64, d as i64])?,
            literal_nd(&state.lambdas, &[k as i64, d as i64, d as i64])?,
            literal_1d(&state.log_dets),
            literal_1d(&state.sps),
            literal_1d(&state.mask),
        ];
        let out = run(&self.exe, &args)?;
        if out.len() != 3 {
            return Err(RuntimeError::Xla(format!("score: expected 3 outputs, got {}", out.len())));
        }
        Ok(ScoreOutput {
            d2: to_f32_vec(&out[0])?,
            log_liks: to_f32_vec(&out[1])?,
            posteriors: to_f32_vec(&out[2])?,
            batch: b,
            capacity: k,
        })
    }
}

/// Output of [`LearnExec::learn`].
#[derive(Debug, Clone)]
pub struct LearnOutput {
    pub state: PackedState,
    /// True if an existing component was updated; false if one was created.
    pub updated: bool,
}

/// One full Algorithm-1 step (Eqs. 4–12, 20–26) on the XLA path.
pub struct LearnExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
}

impl LearnExec {
    pub(super) fn new(exe: Rc<xla::PjRtLoadedExecutable>, meta: ArtifactMeta) -> Self {
        LearnExec { exe, meta }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn learn(
        &self,
        x: &[f32],
        state: &PackedState,
        chi2_thresh: f32,
        sigma_ini: &[f32],
    ) -> Result<LearnOutput> {
        let (d, k) = (self.meta.dim, self.meta.capacity);
        if x.len() != d || sigma_ini.len() != d {
            return Err(RuntimeError::Manifest(format!(
                "learn: x/sigma_ini must have {d} elements"
            )));
        }
        check_state(state, k, d)?;
        let args = [
            literal_1d(x),
            literal_nd(&state.mus, &[k as i64, d as i64])?,
            literal_nd(&state.lambdas, &[k as i64, d as i64, d as i64])?,
            literal_1d(&state.log_dets),
            literal_1d(&state.sps),
            literal_1d(&state.vs),
            literal_1d(&state.mask),
            literal_scalar(chi2_thresh),
            literal_1d(sigma_ini),
        ];
        let out = run(&self.exe, &args)?;
        if out.len() != 7 {
            return Err(RuntimeError::Xla(format!("learn: expected 7 outputs, got {}", out.len())));
        }
        let new_state = PackedState {
            capacity: k,
            dim: d,
            mus: to_f32_vec(&out[0])?,
            lambdas: to_f32_vec(&out[1])?,
            log_dets: to_f32_vec(&out[2])?,
            sps: to_f32_vec(&out[3])?,
            vs: to_f32_vec(&out[4])?,
            mask: to_f32_vec(&out[5])?,
        };
        let updated = to_f32_vec(&out[6])?[0] > 0.5;
        Ok(LearnOutput { state: new_state, updated })
    }
}

/// Batched conditional-mean inference (Eqs. 14 + 27) on the XLA path.
pub struct PredictExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
}

impl PredictExec {
    pub(super) fn new(exe: Rc<xla::PjRtLoadedExecutable>, meta: ArtifactMeta) -> Self {
        PredictExec { exe, meta }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// `xs_known`: (B × n_known) row-major. Returns (B × (D − n_known))
    /// row-major reconstructions.
    pub fn predict(&self, xs_known: &[f32], state: &PackedState) -> Result<Vec<f32>> {
        let (b, d, k, i) = (self.meta.batch, self.meta.dim, self.meta.capacity, self.meta.n_known);
        if xs_known.len() != b * i {
            return Err(RuntimeError::Manifest(format!(
                "predict: xs_known must be {b}×{i} floats, got {}",
                xs_known.len()
            )));
        }
        check_state(state, k, d)?;
        let args = [
            literal_nd(xs_known, &[b as i64, i as i64])?,
            literal_nd(&state.mus, &[k as i64, d as i64])?,
            literal_nd(&state.lambdas, &[k as i64, d as i64, d as i64])?,
            literal_1d(&state.log_dets),
            literal_1d(&state.sps),
            literal_1d(&state.mask),
        ];
        let out = run(&self.exe, &args)?;
        to_f32_vec(&out[0])
    }
}

fn check_state(state: &PackedState, k: usize, d: usize) -> Result<()> {
    if state.capacity != k || state.dim != d {
        return Err(RuntimeError::Manifest(format!(
            "state shape (K={}, D={}) != artifact (K={k}, D={d})",
            state.capacity, state.dim
        )));
    }
    Ok(())
}
