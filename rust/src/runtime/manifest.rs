//! `artifacts/manifest.json` — the contract between `aot.py` and the
//! Rust runtime.

use super::{Result, RuntimeError};
use crate::json::{parse, Json};
use std::path::Path;

/// Entry-point kind of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Score,
    Learn,
    Predict,
}

impl ArtifactKind {
    fn from_str(s: &str) -> Option<Self> {
        match s {
            "score" => Some(ArtifactKind::Score),
            "learn" => Some(ArtifactKind::Learn),
            "predict" => Some(ArtifactKind::Predict),
            _ => None,
        }
    }
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub config: String,
    pub kind: ArtifactKind,
    pub file: String,
    /// Joint dimensionality D.
    pub dim: usize,
    /// Component capacity K.
    pub capacity: usize,
    /// Scoring/predict batch size B.
    pub batch: usize,
    /// Known-block size for predict (i; targets are D − i).
    pub n_known: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = parse(text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        if doc.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(RuntimeError::Manifest("unknown manifest format".into()));
        }
        let version = doc.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        if version != 1.0 {
            return Err(RuntimeError::Manifest(format!("unsupported version {version}")));
        }
        let arr = doc
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| RuntimeError::Manifest("missing artifacts".into()))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for (i, a) in arr.iter().enumerate() {
            let get_s = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| RuntimeError::Manifest(format!("artifact {i}: missing {k}")))
            };
            let get_n = |k: &str| {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| RuntimeError::Manifest(format!("artifact {i}: missing {k}")))
            };
            let kind_s = get_s("kind")?;
            let kind = ArtifactKind::from_str(&kind_s)
                .ok_or_else(|| RuntimeError::Manifest(format!("artifact {i}: bad kind {kind_s}")))?;
            artifacts.push(ArtifactMeta {
                config: get_s("config")?,
                kind,
                file: get_s("file")?,
                dim: get_n("dim")?,
                capacity: get_n("capacity")?,
                batch: get_n("batch")?,
                n_known: get_n("n_known")?,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    pub fn find(&self, config: &str, kind: ArtifactKind) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.config == config && a.kind == kind)
    }

    /// Distinct config names, in manifest order.
    pub fn configs(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for a in &self.artifacts {
            if !out.contains(&a.config.as_str()) {
                out.push(&a.config);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "version": 1,
      "artifacts": [
        {"config": "q", "kind": "score", "file": "q.score.hlo.txt",
         "dim": 6, "capacity": 8, "batch": 16, "n_known": 4},
        {"config": "q", "kind": "learn", "file": "q.learn.hlo.txt",
         "dim": 6, "capacity": 8, "batch": 16, "n_known": 4}
      ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts().len(), 2);
        let a = m.find("q", ArtifactKind::Learn).unwrap();
        assert_eq!(a.dim, 6);
        assert_eq!(a.capacity, 8);
        assert!(m.find("q", ArtifactKind::Predict).is_none());
        assert_eq!(m.configs(), vec!["q"]);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"format":"hlo-text","version":99,"artifacts":[]}"#).is_err());
        assert!(Manifest::parse(
            r#"{"format":"hlo-text","version":1,"artifacts":[{"kind":"bogus"}]}"#
        )
        .is_err());
    }
}
