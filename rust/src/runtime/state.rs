//! Padded f32 state — the wire format between the Rust side and the XLA
//! artifacts (fixed capacity K, activity mask as 0.0/1.0 f32; see
//! aot.py's boundary note).

use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};

/// The mixture state, padded to capacity and flattened for PJRT literals.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedState {
    pub capacity: usize,
    pub dim: usize,
    /// (K·D) row-major.
    pub mus: Vec<f32>,
    /// (K·D·D) row-major.
    pub lambdas: Vec<f32>,
    /// (K,)
    pub log_dets: Vec<f32>,
    /// (K,)
    pub sps: Vec<f32>,
    /// (K,)
    pub vs: Vec<f32>,
    /// (K,) 0.0 / 1.0
    pub mask: Vec<f32>,
}

impl PackedState {
    /// Fresh, all-inactive state.
    pub fn empty(capacity: usize, dim: usize) -> Self {
        PackedState {
            capacity,
            dim,
            mus: vec![0.0; capacity * dim],
            lambdas: vec![0.0; capacity * dim * dim],
            log_dets: vec![0.0; capacity],
            sps: vec![0.0; capacity],
            vs: vec![0.0; capacity],
            mask: vec![0.0; capacity],
        }
    }

    /// Number of active components.
    pub fn active(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Pack a native [`Figmn`] into the wire format (f64 → f32).
    /// Panics if the model has more components than `capacity`.
    pub fn from_figmn(model: &Figmn, capacity: usize) -> Self {
        let dim = model.dim();
        let k = model.num_components();
        assert!(k <= capacity, "model has {k} components > capacity {capacity}");
        let mut s = PackedState::empty(capacity, dim);
        for j in 0..k {
            let mean = model.component_mean(j);
            for (i, &v) in mean.iter().enumerate() {
                s.mus[j * dim + i] = v as f32;
            }
            let lam = model.component_lambda(j);
            for (i, &v) in lam.as_slice().iter().enumerate() {
                s.lambdas[j * dim * dim + i] = v as f32;
            }
            s.log_dets[j] = model.component_log_det(j) as f32;
            let (sp, v) = model.component_stats(j);
            s.sps[j] = sp as f32;
            s.vs[j] = v as f32;
            s.mask[j] = 1.0;
        }
        s
    }

    /// Unpack into a native [`Figmn`] (f32 → f64), e.g. after running
    /// learn steps on the XLA path. `cfg`/`stds` must describe the same
    /// joint space the state was built for.
    pub fn to_figmn(&self, cfg: GmmConfig, stds: &[f64], points: u64) -> Figmn {
        use crate::linalg::Matrix;
        let mut model = Figmn::new(cfg, stds);
        let d = self.dim;
        {
            let comps = model.components_mut();
            for j in 0..self.capacity {
                if self.mask[j] < 0.5 {
                    continue;
                }
                let mean: Vec<f64> =
                    self.mus[j * d..(j + 1) * d].iter().map(|&v| v as f64).collect();
                let flat: Vec<f64> = self.lambdas[j * d * d..(j + 1) * d * d]
                    .iter()
                    .map(|&v| v as f64)
                    .collect();
                comps.push(crate::gmm::new_precision_component(
                    mean,
                    Matrix::from_vec(d, d, flat),
                    self.log_dets[j] as f64,
                    self.sps[j] as f64,
                    self.vs[j] as u64,
                ));
            }
        }
        let _ = points; // points counter is advisory; Figmn tracks its own
        model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};
    use crate::rng::Pcg64;

    fn trained() -> Figmn {
        let cfg = GmmConfig::new(3).with_delta(0.5).with_beta(0.1);
        let mut m = Figmn::new(cfg, &[2.0; 3]);
        let mut rng = Pcg64::seed(1);
        for _ in 0..100 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 6.0 };
            let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        m
    }

    #[test]
    fn pack_round_trip() {
        let m = trained();
        let k = m.num_components();
        let packed = PackedState::from_figmn(&m, 8);
        assert_eq!(packed.active(), k);
        let cfg = GmmConfig::new(3).with_delta(0.5).with_beta(0.1);
        let back = packed.to_figmn(cfg, &[2.0; 3], 100);
        assert_eq!(back.num_components(), k);
        // f32 round-trip: posteriors agree to f32 precision.
        let mut rng = Pcg64::seed(2);
        for _ in 0..10 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            let a = m.posteriors(&x);
            let b = back.posteriors(&x);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn capacity_overflow_panics() {
        let m = trained();
        PackedState::from_figmn(&m, 1.min(m.num_components() - 1));
    }
}
