//! Padded f32 state — the wire format between the Rust side and the XLA
//! artifacts (fixed capacity K, activity mask as 0.0/1.0 f32; see
//! aot.py's boundary note).

use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};

/// The mixture state, padded to capacity and flattened for PJRT literals.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedState {
    pub capacity: usize,
    pub dim: usize,
    /// (K·D) row-major.
    pub mus: Vec<f32>,
    /// (K·D·D) row-major.
    pub lambdas: Vec<f32>,
    /// (K,)
    pub log_dets: Vec<f32>,
    /// (K,)
    pub sps: Vec<f32>,
    /// (K,)
    pub vs: Vec<f32>,
    /// (K,) 0.0 / 1.0
    pub mask: Vec<f32>,
}

impl PackedState {
    /// Fresh, all-inactive state.
    pub fn empty(capacity: usize, dim: usize) -> Self {
        PackedState {
            capacity,
            dim,
            mus: vec![0.0; capacity * dim],
            lambdas: vec![0.0; capacity * dim * dim],
            log_dets: vec![0.0; capacity],
            sps: vec![0.0; capacity],
            vs: vec![0.0; capacity],
            mask: vec![0.0; capacity],
        }
    }

    /// Number of active components.
    pub fn active(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Pack a native [`Figmn`] into the wire format (f64 → f32).
    /// Panics if the model has more components than `capacity`.
    ///
    /// The model stores each Λ as a packed upper triangle; both
    /// triangles of the dense wire matrix are written from it directly
    /// (no intermediate dense `Matrix` allocation on the XLA flush
    /// path).
    pub fn from_figmn(model: &Figmn, capacity: usize) -> Self {
        use crate::linalg::packed::row_start;
        let dim = model.dim();
        let k = model.num_components();
        assert!(k <= capacity, "model has {k} components > capacity {capacity}");
        let mut s = PackedState::empty(capacity, dim);
        let store = model.store();
        for j in 0..k {
            for (i, &v) in store.mean(j).iter().enumerate() {
                s.mus[j * dim + i] = v as f32;
            }
            let ap = store.mat(j);
            let dense = &mut s.lambdas[j * dim * dim..(j + 1) * dim * dim];
            for r in 0..dim {
                let rs = row_start(r, dim);
                for c in r..dim {
                    let v = ap[rs + (c - r)] as f32;
                    dense[r * dim + c] = v;
                    dense[c * dim + r] = v;
                }
            }
            s.log_dets[j] = store.log_det(j) as f32;
            s.sps[j] = store.sp(j) as f32;
            s.vs[j] = store.v(j) as f32;
            s.mask[j] = 1.0;
        }
        s
    }

    /// Unpack into a native [`Figmn`] (f32 → f64), e.g. after running
    /// learn steps on the XLA path. `cfg`/`stds` must describe the same
    /// joint space the state was built for. The wire format carries the
    /// dense f32 matrix; only its upper triangle enters the model's
    /// packed arenas. Producers are expected to keep it symmetric
    /// ([`PackedState::from_figmn`] always does); debug builds assert
    /// this, while release builds trust the wire contract and use the
    /// upper triangle as authoritative.
    pub fn to_figmn(&self, cfg: GmmConfig, stds: &[f64], points: u64) -> Figmn {
        use crate::linalg::packed::pack_symmetric_slice;
        let mut model = Figmn::new(cfg, stds);
        let d = self.dim;
        {
            let store = model.store_mut();
            for j in 0..self.capacity {
                if self.mask[j] < 0.5 {
                    continue;
                }
                let mean: Vec<f64> =
                    self.mus[j * d..(j + 1) * d].iter().map(|&v| v as f64).collect();
                let flat: Vec<f64> = self.lambdas[j * d * d..(j + 1) * d * d]
                    .iter()
                    .map(|&v| v as f64)
                    .collect();
                #[cfg(debug_assertions)]
                for r in 0..d {
                    for c in r + 1..d {
                        debug_assert!(
                            flat[r * d + c] == flat[c * d + r],
                            "to_figmn: asymmetric wire Λ for component {j} at ({r},{c})"
                        );
                    }
                }
                store.push(
                    &mean,
                    &pack_symmetric_slice(&flat, d),
                    self.log_dets[j] as f64,
                    self.sps[j] as f64,
                    self.vs[j] as u64,
                );
            }
        }
        let _ = points; // points counter is advisory; Figmn tracks its own
        model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};
    use crate::rng::Pcg64;

    fn trained() -> Figmn {
        let cfg = GmmConfig::new(3).with_delta(0.5).with_beta(0.1);
        let mut m = Figmn::new(cfg, &[2.0; 3]);
        let mut rng = Pcg64::seed(1);
        for _ in 0..100 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 6.0 };
            let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        m
    }

    #[test]
    fn pack_round_trip() {
        let m = trained();
        let k = m.num_components();
        let packed = PackedState::from_figmn(&m, 8);
        assert_eq!(packed.active(), k);
        let cfg = GmmConfig::new(3).with_delta(0.5).with_beta(0.1);
        let back = packed.to_figmn(cfg, &[2.0; 3], 100);
        assert_eq!(back.num_components(), k);
        // f32 round-trip: posteriors agree to f32 precision.
        let mut rng = Pcg64::seed(2);
        for _ in 0..10 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            let a = m.posteriors(&x);
            let b = back.posteriors(&x);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn capacity_overflow_panics() {
        let m = trained();
        PackedState::from_figmn(&m, 1.min(m.num_components() - 1));
    }
}
