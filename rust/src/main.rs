//! `figmn` — command-line launcher for the FIGMN streaming framework.
//!
//! Subcommands:
//!   datasets                       print the paper's Table 1 (+ synth status)
//!   train   <dataset> [opts]       single-pass online training + holdout eval
//!   serve   [opts]                 start the TCP coordinator
//!   client  <addr> <line...>       send protocol lines to a server
//!   artifacts                      list AOT artifacts and smoke-compile them
//!   version
//!
//! (Arg parsing is hand-rolled: the offline vendor set has no `clap` —
//! DESIGN.md §5.)

use figmn::coordinator::{serve, CheckpointStore, Metrics, Registry, ServerConfig};
use figmn::data::synth::{self, TABLE1};
use figmn::data::Dataset;
use figmn::engine::EngineConfig;
use figmn::eval::{multiclass_auc, Stopwatch};
use figmn::gmm::supervised::{supervised_figmn, supervised_igmn};
use figmn::gmm::{GmmConfig, KernelMode, LearnMode, ReplicaMode, SearchMode};
use figmn::rng::Pcg64;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("datasets") => cmd_datasets(),
        Some("train") => cmd_train(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        Some("artifacts") => cmd_artifacts(),
        Some("version") => {
            println!("figmn {}", figmn::version());
            0
        }
        _ => {
            eprintln!(
                "usage: figmn <datasets|train|serve|client|artifacts|version>\n\
                 \n  figmn train iris --delta 1 --beta 0.001 --algo fast\
                 \n  figmn serve --addr 127.0.0.1:7464 --checkpoints ckpts/ \
                 \n              [--drivers N] [--max-line-bytes B] [--no-coalesce] \
                 \n              [--batch-max B] [--batch-delay-ms MS] \
                 \n              [--replica-mode off|f32[:TOL]]\
                 \n  figmn client 127.0.0.1:7464 '{{\"op\":\"ping\"}}'"
            );
            2
        }
    };
    std::process::exit(code);
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (positional, flags)
}

fn cmd_datasets() -> i32 {
    println!("{:<16} {:>9} {:>10} {:>7}   generator", "dataset", "N", "D", "classes");
    for s in &TABLE1 {
        println!(
            "{:<16} {:>9} {:>10} {:>7}   {:?}",
            s.name, s.instances, s.attributes, s.classes, s.kind
        );
    }
    println!("\n(synthetic stand-ins with the paper's exact shapes — DESIGN.md §5)");
    0
}

fn cmd_train(args: &[String]) -> i32 {
    let (pos, flags) = parse_flags(args);
    let Some(name) = pos.first() else {
        eprintln!(
            "usage: figmn train <dataset> [--delta D] [--beta B] [--algo fast|orig] \
             [--seed N] [--threads T] [--kernel-mode strict|fast] \
             [--search-mode strict|topc:C] [--replica-mode off|f32[:TOL]] \
             [--learn-mode online|minibatch:B] [--decay RATE] [--max-age AGE]"
        );
        return 2;
    };
    let Some(spec) = synth::spec(name) else {
        eprintln!("unknown dataset '{name}' (see `figmn datasets`)");
        return 2;
    };
    let delta: f64 = flags.get("delta").map(|s| s.parse().unwrap()).unwrap_or(0.1);
    let beta: f64 = flags.get("beta").map(|s| s.parse().unwrap()).unwrap_or(0.05);
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap()).unwrap_or(42);
    let algo = flags.get("algo").map(String::as_str).unwrap_or("fast");
    // Component-sharded engine threads (1 = serial; results identical).
    let threads: usize = flags.get("threads").map(|s| s.parse().unwrap()).unwrap_or(1);
    let engine = (threads > 1).then(|| EngineConfig::new(threads));
    // Packed-kernel mode: strict (default, bit-identical scalar loops)
    // or fast (blocked SIMD lanes, tolerance-equivalent).
    let kernel_mode = match flags.get("kernel-mode").map(String::as_str) {
        None => KernelMode::Strict,
        Some(s) => match KernelMode::parse(s) {
            Some(m) => m,
            None => {
                eprintln!("unknown --kernel-mode '{s}' (want strict|fast)");
                return 2;
            }
        },
    };
    // Component-axis search: strict (default, exact full-K sweeps) or
    // topc:C (candidate-index search, tolerance-gated — see
    // figmn::gmm::SearchMode).
    let search_mode = match flags.get("search-mode").map(String::as_str) {
        None => SearchMode::Strict,
        Some(s) => match SearchMode::parse(s) {
            Some(m) => m,
            None => {
                eprintln!("unknown --search-mode '{s}' (want strict|topc:C with C >= 1)");
                return 2;
            }
        },
    };
    // f32 read-replica tier for published snapshots (off by default;
    // write-path arithmetic is unaffected — see figmn::gmm::ReplicaMode).
    let replica_mode = match flags.get("replica-mode").map(String::as_str) {
        None => ReplicaMode::Off,
        Some(s) => match ReplicaMode::parse(s) {
            Some(m) => m,
            None => {
                eprintln!("unknown --replica-mode '{s}' (want off|f32|f32:TOL with TOL > 0)");
                return 2;
            }
        },
    };

    // Staged mini-batch learn mode (online = default, bit-identical
    // legacy path; minibatch:B stages B-point blocks through the
    // blocked distance pass — see figmn::gmm::learn_pipeline).
    let learn_mode = match flags.get("learn-mode").map(String::as_str) {
        None => LearnMode::Online,
        Some(s) => match LearnMode::parse(s) {
            Some(m) => m,
            None => {
                eprintln!("unknown --learn-mode '{s}' (want online|minibatch:B with B >= 1)");
                return 2;
            }
        },
    };
    // Drift-adaptive knobs: per-point sp decay in (0, 1] (1.0 = off)
    // and component max-age eviction (0 = off).
    let decay: f64 = match flags.get("decay").map(|s| s.parse::<f64>()) {
        None => 1.0,
        Some(Ok(d)) if d > 0.0 && d <= 1.0 => d,
        Some(_) => {
            eprintln!("bad --decay (want a rate in (0, 1]; 1.0 disables decay)");
            return 2;
        }
    };
    let max_age: u64 = match flags.get("max-age").map(|s| s.parse::<u64>()) {
        None => 0,
        Some(Ok(a)) => a,
        Some(Err(_)) => {
            eprintln!("bad --max-age (want a point count; 0 disables eviction)");
            return 2;
        }
    };

    let data = synth::generate(spec, seed);
    let stds = data.feature_stds();
    let mut rng = Pcg64::seed(seed);
    let order = rng.permutation(data.len());
    let split = data.len() * 4 / 5;
    let (train_idx, test_idx) = order.split_at(split);
    let train: Dataset = data.subset(train_idx);
    let test: Dataset = data.subset(test_idx);

    // The covariance baseline always runs strict (Cholesky) kernels;
    // report the mode that actually executes instead of echoing the
    // flag back.
    let effective_mode = if algo == "orig" { KernelMode::Strict } else { kernel_mode };
    if algo == "orig" && kernel_mode != effective_mode {
        eprintln!("note: --algo orig always runs strict kernels; ignoring --kernel-mode fast");
    }
    // Likewise: the baseline has no candidate index.
    let effective_search = if algo == "orig" { SearchMode::Strict } else { search_mode };
    if algo == "orig" && search_mode != effective_search {
        eprintln!("note: --algo orig always sweeps full-K; ignoring --search-mode");
    }
    // ... and no staged learn pipeline.
    let effective_learn = if algo == "orig" { LearnMode::Online } else { learn_mode };
    if algo == "orig" && learn_mode != effective_learn {
        eprintln!("note: --algo orig always learns online; ignoring --learn-mode");
    }

    let cfg = GmmConfig::new(1)
        .with_delta(delta)
        .with_beta(beta)
        .with_kernel_mode(effective_mode)
        .with_search_mode(effective_search)
        .with_replica_mode(replica_mode)
        .with_learn_mode(effective_learn)
        .with_decay(decay)
        .with_max_age(max_age);
    let mut sw = Stopwatch::new();
    let (scores, components): (Vec<Vec<f64>>, usize) = if algo == "orig" {
        let mut clf = supervised_igmn(cfg, &stds, data.n_classes);
        clf.model_mut().set_engine(engine);
        sw.time(|| clf.train_batch(&train.features, &train.labels));
        (clf.class_scores_batch(&test.features), clf.num_components())
    } else {
        let mut clf = supervised_figmn(cfg, &stds, data.n_classes);
        clf.model_mut().set_engine(engine);
        sw.time(|| clf.train_batch(&train.features, &train.labels));
        (clf.class_scores_batch(&test.features), clf.num_components())
    };

    let auc = multiclass_auc(&scores, &test.labels, data.n_classes);
    let acc = scores
        .iter()
        .zip(test.labels.iter())
        .filter(|(s, &t)| {
            s.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 == t
        })
        .count() as f64
        / test.len() as f64;
    println!(
        "{name}: algo={algo} kernels={effective_mode} search={effective_search} \
         learn={effective_learn} N_train={} D={} → {} components, train {:.3}s, \
         AUC {:.3}, acc {:.3}",
        train.len(),
        data.dim(),
        components,
        sw.seconds(),
        auc,
        acc
    );
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let (_, flags) = parse_flags(args);
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7464".into());
    let metrics = Arc::new(Metrics::new());
    let mut registry = Registry::new(metrics);
    if let Some(dir) = flags.get("checkpoints") {
        match CheckpointStore::new(dir) {
            Ok(store) => registry = registry.with_checkpoints(store),
            Err(e) => {
                eprintln!("cannot open checkpoint dir: {e}");
                return 1;
            }
        }
    }
    let parse_num = |key: &str| flags.get(key).and_then(|v| v.parse::<usize>().ok());
    let mut cfg = ServerConfig {
        addr,
        xla_config: flags.get("xla").cloned(),
        ..ServerConfig::default()
    };
    if let Some(n) = parse_num("drivers") {
        cfg.drivers = n;
    }
    if let Some(n) = parse_num("max-line-bytes") {
        cfg.max_line_bytes = n;
    }
    if flags.contains_key("no-coalesce") {
        cfg.coalesce = false;
    }
    if let Some(n) = parse_num("batch-max") {
        cfg.batch.max_batch = n.max(1);
    }
    if let Some(ms) = parse_num("batch-delay-ms") {
        cfg.batch.max_delay = std::time::Duration::from_millis(ms as u64);
    }
    if let Some(s) = flags.get("replica-mode") {
        match ReplicaMode::parse(s) {
            Some(m) => cfg.replica_mode = m,
            None => {
                eprintln!("unknown --replica-mode '{s}' (want off|f32|f32:TOL with TOL > 0)");
                return 2;
            }
        }
    }
    match serve(Arc::new(registry), cfg) {
        Ok(server) => {
            println!("figmn coordinator listening on {}", server.local_addr);
            println!("(send {{\"op\":\"shutdown\"}} to stop)");
            // Park until a client's shutdown op flips the flag, then
            // join the drivers (the event loop's wake pair makes this
            // race-free for any bind address, 0.0.0.0 included).
            while !server.shutdown_requested() {
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
            server.shutdown();
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_client(args: &[String]) -> i32 {
    use std::io::{BufRead, BufReader, Write};
    let Some(addr) = args.first() else {
        eprintln!("usage: figmn client <addr> <json-line> [...]");
        return 2;
    };
    let Ok(stream) = std::net::TcpStream::connect(addr) else {
        eprintln!("cannot connect to {addr}");
        return 1;
    };
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for line in &args[1..] {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut buf = String::new();
        if reader.read_line(&mut buf).is_err() || buf.is_empty() {
            eprintln!("connection closed");
            return 1;
        }
        print!("{buf}");
    }
    0
}

fn cmd_artifacts() -> i32 {
    use figmn::runtime::Runtime;
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {dir:?}; run `make artifacts`");
        return 1;
    }
    match Runtime::open(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for a in rt.manifest().artifacts() {
                println!(
                    "  {:<12} {:<8} D={:<5} K={:<4} B={:<4} i={:<5} {}",
                    a.config, format!("{:?}", a.kind), a.dim, a.capacity, a.batch, a.n_known, a.file
                );
            }
            // Smoke-compile the first config's score artifact.
            if let Some(meta) = rt.manifest().artifacts().first() {
                let cfgname = meta.config.clone();
                match rt.score_exec(&cfgname) {
                    Ok(_) => println!("compile check: OK ({cfgname})"),
                    Err(e) => {
                        eprintln!("compile check FAILED: {e}");
                        return 1;
                    }
                }
            }
            0
        }
        Err(e) => {
            eprintln!("cannot open artifacts: {e}");
            1
        }
    }
}
