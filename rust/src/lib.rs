//! # figmn — A Fast Incremental Gaussian Mixture Model
//!
//! Production reproduction of Pinto & Engel, *"A Fast Incremental Gaussian
//! Mixture Model"*, PLOS ONE 2015 (DOI 10.1371/journal.pone.0139931).
//!
//! The paper reformulates the Incremental Gaussian Mixture Network (IGMN)
//! to work directly on **precision matrices** via Sherman–Morrison rank-one
//! updates (and on determinants via the Matrix Determinant Lemma), cutting
//! the learning complexity from `O(NKD³)` to `O(NKD²)` while producing the
//! *same* model as the covariance-based original.
//!
//! ## Crate layout
//!
//! - [`linalg`] — dense linear algebra substrate (no external BLAS).
//! - [`stats`] — special functions (χ² quantile, lgamma), Student-t,
//!   paired t-tests, descriptive statistics.
//! - [`rng`] — deterministic PCG-based random numbers and samplers.
//! - [`json`] — minimal JSON substrate (protocol, checkpoints, manifest).
//! - [`gmm`] — the paper's algorithms: [`gmm::Igmn`] (covariance baseline,
//!   `O(D³)`) and [`gmm::Figmn`] (precision-matrix fast version, `O(D²)`).
//! - [`engine`] — the component-sharded parallel execution engine: a
//!   fixed pool of `std::thread` workers (each with its own scratch
//!   arena) that splits the K components across threads for the
//!   Mahalanobis pass and the fused Sherman–Morrison update, feeding
//!   the batch API (`learn_batch` / `score_batch` / `predict_batch`).
//! - [`data`] — dataset substrate: synthetic generators matching the
//!   paper's Table 1, CSV/ARFF parsing, normalization, record streams.
//! - [`baselines`] — Table 4 comparators: dropout MLP, 1-NN, Gaussian
//!   naive Bayes, linear SVM (Pegasos).
//! - [`eval`] — 2-fold cross-validation, AUC, timing, significance marks.
//! - [`runtime`] — PJRT/XLA runtime loading the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text; Python is never on the request
//!   path).
//! - [`coordinator`] — the L3 streaming orchestrator: routing, batching,
//!   model workers, backpressure, checkpoints, TCP protocol.
//! - [`bench_support`] — the in-repo benchmark harness (criterion is not
//!   available in the offline vendor set).
//!
//! ## Quickstart
//!
//! ```
//! use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
//!
//! // 2-D stream; pass the per-dimension dataset std for σ_ini = δ·std(x).
//! let cfg = GmmConfig::new(2).with_delta(0.1).with_beta(0.1);
//! let mut model = Figmn::new(cfg, &[1.0, 1.0]);
//! for p in [[0.0_f64, 0.0], [0.1, 0.1], [5.0, 5.0], [5.1, 4.9]] {
//!     model.learn(&p);
//! }
//! assert!(model.num_components() >= 2);
//! // Predict the 2nd element from the 1st (autoassociative inference).
//! let pred = model.predict(&[5.0], &[0], &[1]);
//! assert!((pred[0] - 5.0).abs() < 1.0);
//! ```
//!
//! ## Parallelism and determinism
//!
//! Attaching an engine shards the K components across a fixed thread
//! pool:
//!
//! ```
//! use figmn::engine::EngineConfig;
//! use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
//!
//! let cfg = GmmConfig::new(2).with_delta(0.1).with_beta(0.1);
//! let mut model = Figmn::new(cfg, &[1.0, 1.0]).with_engine(EngineConfig::new(4));
//! let batch: Vec<Vec<f64>> = vec![vec![0.0, 0.0], vec![0.1, 0.1], vec![5.0, 5.0]];
//! model.learn_batch(&batch);
//! let densities = model.score_batch(&batch);
//! assert_eq!(densities.len(), 3);
//! ```
//!
//! **Determinism guarantee:** every result — components, log-dets,
//! posteriors, predictions — is *bit-identical* for every thread count,
//! including the serial (no-engine) path. Per-component arithmetic is
//! component-local and cross-component merges go through a fixed-shape
//! pairwise tree reduction (see [`engine`]), so shard boundaries decide
//! only *where* a number is computed, never its value. The
//! `engine_determinism` integration test enforces this on the paper's
//! Table 1 streams.

pub mod baselines;
pub mod bench_support;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod gmm;
pub mod json;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod testutil;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
