//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Used by the covariance-baseline IGMN for numerically robust
//! log-determinants, by the dataset generators (sampling from full-
//! covariance Gaussians), and as a test oracle for the rank-one paths.

use super::Matrix;

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix. Returns `None` if the
    /// matrix is not (numerically) positive definite.
    pub fn new(a: &Matrix) -> Option<Self> {
        assert_eq!(a.rows(), a.cols(), "cholesky: square only");
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Factor a **packed upper-triangular** symmetric matrix (see
    /// [`crate::linalg::packed`]) without expanding it to dense form.
    /// Reads element `(i, j)` through the symmetric accessor, which for
    /// `j ≤ i` yields the packed `(j, i)` slot — the same value the
    /// dense factorization reads from its (exactly symmetric) lower
    /// triangle, so the factor is bit-identical to
    /// [`Cholesky::new`] on the dense expansion.
    pub fn new_packed(ap: &[f64], d: usize) -> Option<Self> {
        use crate::linalg::packed::{packed_len, sym_at};
        assert_eq!(ap.len(), packed_len(d), "cholesky: packed length mismatch");
        let mut l = Matrix::zeros(d, d);
        for i in 0..d {
            for j in 0..=i {
                let mut sum = sym_at(ap, d, i, j);
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn factor(&self) -> &Matrix {
        &self.l
    }

    /// `log|A| = 2·Σ log Lᵢᵢ` — numerically stable even when `|A|`
    /// under/overflows as a raw product (relevant at D=3072).
    pub fn log_det(&self) -> f64 {
        let n = self.l.rows();
        let mut acc = 0.0;
        for i in 0..n {
            acc += self.l[(i, i)].ln();
        }
        2.0 * acc
    }

    /// Solve `A·x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // Forward: L·y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Back: Lᵀ·x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// Mahalanobis quadratic form `bᵀ·A⁻¹·b` via one triangular solve:
    /// `‖L⁻¹b‖²`.
    pub fn quad_form_inv(&self, b: &[f64]) -> f64 {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        let mut acc = 0.0;
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            let yi = sum / self.l[(i, i)];
            y[i] = yi;
            acc += yi * yi;
        }
        acc
    }

    /// Apply the factor to a standard-normal vector: returns `L·z`, which
    /// is distributed `N(0, A)`. Used by the dataset generators.
    pub fn sample_transform(&self, z: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(z.len(), n);
        let mut out = vec![0.0; n];
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..=i {
                acc += self.l[(i, k)] * z[k];
            }
            out[i] = acc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        Matrix::from_rows(3, 3, &[4.0, 1.0, 0.5, 1.0, 3.0, 0.2, 0.5, 0.2, 2.0])
    }

    #[test]
    fn reconstructs() {
        let a = spd3();
        let ch = Cholesky::new(&a).unwrap();
        let l = ch.factor();
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn log_det_matches_lu() {
        let a = spd3();
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - a.determinant().ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_matches_inverse() {
        let a = spd3();
        let ch = Cholesky::new(&a).unwrap();
        let b = [1.0, 2.0, 3.0];
        let x = ch.solve(&b);
        let xi = a.inverse().unwrap().matvec(&b);
        for (u, v) in x.iter().zip(xi.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn quad_form_inv_matches_solve() {
        let a = spd3();
        let ch = Cholesky::new(&a).unwrap();
        let b = [1.0, -2.0, 0.5];
        let x = ch.solve(&b);
        let direct: f64 = b.iter().zip(x.iter()).map(|(u, v)| u * v).sum();
        assert!((ch.quad_form_inv(&b) - direct).abs() < 1e-12);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn packed_factor_bit_identical_to_dense() {
        use crate::linalg::packed::pack_symmetric;
        let a = spd3();
        let dense = Cholesky::new(&a).unwrap();
        let packed = Cholesky::new_packed(&pack_symmetric(&a), 3).unwrap();
        assert_eq!(dense.factor().as_slice(), packed.factor().as_slice());
        assert!(dense.log_det().to_bits() == packed.log_det().to_bits());
        // Non-PD packed input rejected too.
        let bad = pack_symmetric(&Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]));
        assert!(Cholesky::new_packed(&bad, 2).is_none());
    }
}
