//! Rank-one update primitives — the heart of the paper.
//!
//! The Fast IGMN replaces every `O(D³)` inversion/determinant with
//! Sherman–Morrison rank-one updates of the precision matrix `Λ = C⁻¹`
//! (paper Eqs. 18–21) and Matrix-Determinant-Lemma updates of `|C|`
//! (Eqs. 23–26). This module implements those recurrences in place with
//! caller-provided scratch so the hot path allocates nothing.
//!
//! One deliberate deviation from the paper's presentation: we track
//! `log|C|` instead of `|C|`. At the paper's own largest configuration
//! (CIFAR-10, D = 3072) the raw determinant of `σ²·I` under/overflows
//! `f64` for any σ ≠ 1, while the recurrences translate verbatim into log
//! space (products become sums). The equivalence tests compare log-dets.

use super::packed::{simd_tier, SimdTier};
use super::{dot, KernelMode, Matrix};

/// Symmetric rank-one accumulate: `A += α·u·uᵀ` (full storage).
#[inline]
pub fn syr(a: &mut Matrix, alpha: f64, u: &[f64]) {
    let n = u.len();
    debug_assert_eq!(a.rows(), n);
    debug_assert_eq!(a.cols(), n);
    for i in 0..n {
        let ui = u[i];
        if ui == 0.0 {
            continue;
        }
        let row = a.row_mut(i);
        // `α·(uᵢ·uⱼ)` (not `(α·uᵢ)·uⱼ`): uᵢ·uⱼ rounds identically to
        // uⱼ·uᵢ, so the update is *exactly* symmetric in floating point —
        // no drift accumulates over millions of hot-loop updates.
        for (r, &uj) in row.iter_mut().zip(u.iter()) {
            *r += alpha * (ui * uj);
        }
    }
}

/// Sherman–Morrison (paper Eq. 18/19): given `A⁻¹`, update it in place to
/// `(A + α·u·uᵀ)⁻¹` (use `α = -1` for subtraction, Eq. 19).
///
/// Returns the scalar `1 + α·uᵀA⁻¹u` (the Matrix-Determinant-Lemma factor,
/// Eq. 23/24), or `None` (leaving `A⁻¹` untouched) if that factor is ≤ 0,
/// i.e. the update would destroy positive-definiteness.
pub fn sherman_morrison(ainv: &mut Matrix, alpha: f64, u: &[f64], scratch: &mut [f64]) -> Option<f64> {
    let n = u.len();
    debug_assert_eq!(scratch.len(), n);
    ainv.matvec_into(u, scratch); // w = A⁻¹u
    let q = dot(u, scratch); // uᵀA⁻¹u
    let denom = 1.0 + alpha * q;
    if denom <= 0.0 || !denom.is_finite() {
        return None;
    }
    // A⁻¹ ← A⁻¹ − (α/denom)·w·wᵀ
    syr(ainv, -alpha / denom, scratch);
    Some(denom)
}

/// Scratch buffers for [`figmn_rank_two_update`]; reuse across calls to
/// keep the hot loop allocation-free.
pub struct UpdateScratch {
    w: Vec<f64>,
}

impl UpdateScratch {
    pub fn new(dim: usize) -> Self {
        UpdateScratch { w: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }
}

/// Outcome of one fused FIGMN precision/determinant update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UpdateResult {
    /// `log|C(t)|` after the rank-two update (Eqs. 25–26 in log space).
    pub log_det: f64,
    /// `e*ᵀ·Λ(t-1)·e*` — reused by callers for diagnostics.
    pub quad_estar: f64,
}

/// The paper's fused rank-two update (Eqs. 20–21 for `Λ`, 25–26 for the
/// determinant), performed in place.
///
/// Inputs: `lambda` = `Λ(t−1)` (overwritten with `Λ(t)`), `err` = the
/// mean-error vector whose weighted outer product drives Eq. 16 (the gmm
/// layer passes the OLD-mean error `e = x − μ(t−1)`, the exact Eq. 11
/// form — see DESIGN.md §Deviations), `delta_mu` = `ω·e` (Eq. 8),
/// `omega` = `p(j|x)/sp` (Eq. 7), `log_det` = `log|C(t−1)|`.
///
/// Returns `None` (with `lambda` left in an unspecified but finite state
/// only if the *second* step fails; callers should treat `None` as "reset
/// this component", which the [`crate::gmm`] layer does) when a
/// denominator hits zero/negative — mathematically impossible for
/// `0 < ω < 1` with a PD matrix, but reachable through float underflow at
/// extreme conditioning.
pub fn figmn_rank_two_update(
    lambda: &mut Matrix,
    err: &[f64],
    delta_mu: &[f64],
    omega: f64,
    log_det: f64,
    scratch: &mut UpdateScratch,
) -> Option<UpdateResult> {
    let d = err.len();
    debug_assert_eq!(lambda.rows(), d);
    debug_assert_eq!(delta_mu.len(), d);
    debug_assert_eq!(scratch.dim(), d);
    debug_assert!(omega > 0.0 && omega < 1.0, "omega must be in (0,1), got {omega}");

    let one_minus = 1.0 - omega;
    let w = &mut scratch.w;

    // ---- Step 1 (Eq. 20): Λ̄ = Λ/(1−ω) − [ω/(1−ω)²·Λe*e*ᵀΛ] / (1 + ω/(1−ω)·e*ᵀΛe*)
    lambda.matvec_into(err, w); // w = Λ(t−1)·e
    let q = dot(err, w); // eᵀΛe  (≥ 0 for PD Λ)
    let denom1 = 1.0 + omega / one_minus * q;
    if denom1 <= 0.0 || !denom1.is_finite() {
        return None;
    }
    // In-place: first scale Λ by 1/(1−ω), then subtract the rank-one term
    // expressed with the *unscaled* w: coefficient ω/((1−ω)²·denom1).
    lambda.scale_in_place(1.0 / one_minus);
    let c1 = omega / (one_minus * one_minus * denom1);
    syr(lambda, -c1, w);

    // ---- det step 1 (Eq. 25, log space):
    // log|C̄| = D·log(1−ω) + log|C(t−1)| + log(denom1)
    let log_det_bar = (d as f64) * one_minus.ln() + log_det + denom1.ln();

    // ---- Step 2 (Eq. 21): Λ = Λ̄ + Λ̄ΔμΔμᵀΛ̄ / (1 − ΔμᵀΛ̄Δμ)
    lambda.matvec_into(delta_mu, w); // w = Λ̄·Δμ
    let r = dot(delta_mu, w); // ΔμᵀΛ̄Δμ
    let denom2 = 1.0 - r;
    if denom2 <= 0.0 || !denom2.is_finite() {
        return None;
    }
    syr(lambda, 1.0 / denom2, w);

    // ---- det step 2 (Eq. 26, log space): log|C| = log|C̄| + log(1 − r)
    let new_log_det = log_det_bar + denom2.ln();

    Some(UpdateResult { log_det: new_log_det, quad_estar: q })
}

/// The fused single-pass form of [`figmn_rank_two_update`] — the perf-
/// pass optimization (EXPERIMENTS.md §Perf L3-1).
///
/// Observation: in the exact Eq. 11 recurrence the two rank-one
/// directions are **parallel** (`Δμ = ω·e`), so the whole update is a
/// single rank-one correction:
///
/// ```text
/// C(t) = (1−ω)·C + ω(1−ω)·e·eᵀ
/// Λ(t) = Λ/(1−ω) − [ω/(1−ω)] / (1 + ω·q) · w·wᵀ,   w = Λe, q = eᵀΛe
/// log|C(t)| = D·log(1−ω) + log|C| + log(1 + ω·q)
/// ```
///
/// The caller supplies `w` and `q` — which the Mahalanobis distance pass
/// (Eq. 22) has already computed — so the whole learn step makes exactly
/// **two** O(D²) sweeps per component (one mat-vec, one fused
/// scale+GER) instead of six. Algebraically identical to the two-step
/// form (property-tested below); `1 + ω·q > 0` always holds for PD `Λ`,
/// so unlike the two-step form there is no failure path beyond
/// non-finite input.
pub fn figmn_fused_update(
    lambda: &mut Matrix,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    let d = w.len();
    debug_assert_eq!(lambda.rows(), d);
    debug_assert!(omega > 0.0 && omega < 1.0, "omega must be in (0,1), got {omega}");
    let one_minus = 1.0 - omega;
    let denom = 1.0 + omega * q;
    if !(denom > 0.0) || !denom.is_finite() {
        return None;
    }
    let a = 1.0 / one_minus;
    let beta = -(omega * a) / denom;
    // Single fused pass: Λ ← a·Λ + β·w·wᵀ  (β·(wᵢ·wⱼ) keeps exact
    // symmetry, same trick as `syr`).
    for i in 0..d {
        let wi = w[i];
        let row = lambda.row_mut(i);
        for (r, &wj) in row.iter_mut().zip(w.iter()) {
            *r = a * *r + beta * (wi * wj);
        }
    }
    let new_log_det = (d as f64) * one_minus.ln() + log_det + denom.ln();
    Some(UpdateResult { log_det: new_log_det, quad_estar: q })
}

/// [`figmn_fused_update`] on **packed upper-triangular** storage (see
/// [`crate::linalg::packed`]) — the layout the `gmm::ComponentStore`
/// arenas use. Touches `D·(D+1)/2` entries instead of `D²`, halving the
/// bytes moved per component.
///
/// Bit-identity: each stored entry `(i, j)`, `j ≥ i`, is updated with
/// the exact expression the dense kernel uses (`a·Λᵢⱼ + β·(wᵢ·wⱼ)`),
/// and the `log|C|` recurrence is unchanged — so a packed trajectory is
/// bit-identical to the dense one (property-tested below).
pub fn figmn_fused_update_packed(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    debug_assert_eq!(lambda.len(), crate::linalg::packed::packed_len(d));
    debug_assert_eq!(w.len(), d);
    debug_assert!(omega > 0.0 && omega < 1.0, "omega must be in (0,1), got {omega}");
    let one_minus = 1.0 - omega;
    let denom = 1.0 + omega * q;
    if !(denom > 0.0) || !denom.is_finite() {
        return None;
    }
    let a = 1.0 / one_minus;
    let beta = -(omega * a) / denom;
    let mut rs = 0usize;
    for i in 0..d {
        let wi = w[i];
        let row = &mut lambda[rs..rs + d - i];
        for (r, &wj) in row.iter_mut().zip(w[i..].iter()) {
            *r = a * *r + beta * (wi * wj);
        }
        rs += d - i;
    }
    let new_log_det = (d as f64) * one_minus.ln() + log_det + denom.ln();
    Some(UpdateResult { log_det: new_log_det, quad_estar: q })
}

/// Fast-mode variant of [`figmn_fused_update_packed`]: the per-entry
/// expression becomes `a·Λᵢⱼ + (β·wᵢ)·wⱼ` — `β·wᵢ` is hoisted out of
/// the inner loop, saving one multiply per entry and leaving a pure
/// scale-and-axpy body that LLVM vectorizes. The factoring is the one
/// deliberate deviation from the strict kernel, so results are
/// tolerance-equivalent rather than bit-identical (see
/// [`crate::linalg::KernelMode`]). Packed storage keeps the matrix
/// structurally symmetric regardless of rounding, and the `log|C|`
/// recurrence is unchanged, so the determinism-within-a-mode guarantee
/// still holds.
pub fn figmn_fused_update_packed_fast(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    debug_assert_eq!(lambda.len(), crate::linalg::packed::packed_len(d));
    debug_assert_eq!(w.len(), d);
    debug_assert!(omega > 0.0 && omega < 1.0, "omega must be in (0,1), got {omega}");
    let one_minus = 1.0 - omega;
    let denom = 1.0 + omega * q;
    if !(denom > 0.0) || !denom.is_finite() {
        return None;
    }
    let a = 1.0 / one_minus;
    let beta = -(omega * a) / denom;
    let mut rs = 0usize;
    for i in 0..d {
        let bwi = beta * w[i];
        let row = &mut lambda[rs..rs + d - i];
        for (r, &wj) in row.iter_mut().zip(w[i..].iter()) {
            *r = a * *r + bwi * wj;
        }
        rs += d - i;
    }
    let new_log_det = (d as f64) * one_minus.ln() + log_det + denom.ln();
    Some(UpdateResult { log_det: new_log_det, quad_estar: q })
}

/// Fused-FMA body of the packed fused update's row sweep: the same
/// hoisted `a·Λᵢⱼ + (β·wᵢ)·wⱼ` expression as
/// [`figmn_fused_update_packed_fast`] with the scale and accumulate
/// contracted into one `mul_add` per entry. `#[inline(always)]` so the
/// `target_feature` wrapper recompiles it at that feature set's full
/// vector width. The `log|C|` recurrence does not involve the row loop
/// and stays bit-identical across every tier (property-tested below).
#[inline(always)]
fn figmn_fused_update_packed_fused(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    debug_assert_eq!(lambda.len(), crate::linalg::packed::packed_len(d));
    debug_assert_eq!(w.len(), d);
    debug_assert!(omega > 0.0 && omega < 1.0, "omega must be in (0,1), got {omega}");
    let one_minus = 1.0 - omega;
    let denom = 1.0 + omega * q;
    if !(denom > 0.0) || !denom.is_finite() {
        return None;
    }
    let a = 1.0 / one_minus;
    let beta = -(omega * a) / denom;
    let mut rs = 0usize;
    for i in 0..d {
        let bwi = beta * w[i];
        let row = &mut lambda[rs..rs + d - i];
        for (r, &wj) in row.iter_mut().zip(w[i..].iter()) {
            *r = a.mul_add(*r, bwi * wj);
        }
        rs += d - i;
    }
    let new_log_det = (d as f64) * one_minus.ln() + log_det + denom.ln();
    Some(UpdateResult { log_det: new_log_det, quad_estar: q })
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn figmn_fused_update_packed_fma(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    figmn_fused_update_packed_fused(lambda, d, w, q, omega, log_det)
}

/// Explicit-SIMD tier of the packed fused update — the write-path rung
/// of the [`SimdTier`] ladder (see `linalg::packed`'s module docs):
/// [`figmn_fused_update_packed_fast`] semantics at the best tier the
/// CPU supports, within ~1e-12 relative of the `Fast` kernel on the
/// matrix entries, `log|C|` bit-identical, deterministic for a fixed
/// tier.
pub fn figmn_fused_update_packed_simd(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
) -> Option<UpdateResult> {
    figmn_fused_update_packed_simd_tier(lambda, d, w, q, omega, log_det, simd_tier())
}

/// Tier-forcing variant of [`figmn_fused_update_packed_simd`] (tests,
/// benches). The requested tier is clamped to the detected one; forced
/// `Scalar` runs the portable [`figmn_fused_update_packed_fast`] kernel
/// bit for bit.
pub fn figmn_fused_update_packed_simd_tier(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
    tier: SimdTier,
) -> Option<UpdateResult> {
    let eff = tier.min(simd_tier());
    match eff {
        SimdTier::Scalar => figmn_fused_update_packed_fast(lambda, d, w, q, omega, log_det),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `eff ≤ simd_tier()`, and `Fma` is only ever detected
        // when avx2+fma are present on the running CPU.
        SimdTier::Fma => unsafe { figmn_fused_update_packed_fma(lambda, d, w, q, omega, log_det) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Fma => figmn_fused_update_packed_fast(lambda, d, w, q, omega, log_det),
        // Only reachable when the build enables avx512f globally, so the
        // plain body already compiles at full width.
        SimdTier::Avx512 => figmn_fused_update_packed_fused(lambda, d, w, q, omega, log_det),
    }
}

/// Mode dispatcher for the packed fused update (see
/// [`crate::linalg::KernelMode`] for the contract of each arm).
#[inline]
pub fn figmn_fused_update_packed_mode(
    lambda: &mut [f64],
    d: usize,
    w: &[f64],
    q: f64,
    omega: f64,
    log_det: f64,
    mode: KernelMode,
) -> Option<UpdateResult> {
    match mode {
        KernelMode::Strict => figmn_fused_update_packed(lambda, d, w, q, omega, log_det),
        KernelMode::Fast => figmn_fused_update_packed_fast(lambda, d, w, q, omega, log_det),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::TEST_EPS;
    use crate::rng::Pcg64;
    use crate::testutil::random_spd;

    #[test]
    fn syr_known() {
        let mut a = Matrix::zeros(2, 2);
        syr(&mut a, 2.0, &[1.0, 3.0]);
        assert_eq!(a.as_slice(), &[2.0, 6.0, 6.0, 18.0]);
    }

    #[test]
    fn sherman_morrison_matches_direct_inverse() {
        let mut rng = Pcg64::seed(7);
        for trial in 0..50 {
            let n = 2 + (trial % 6);
            let a = random_spd(n, &mut rng);
            let u: Vec<f64> = (0..n).map(|_| rng.normal() * 0.3).collect();
            let mut ainv = a.inverse().unwrap();
            let mut scratch = vec![0.0; n];
            let factor = sherman_morrison(&mut ainv, 1.0, &u, &mut scratch).unwrap();

            // Direct: (A + u·uᵀ)⁻¹
            let mut apu = a.clone();
            syr(&mut apu, 1.0, &u);
            let direct = apu.inverse().unwrap();
            assert!(
                ainv.max_abs_diff(&direct) < 1e-8,
                "trial {trial}: SM diverged from direct inverse"
            );
            // Determinant lemma factor: |A+uuᵀ| = |A|·factor
            let lhs = apu.determinant();
            let rhs = a.determinant() * factor;
            assert!((lhs / rhs - 1.0).abs() < 1e-8);
        }
    }

    #[test]
    fn sherman_morrison_subtraction_guard() {
        // Subtracting u·uᵀ with ‖u‖ too large ⇒ denominator ≤ 0 ⇒ None.
        let mut ainv = Matrix::identity(2);
        let mut scratch = vec![0.0; 2];
        let before = ainv.clone();
        let res = sherman_morrison(&mut ainv, -1.0, &[2.0, 0.0], &mut scratch);
        assert!(res.is_none());
        assert_eq!(ainv.max_abs_diff(&before), 0.0, "must leave input untouched");
    }

    /// Property: the fused rank-two update equals the direct recompute
    /// (Eqs. 16–17 on C, then invert) for random PD matrices — the
    /// paper's central algebraic claim.
    #[test]
    fn figmn_update_matches_covariance_path() {
        let mut rng = Pcg64::seed(42);
        for trial in 0..100 {
            let n = 2 + (trial % 8);
            let c = random_spd(n, &mut rng);
            let omega = 0.05 + 0.9 * rng.uniform();
            let e_star: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // Δμ must be small enough to keep C(t) PD: Δμ = ω·e with
            // e ≈ e* scaled, as in the real algorithm.
            let delta_mu: Vec<f64> = e_star.iter().map(|v| omega * v * 0.5).collect();

            // Covariance path (paper Eqs. 16–17).
            let mut cbar = c.clone();
            cbar.scale_in_place(1.0 - omega);
            syr(&mut cbar, omega, &e_star);
            let mut ct = cbar.clone();
            syr(&mut ct, -1.0, &delta_mu);
            let Some(direct_inv) = ct.inverse() else { continue };
            let det_ct = ct.determinant();
            if det_ct <= 0.0 {
                continue; // degenerate draw; covariance left PD-land
            }

            // Precision path (Eqs. 20–21, 25–26).
            let mut lambda = c.inverse().unwrap();
            let mut scratch = UpdateScratch::new(n);
            let res = figmn_rank_two_update(
                &mut lambda,
                &e_star,
                &delta_mu,
                omega,
                c.determinant().ln(),
                &mut scratch,
            )
            .expect("update must succeed when covariance path stays PD");

            assert!(
                lambda.max_abs_diff(&direct_inv) < 1e-6,
                "trial {trial}: precision path diverged (n={n}, ω={omega})"
            );
            assert!(
                (res.log_det - det_ct.ln()).abs() < 1e-8,
                "trial {trial}: log-det mismatch {} vs {}",
                res.log_det,
                det_ct.ln()
            );
        }
    }

    /// Property: update preserves symmetry exactly-ish.
    #[test]
    fn figmn_update_preserves_symmetry() {
        let mut rng = Pcg64::seed(3);
        let n = 6;
        let c = random_spd(n, &mut rng);
        let mut lambda = c.inverse().unwrap();
        // Gauss–Jordan output is not exactly symmetric; the real algorithm
        // starts from an exactly-diagonal Λ, so align the test with that.
        lambda.symmetrize();
        let mut scratch = UpdateScratch::new(n);
        let mut log_det = c.determinant().ln();
        for _ in 0..200 {
            let e: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let omega = 0.01 + 0.3 * rng.uniform();
            let dmu: Vec<f64> = e.iter().map(|v| omega * v).collect();
            let e_star: Vec<f64> = e.iter().zip(dmu.iter()).map(|(a, b)| a - b).collect();
            if let Some(r) = figmn_rank_two_update(&mut lambda, &e_star, &dmu, omega, log_det, &mut scratch) {
                log_det = r.log_det;
            }
            for i in 0..n {
                for j in 0..n {
                    let drift = (lambda[(i, j)] - lambda[(j, i)]).abs();
                    let mag = lambda[(i, j)].abs().max(1.0);
                    assert!(drift / mag < 1e-9, "symmetry drift {drift}");
                }
            }
        }
        // Λ must still be PD-ish: quad form positive for random probes.
        for _ in 0..10 {
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            assert!(lambda.quad_form(&v) > 0.0);
        }
    }

    /// Property: the fused single-pass update equals the two-step
    /// Sherman–Morrison pair exactly (to fp tolerance) — the perf-pass
    /// rewrite changes no semantics.
    #[test]
    fn fused_equals_two_step() {
        let mut rng = Pcg64::seed(77);
        for trial in 0..200 {
            let n = 2 + (trial % 10);
            let c = random_spd(n, &mut rng);
            let mut lam_two = c.inverse().unwrap();
            lam_two.symmetrize();
            let mut lam_fused = lam_two.clone();
            let log_det = c.determinant().ln();

            let e: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let omega = 0.01 + 0.95 * rng.uniform();
            let dmu: Vec<f64> = e.iter().map(|v| omega * v).collect();

            let mut scratch = UpdateScratch::new(n);
            let r_two =
                figmn_rank_two_update(&mut lam_two, &e, &dmu, omega, log_det, &mut scratch)
                    .expect("two-step must succeed");

            let mut w = vec![0.0; n];
            lam_fused.matvec_into(&e, &mut w);
            let q = dot(&e, &w);
            let r_fused = figmn_fused_update(&mut lam_fused, &w, q, omega, log_det)
                .expect("fused must succeed");

            let scale = lam_two.as_slice().iter().fold(1.0f64, |m, v| m.max(v.abs()));
            assert!(
                lam_two.max_abs_diff(&lam_fused) < 1e-9 * scale,
                "trial {trial}: fused diverged (n={n}, ω={omega})"
            );
            assert!(
                (r_two.log_det - r_fused.log_det).abs() < 1e-9 * (1.0 + r_two.log_det.abs()),
                "trial {trial}: log-det mismatch {} vs {}",
                r_two.log_det,
                r_fused.log_det
            );
        }
    }

    /// Property: the packed fused update equals the dense fused update
    /// bit for bit (entries and log-det) — the layout refactor's core
    /// invariant.
    #[test]
    fn packed_fused_bit_identical_to_dense() {
        use crate::linalg::packed::{pack_symmetric, packed_len};
        let mut rng = Pcg64::seed(123);
        for trial in 0..200 {
            let n = 1 + (trial % 10);
            let mut dense = random_spd(n, &mut rng);
            dense.symmetrize();
            let mut packed = pack_symmetric(&dense);
            assert_eq!(packed.len(), packed_len(n));
            let log_det = rng.normal();

            let e: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let omega = 0.01 + 0.95 * rng.uniform();
            let mut w = vec![0.0; n];
            dense.matvec_into(&e, &mut w);
            let q = dot(&e, &w);

            let r_dense = figmn_fused_update(&mut dense, &w, q, omega, log_det)
                .expect("dense must succeed");
            let r_packed = figmn_fused_update_packed(&mut packed, n, &w, q, omega, log_det)
                .expect("packed must succeed");
            assert_eq!(
                pack_symmetric(&dense),
                packed,
                "trial {trial}: packed entries diverged (n={n}, ω={omega})"
            );
            assert!(
                r_dense.log_det.to_bits() == r_packed.log_det.to_bits(),
                "trial {trial}: log-det bits differ"
            );
        }
    }

    /// The fast fused update agrees with the strict one to tight
    /// relative tolerance (same math, `β·wᵢ` hoisted), rejects the same
    /// degenerate denominators, and its log-det recurrence — which does
    /// not involve the refactored loop — stays bit-identical.
    #[test]
    fn packed_fast_update_matches_strict_within_tolerance() {
        use crate::linalg::packed::pack_symmetric;
        let mut rng = Pcg64::seed(321);
        for trial in 0..200 {
            let n = 1 + (trial % 12);
            let mut dense = random_spd(n, &mut rng);
            dense.symmetrize();
            let mut strict = pack_symmetric(&dense);
            let mut fast = strict.clone();
            let log_det = rng.normal();

            let e: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let omega = 0.01 + 0.95 * rng.uniform();
            let mut w = vec![0.0; n];
            dense.matvec_into(&e, &mut w);
            let q = dot(&e, &w);

            let r_strict = figmn_fused_update_packed(&mut strict, n, &w, q, omega, log_det)
                .expect("strict must succeed");
            let r_fast = figmn_fused_update_packed_fast(&mut fast, n, &w, q, omega, log_det)
                .expect("fast must succeed");
            let scale = strict.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for (i, (a, b)) in strict.iter().zip(fast.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * scale,
                    "trial {trial}: entry {i} diverged ({a} vs {b})"
                );
            }
            assert!(
                r_strict.log_det.to_bits() == r_fast.log_det.to_bits(),
                "trial {trial}: log-det recurrence must not change"
            );

            // Dispatcher routes: Strict arm is bit-identical to the
            // strict kernel, Fast arm to the fast one.
            let base = pack_symmetric(&dense);
            let mut via_mode = base.clone();
            figmn_fused_update_packed_mode(
                &mut via_mode,
                n,
                &w,
                q,
                omega,
                log_det,
                KernelMode::Fast,
            )
            .unwrap();
            assert_eq!(via_mode, fast, "trial {trial}: Fast dispatch mismatch");
            let mut via_strict = base;
            figmn_fused_update_packed_mode(
                &mut via_strict,
                n,
                &w,
                q,
                omega,
                log_det,
                KernelMode::Strict,
            )
            .unwrap();
            assert_eq!(via_strict, strict, "trial {trial}: Strict dispatch mismatch");
        }
    }

    /// The write-path update tier keeps the ladder's contract: forced
    /// `Scalar` IS the portable fast kernel bit for bit, the dispatched
    /// tier is within 1e-12 relative of it on the matrix entries, the
    /// `log|C|` recurrence is bit-identical across every tier, forcing
    /// above the detected tier clamps to the dispatched result, and a
    /// fixed tier is deterministic.
    #[test]
    fn fused_update_simd_tier_matches_fast_within_tolerance() {
        use crate::linalg::packed::pack_symmetric;
        let mut rng = Pcg64::seed(421);
        for trial in 0..100 {
            let n = 1 + (trial % 16);
            let mut dense = random_spd(n, &mut rng);
            dense.symmetrize();
            let base = pack_symmetric(&dense);
            let log_det = rng.normal();

            let e: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let omega = 0.01 + 0.95 * rng.uniform();
            let mut w = vec![0.0; n];
            dense.matvec_into(&e, &mut w);
            let q = dot(&e, &w);

            let mut fast = base.clone();
            let r_fast = figmn_fused_update_packed_fast(&mut fast, n, &w, q, omega, log_det)
                .expect("fast must succeed");

            let mut simd = base.clone();
            let r_simd = figmn_fused_update_packed_simd(&mut simd, n, &w, q, omega, log_det)
                .expect("simd must succeed");
            let scale = fast.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for (i, (a, b)) in fast.iter().zip(simd.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * scale,
                    "trial {trial}: entry {i} diverged ({a} vs {b})"
                );
            }
            assert!(
                r_fast.log_det.to_bits() == r_simd.log_det.to_bits(),
                "trial {trial}: log-det recurrence must not change across tiers"
            );

            let mut scalar = base.clone();
            let r_scalar = figmn_fused_update_packed_simd_tier(
                &mut scalar,
                n,
                &w,
                q,
                omega,
                log_det,
                SimdTier::Scalar,
            )
            .expect("scalar tier must succeed");
            assert_eq!(scalar, fast, "trial {trial}: forced-scalar bits differ from fast");
            assert!(r_scalar.log_det.to_bits() == r_fast.log_det.to_bits());

            let mut clamped = base.clone();
            figmn_fused_update_packed_simd_tier(
                &mut clamped,
                n,
                &w,
                q,
                omega,
                log_det,
                SimdTier::Avx512,
            )
            .expect("clamped tier must succeed");
            assert_eq!(clamped, simd, "trial {trial}: clamped tier diverges from dispatch");

            let mut again = base.clone();
            figmn_fused_update_packed_simd(&mut again, n, &w, q, omega, log_det)
                .expect("repeat must succeed");
            assert_eq!(again, simd, "trial {trial}: update tier not deterministic");
        }
    }

    #[test]
    fn rejects_bad_omega() {
        // debug_assert guards ω∈(0,1); in release the math still holds for
        // the denominators to trip. Here just check the guard boundary via
        // a valid small ω.
        let mut lambda = Matrix::identity(2);
        let mut scratch = UpdateScratch::new(2);
        let r = figmn_rank_two_update(&mut lambda, &[0.1, 0.1], &[0.001, 0.001], 1e-6, 0.0, &mut scratch);
        assert!(r.is_some());
        assert!(r.unwrap().log_det.abs() < 1.0 + TEST_EPS);
    }
}
