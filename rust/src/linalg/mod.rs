//! Dense and packed-symmetric linear algebra substrate.
//!
//! The offline vendor set has no BLAS/LAPACK/ndarray, so the paper's
//! numerical kernels are built on this module: a row-major [`Matrix`] of
//! `f64`, vector helpers, Cholesky factorization (used by the covariance
//! baseline and for validation), explicit inverse/determinant (the
//! `O(D³)` operations the paper *removes*), and the rank-one update
//! primitives (the operations the paper *adds*).
//!
//! The mixture's per-component matrices are symmetric, so the hot-path
//! kernels also come in [`packed`] upper-triangular form — half the
//! bytes per sweep, bit-identical results (see the [`packed`] module
//! docs for the layout and the bit-identity contract). The component
//! arenas of `gmm::ComponentStore` store exclusively packed matrices;
//! the dense [`Matrix`] remains the interop/oracle type.
//!
//! Everything here is deliberately allocation-conscious: the GMM hot path
//! calls [`rank_one`] routines that write in place and allocate nothing.

mod cholesky;
mod matrix;
pub mod packed;
pub mod rank_one;
mod vector;

pub use cholesky::Cholesky;
pub use matrix::Matrix;
pub use vector::{add, axpy, dot, norm2, outer_into, scale, sub, sub_into};

/// Numerical tolerance used by the test-suite comparisons in this crate.
pub const TEST_EPS: f64 = 1e-9;
