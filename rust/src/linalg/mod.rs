//! Dense linear algebra substrate.
//!
//! The offline vendor set has no BLAS/LAPACK/ndarray, so the paper's
//! numerical kernels are built on this module: a row-major [`Matrix`] of
//! `f64`, vector helpers, Cholesky factorization (used by the covariance
//! baseline and for validation), explicit inverse/determinant (the
//! `O(D³)` operations the paper *removes*), and the rank-one update
//! primitives (the operations the paper *adds*).
//!
//! Everything here is deliberately allocation-conscious: the GMM hot path
//! calls [`rank_one`] routines that write in place and allocate nothing.

mod cholesky;
mod matrix;
pub mod rank_one;
mod vector;

pub use cholesky::Cholesky;
pub use matrix::Matrix;
pub use vector::{add, axpy, dot, norm2, outer_into, scale, sub, sub_into};

/// Numerical tolerance used by the test-suite comparisons in this crate.
pub const TEST_EPS: f64 = 1e-9;
