//! Dense and packed-symmetric linear algebra substrate.
//!
//! The offline vendor set has no BLAS/LAPACK/ndarray, so the paper's
//! numerical kernels are built on this module: a row-major [`Matrix`] of
//! `f64`, vector helpers, Cholesky factorization (used by the covariance
//! baseline and for validation), explicit inverse/determinant (the
//! `O(D³)` operations the paper *removes*), and the rank-one update
//! primitives (the operations the paper *adds*).
//!
//! The mixture's per-component matrices are symmetric, so the hot-path
//! kernels also come in [`packed`] upper-triangular form — half the
//! bytes per sweep, bit-identical results (see the [`packed`] module
//! docs for the layout and the bit-identity contract). The component
//! arenas of `gmm::ComponentStore` store exclusively packed matrices;
//! the dense [`Matrix`] remains the interop/oracle type.
//!
//! Everything here is deliberately allocation-conscious: the GMM hot path
//! calls [`rank_one`] routines that write in place and allocate nothing.

mod cholesky;
mod matrix;
pub mod packed;
pub mod rank_one;
mod vector;

pub use cholesky::Cholesky;
pub use matrix::Matrix;
pub use packed::{simd_tier, SimdTier};
pub use vector::{add, axpy, dot, norm2, outer_into, scale, sq_dist, sub, sub_into};

/// Numerical tolerance used by the test-suite comparisons in this crate.
pub const TEST_EPS: f64 = 1e-9;

/// Which implementation the three hot packed kernels
/// ([`packed::quad_form_with`]-family, [`packed::spmv`],
/// [`rank_one::figmn_fused_update_packed`]) run in.
///
/// - [`KernelMode::Strict`] (the default) is the scalar reference path:
///   the same floating-point operations in the same order as the dense
///   formulation, so every result is **bit-identical** across layouts,
///   thread counts, and checkpoint round-trips (the crate's determinism
///   guarantee; see `tests/layout_equivalence.rs`).
/// - [`KernelMode::Fast`] trades bit-identity for throughput: the
///   reduction kernels accumulate in four independent lanes with a
///   scalar tail (a shape LLVM auto-vectorizes to SIMD on every
///   target), and the fused update hoists `β·wᵢ` out of its inner loop.
///   Results are **tolerance-equivalent** to `Strict` (relative ~1e-12
///   on log-densities over the paper's Table 1 streams — enforced by
///   `tests/kernel_mode_equivalence.rs`), and still deterministic: for
///   a fixed mode, every thread count and the serial path agree bit for
///   bit, because the per-component instruction sequence is unchanged.
///
/// The mode is carried per model (`gmm::GmmConfig::kernel_mode`),
/// serialized with checkpoints, and selectable over the coordinator
/// protocol and the CLI (`train --kernel-mode fast`).
///
/// Above `Fast`, the hot paths have a third rung that is *not* a
/// `KernelMode`: the runtime-detected explicit-SIMD tier ([`SimdTier`],
/// `Scalar < Fma < Avx512`) behind [`packed::quad_form_multi_simd`] and
/// the f32 replica kernels on the read path, and [`packed::spmv_simd`] /
/// [`rank_one::figmn_fused_update_packed_simd`] on the write path. It is
/// dispatch, not policy — models never select it, it degrades to the
/// portable `Fast` kernels on CPUs lacking the features, and it keeps
/// `Fast`'s ~1e-12 tolerance contract (see the [`packed`] module docs
/// for the full ladder Strict → Fast → FMA/AVX-512 and the f32 replica
/// tolerance contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Scalar reference loops — bit-identical to the dense formulation.
    #[default]
    Strict,
    /// 4-wide blocked (auto-vectorizable) loops — tolerance-equivalent.
    Fast,
}

impl KernelMode {
    /// Wire/CLI name: `"strict"` or `"fast"`.
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Strict => "strict",
            KernelMode::Fast => "fast",
        }
    }

    /// Parse a wire/CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s {
            "strict" => Some(KernelMode::Strict),
            "fast" => Some(KernelMode::Fast),
            _ => None,
        }
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}
