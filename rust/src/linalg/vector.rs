//! Small allocation-free vector helpers used on the GMM hot path.

use super::Matrix;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

/// Euclidean norm squared.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a)
}

/// `y += s·x` in place.
#[inline]
pub fn axpy(s: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += s * xi;
    }
}

/// Elementwise `a + b` (allocates).
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

/// Elementwise `a - b` (allocates).
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// Elementwise `out = a - b` into a caller buffer.
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x - y;
    }
}

/// Squared Euclidean distance `‖a − b‖²` without an intermediate buffer.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Scale in place.
#[inline]
pub fn scale(a: &mut [f64], s: f64) {
    for v in a {
        *v *= s;
    }
}

/// Outer product `out = u·vᵀ` written into an existing matrix.
pub fn outer_into(u: &[f64], v: &[f64], out: &mut Matrix) {
    assert_eq!(out.rows(), u.len());
    assert_eq!(out.cols(), v.len());
    for i in 0..u.len() {
        let ui = u[i];
        let row = out.row_mut(i);
        for (r, &vj) in row.iter_mut().zip(v.iter()) {
            *r = ui * vj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn sub_into_matches_sub() {
        let a = [5.0, 7.0];
        let b = [2.0, 3.0];
        let mut out = [0.0; 2];
        sub_into(&a, &b, &mut out);
        assert_eq!(out.to_vec(), sub(&a, &b));
    }

    #[test]
    fn outer_into_known() {
        let mut m = Matrix::zeros(2, 2);
        outer_into(&[1.0, 2.0], &[3.0, 4.0], &mut m);
        assert_eq!(m.as_slice(), &[3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn norm2_is_self_dot() {
        assert_eq!(norm2(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn sq_dist_matches_sub_norm2() {
        let a = [1.0, -2.0, 3.5];
        let b = [0.5, 1.0, -1.0];
        assert_eq!(sq_dist(&a, &b), norm2(&sub(&a, &b)));
        assert_eq!(sq_dist(&a, &a), 0.0);
    }
}
