//! Packed upper-triangular storage for symmetric matrices.
//!
//! A symmetric `D×D` matrix is fully determined by its upper triangle —
//! `D·(D+1)/2` values instead of `D²`. The mixture's per-component
//! matrices (the precision `Λ` of the fast path, the covariance `C` of
//! the baseline) are kept *exactly* symmetric by the update rules (the
//! `α·(uᵢ·uⱼ)` trick in [`super::rank_one`]), so packing loses nothing —
//! and the component arenas of `gmm::ComponentStore` move roughly half
//! the bytes per kernel sweep.
//!
//! ## Layout
//!
//! Row-major upper triangle: row `i` stores entries `(i, i..D)`
//! contiguously, so element `(i, j)` with `i ≤ j` lives at
//! `row_start(i, d) + (j − i)`.
//!
//! ## Bit-identity contract (`Strict` mode)
//!
//! Every kernel here performs the **same floating-point operations in
//! the same order** as its dense counterpart in [`super::Matrix`] /
//! [`super::rank_one`]: a mat-vec still accumulates `Σⱼ A(i,j)·xⱼ` in
//! ascending `j` (reading `(j, i)` from earlier packed rows when
//! `j < i` — the same value, since the dense matrices are exactly
//! symmetric), and per-entry updates use identical expressions. Packing
//! therefore changes *where a value is stored*, never the value — the
//! crate's determinism guarantee extends across layouts, enforced by
//! this module's side-by-side tests and `tests/layout_equivalence.rs`.
//!
//! ## Fast mode (tolerance contract)
//!
//! The strict mat-vec is a scalar left-fold — a loop-carried FP
//! dependence the compiler may not reorder, so it runs one lane wide no
//! matter the hardware. The `*_fast` kernels below (selected per model
//! via [`KernelMode::Fast`]) rewrite the two reduction-bound sweeps as
//! **4-wide blocked accumulations with a scalar tail** and stream each
//! packed row exactly once (the row's entries serve `y[i]`'s dot
//! product and the `y[j] += A(i,j)·x[i]` scatter in the same pass).
//! Those loops auto-vectorize on every SIMD target without `unsafe` or
//! nightly intrinsics. The price is a *different summation order*:
//! results are no longer bit-identical to `Strict`, only
//! tolerance-equivalent (relative ~1e-12 on log-densities; see
//! [`super::KernelMode`] for the full contract). Within `Fast` mode
//! results remain deterministic — the blocked order is fixed, so every
//! thread count agrees bit for bit.

use super::{KernelMode, Matrix};

/// Packed length of a symmetric `d×d` matrix: `d·(d+1)/2`.
#[inline]
pub fn packed_len(d: usize) -> usize {
    d * (d + 1) / 2
}

/// Offset of packed row `i` (the diagonal element `(i, i)`):
/// `Σ_{r<i} (d − r) = i·d − i·(i−1)/2`, written underflow-free.
#[inline]
pub fn row_start(i: usize, d: usize) -> usize {
    i * (2 * d + 1 - i) / 2
}

/// Symmetric element access for arbitrary `(i, j)`.
#[inline]
pub fn sym_at(ap: &[f64], d: usize, i: usize, j: usize) -> f64 {
    if i <= j {
        ap[row_start(i, d) + (j - i)]
    } else {
        ap[row_start(j, d) + (i - j)]
    }
}

/// Pack the upper triangle of a (symmetric) dense matrix.
pub fn pack_symmetric(m: &Matrix) -> Vec<f64> {
    assert_eq!(m.rows(), m.cols(), "pack_symmetric: square only");
    pack_symmetric_slice(m.as_slice(), m.rows())
}

/// Pack the upper triangle of a row-major `d×d` slice.
pub fn pack_symmetric_slice(flat: &[f64], d: usize) -> Vec<f64> {
    assert_eq!(flat.len(), d * d, "pack_symmetric_slice: shape mismatch");
    let mut out = Vec::with_capacity(packed_len(d));
    for i in 0..d {
        out.extend_from_slice(&flat[i * d + i..(i + 1) * d]);
    }
    out
}

/// Expand a packed symmetric matrix back to dense (both triangles).
pub fn unpack_symmetric(ap: &[f64], d: usize) -> Matrix {
    assert_eq!(ap.len(), packed_len(d), "unpack_symmetric: length mismatch");
    let mut m = Matrix::zeros(d, d);
    for i in 0..d {
        let rs = row_start(i, d);
        for j in i..d {
            let v = ap[rs + (j - i)];
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
    }
    m
}

/// Packed diagonal matrix from the given entries.
pub fn from_diag(entries: &[f64]) -> Vec<f64> {
    let d = entries.len();
    let mut out = vec![0.0; packed_len(d)];
    for (i, &v) in entries.iter().enumerate() {
        out[row_start(i, d)] = v;
    }
    out
}

/// Symmetric mat-vec `y = A·x` — bit-identical to
/// [`Matrix::matvec_into`] on the dense expansion (same accumulation
/// order, same values).
pub fn spmv(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv: x length");
    assert_eq!(y.len(), d, "spmv: y length");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = row_dot(ap, d, i, x);
    }
}

/// Quadratic form `xᵀ·A·x` — bit-identical to [`Matrix::quad_form`].
pub fn quad_form(ap: &[f64], d: usize, x: &[f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form: x length");
    let mut total = 0.0;
    for i in 0..d {
        total += x[i] * row_dot(ap, d, i, x);
    }
    total
}

/// Quadratic form that also writes `w = A·x` — bit-identical to
/// [`Matrix::quad_form_with`]. The learn hot path reuses `w` for the
/// fused rank-one update (see `rank_one::figmn_fused_update_packed`).
pub fn quad_form_with(ap: &[f64], d: usize, x: &[f64], w: &mut [f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form_with: x length");
    assert_eq!(w.len(), d, "quad_form_with: w length");
    let mut total = 0.0;
    for i in 0..d {
        let acc = row_dot(ap, d, i, x);
        w[i] = acc;
        total += x[i] * acc;
    }
    total
}

/// `Σⱼ A(i,j)·xⱼ` in ascending `j` — the dense row dot product, reading
/// the `j < i` entries from earlier packed rows (their `(j, i)` slot).
#[inline]
fn row_dot(ap: &[f64], d: usize, i: usize, x: &[f64]) -> f64 {
    let mut acc = 0.0;
    // Entries (i, j) with j < i: element (j, i) at pk(j, i); successive
    // j differ by d − j − 1 (one shorter packed row each step).
    let mut idx = i; // pk(0, i) = i
    for (j, &xj) in x[..i].iter().enumerate() {
        acc += ap[idx] * xj;
        idx += d - j - 1;
    }
    // Entries (i, j) with j ≥ i: the contiguous packed row i.
    let rs = row_start(i, d);
    for (a, &xj) in ap[rs..rs + d - i].iter().zip(x[i..].iter()) {
        acc += a * xj;
    }
    acc
}

/// Symmetric rank-one accumulate `A += α·u·uᵀ` on packed storage —
/// per-entry expressions identical to [`super::rank_one::syr`].
pub fn syr_packed(ap: &mut [f64], d: usize, alpha: f64, u: &[f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    debug_assert_eq!(u.len(), d);
    for i in 0..d {
        let ui = u[i];
        if ui == 0.0 {
            continue;
        }
        let rs = row_start(i, d);
        for (r, &uj) in ap[rs..rs + d - i].iter_mut().zip(u[i..].iter()) {
            *r += alpha * (ui * uj);
        }
    }
}

/// Scale every entry in place — the packed analog of
/// [`Matrix::scale_in_place`].
pub fn scale(ap: &mut [f64], s: f64) {
    for v in ap {
        *v *= s;
    }
}

// ---- Fast-mode kernels ------------------------------------------------
//
// See the module docs: same math, blocked summation order, explicitly
// NOT bit-identical to the strict kernels above.

/// Dot product in four independent accumulator lanes plus a scalar
/// tail. The lane sums combine as `(s0+s2) + (s1+s3) + tail` — a fixed
/// order, so fast-mode results are deterministic, just not equal to the
/// strict left-fold.
#[inline]
fn dot_blocked(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        lanes[0] += xa[0] * xb[0];
        lanes[1] += xa[1] * xb[1];
        lanes[2] += xa[2] * xb[2];
        lanes[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        tail += x * y;
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]) + tail
}

/// Fast symmetric mat-vec `y = A·x`: one pass over the packed rows.
/// Row `i`'s contiguous entries `(i, i..d)` feed both `y[i]`'s blocked
/// dot product and the `y[j] += A(i,j)·x[i]` update for `j > i`, so
/// each packed element is touched in cache-friendly contiguous loops
/// that LLVM vectorizes (the strict kernel's `j < i` column walk is a
/// strided scalar chain).
pub fn spmv_fast(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv_fast: x length");
    assert_eq!(y.len(), d, "spmv_fast: y length");
    y.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        let diag_dot = dot_blocked(row, &x[i..]);
        let xi = x[i];
        for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
            *yj += aij * xi;
        }
        y[i] += diag_dot;
        rs += len;
    }
}

/// Fast quadratic form `xᵀ·A·x` that also writes `w = A·x` — the
/// fast-mode analog of [`quad_form_with`]. `xᵀ·w` is taken as one final
/// blocked dot over the assembled `w`.
pub fn quad_form_with_fast(ap: &[f64], d: usize, x: &[f64], w: &mut [f64]) -> f64 {
    spmv_fast(ap, d, x, w);
    dot_blocked(x, w)
}

/// Mode dispatcher for the distance-pass kernel: strict scalar loops or
/// the blocked fast sweep.
#[inline]
pub fn quad_form_with_mode(
    ap: &[f64],
    d: usize,
    x: &[f64],
    w: &mut [f64],
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Strict => quad_form_with(ap, d, x, w),
        KernelMode::Fast => quad_form_with_fast(ap, d, x, w),
    }
}

/// Mode dispatcher for the plain quadratic form. The fast path needs a
/// `D`-float scratch buffer for `w = A·x` (the strict path ignores it),
/// so scoring loops hand in their per-thread scratch arena instead of
/// allocating.
#[inline]
pub fn quad_form_scratch(
    ap: &[f64],
    d: usize,
    x: &[f64],
    scratch: &mut [f64],
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Strict => quad_form(ap, d, x),
        KernelMode::Fast => quad_form_with_fast(ap, d, x, scratch),
    }
}

/// Mode dispatcher for the symmetric mat-vec.
#[inline]
pub fn spmv_mode(ap: &[f64], d: usize, x: &[f64], y: &mut [f64], mode: KernelMode) {
    match mode {
        KernelMode::Strict => spmv(ap, d, x, y),
        KernelMode::Fast => spmv_fast(ap, d, x, y),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rank_one::syr;
    use crate::rng::Pcg64;
    use crate::testutil::random_spd;

    fn random_sym(n: usize, rng: &mut Pcg64) -> Matrix {
        let mut m = random_spd(n, rng);
        m.symmetrize();
        m
    }

    #[test]
    fn indexing_round_trips() {
        for d in [1usize, 2, 3, 5, 8] {
            assert_eq!(packed_len(d), (0..d).map(|i| d - i).sum::<usize>());
            let mut seen = vec![false; packed_len(d)];
            for i in 0..d {
                for j in i..d {
                    let idx = row_start(i, d) + (j - i);
                    assert!(!seen[idx], "slot ({i},{j}) collides at {idx}");
                    seen[idx] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "packed slots not covered for d={d}");
        }
    }

    #[test]
    fn pack_unpack_round_trip() {
        let mut rng = Pcg64::seed(5);
        for n in 1..8 {
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            assert_eq!(ap.len(), packed_len(n));
            let back = unpack_symmetric(&ap, n);
            assert_eq!(back.as_slice(), m.as_slice(), "n={n}");
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(sym_at(&ap, n, i, j), m[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn from_diag_places_diagonal() {
        let ap = from_diag(&[2.0, 3.0, 4.0]);
        let m = unpack_symmetric(&ap, 3);
        assert_eq!(m.as_slice(), Matrix::diag(&[2.0, 3.0, 4.0]).as_slice());
    }

    /// The bit-identity contract: packed kernels equal their dense
    /// counterparts *exactly*, not just to tolerance.
    #[test]
    fn kernels_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(42);
        for trial in 0..60 {
            let n = 1 + (trial % 9);
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut y_dense = vec![0.0; n];
            m.matvec_into(&x, &mut y_dense);
            let mut y_packed = vec![0.0; n];
            spmv(&ap, n, &x, &mut y_packed);
            assert_eq!(y_dense, y_packed, "trial {trial}: spmv bits differ");

            assert!(
                m.quad_form(&x).to_bits() == quad_form(&ap, n, &x).to_bits(),
                "trial {trial}: quad_form bits differ"
            );

            let mut w_dense = vec![0.0; n];
            let q_dense = m.quad_form_with(&x, &mut w_dense);
            let mut w_packed = vec![0.0; n];
            let q_packed = quad_form_with(&ap, n, &x, &mut w_packed);
            assert_eq!(w_dense, w_packed, "trial {trial}: w bits differ");
            assert!(q_dense.to_bits() == q_packed.to_bits(), "trial {trial}: q bits differ");
        }
    }

    /// The fast-mode contract: blocked kernels agree with the strict
    /// ones to tight relative tolerance (they are the same math in a
    /// different summation order), and are deterministic run to run.
    #[test]
    fn fast_kernels_match_strict_within_tolerance() {
        let mut rng = Pcg64::seed(77);
        for trial in 0..80 {
            let n = 1 + (trial % 17);
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut y_strict = vec![0.0; n];
            spmv(&ap, n, &x, &mut y_strict);
            let mut y_fast = vec![0.0; n];
            spmv_fast(&ap, n, &x, &mut y_fast);
            for (i, (a, b)) in y_strict.iter().zip(y_fast.iter()).enumerate() {
                let tol = 1e-12 * (1.0 + a.abs());
                assert!((a - b).abs() <= tol, "trial {trial}: spmv[{i}] {a} vs {b}");
            }

            let mut w_strict = vec![0.0; n];
            let q_strict = quad_form_with(&ap, n, &x, &mut w_strict);
            let mut w_fast = vec![0.0; n];
            let q_fast = quad_form_with_fast(&ap, n, &x, &mut w_fast);
            assert!(
                (q_strict - q_fast).abs() <= 1e-12 * (1.0 + q_strict.abs()),
                "trial {trial}: quad_form {q_strict} vs {q_fast}"
            );
            assert_eq!(y_fast, w_fast, "trial {trial}: fast w must equal fast spmv");

            // Determinism within a mode: re-running gives the same bits.
            let mut w_again = vec![0.0; n];
            let q_again = quad_form_with_fast(&ap, n, &x, &mut w_again);
            assert_eq!(w_fast, w_again, "trial {trial}: fast w not deterministic");
            assert!(q_fast.to_bits() == q_again.to_bits(), "trial {trial}: fast q bits");
        }
    }

    /// Mode dispatchers route to the right kernel: `Strict` stays
    /// bit-identical to the reference loops, `Fast` to the blocked ones.
    #[test]
    fn mode_dispatchers_route_correctly() {
        let mut rng = Pcg64::seed(8);
        let n = 13;
        let m = random_sym(n, &mut rng);
        let ap = pack_symmetric(&m);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut scratch = vec![0.0; n];

        let q_ref = quad_form(&ap, n, &x);
        assert!(
            quad_form_scratch(&ap, n, &x, &mut scratch, KernelMode::Strict).to_bits()
                == q_ref.to_bits()
        );
        let mut w_fast = vec![0.0; n];
        let q_fast_ref = quad_form_with_fast(&ap, n, &x, &mut w_fast);
        assert!(
            quad_form_scratch(&ap, n, &x, &mut scratch, KernelMode::Fast).to_bits()
                == q_fast_ref.to_bits()
        );

        let mut w = vec![0.0; n];
        assert!(
            quad_form_with_mode(&ap, n, &x, &mut w, KernelMode::Strict).to_bits()
                == quad_form_with(&ap, n, &x, &mut scratch).to_bits()
        );
        let mut y_mode = vec![0.0; n];
        let mut y_fast = vec![0.0; n];
        spmv_mode(&ap, n, &x, &mut y_mode, KernelMode::Fast);
        spmv_fast(&ap, n, &x, &mut y_fast);
        assert_eq!(y_mode, y_fast);
    }

    #[test]
    fn syr_and_scale_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(9);
        for trial in 0..40 {
            let n = 1 + (trial % 7);
            let mut dense = random_sym(n, &mut rng);
            let mut ap = pack_symmetric(&dense);
            let u: Vec<f64> = (0..n)
                .map(|_| if rng.uniform() < 0.2 { 0.0 } else { rng.normal() })
                .collect();
            let alpha = rng.normal();

            syr(&mut dense, alpha, &u);
            syr_packed(&mut ap, n, alpha, &u);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: syr bits differ");

            let s = rng.normal();
            dense.scale_in_place(s);
            scale(&mut ap, s);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: scale bits differ");
        }
    }
}
