//! Packed upper-triangular storage for symmetric matrices.
//!
//! A symmetric `D×D` matrix is fully determined by its upper triangle —
//! `D·(D+1)/2` values instead of `D²`. The mixture's per-component
//! matrices (the precision `Λ` of the fast path, the covariance `C` of
//! the baseline) are kept *exactly* symmetric by the update rules (the
//! `α·(uᵢ·uⱼ)` trick in [`super::rank_one`]), so packing loses nothing —
//! and the component arenas of `gmm::ComponentStore` move roughly half
//! the bytes per kernel sweep.
//!
//! ## Layout
//!
//! Row-major upper triangle: row `i` stores entries `(i, i..D)`
//! contiguously, so element `(i, j)` with `i ≤ j` lives at
//! `row_start(i, d) + (j − i)`.
//!
//! ## Bit-identity contract (`Strict` mode)
//!
//! Every kernel here performs the **same floating-point operations in
//! the same order** as its dense counterpart in [`super::Matrix`] /
//! [`super::rank_one`]: a mat-vec still accumulates `Σⱼ A(i,j)·xⱼ` in
//! ascending `j` (reading `(j, i)` from earlier packed rows when
//! `j < i` — the same value, since the dense matrices are exactly
//! symmetric), and per-entry updates use identical expressions. Packing
//! therefore changes *where a value is stored*, never the value — the
//! crate's determinism guarantee extends across layouts, enforced by
//! this module's side-by-side tests and `tests/layout_equivalence.rs`.
//!
//! ## Fast mode (tolerance contract)
//!
//! The strict mat-vec is a scalar left-fold — a loop-carried FP
//! dependence the compiler may not reorder, so it runs one lane wide no
//! matter the hardware. The `*_fast` kernels below (selected per model
//! via [`KernelMode::Fast`]) rewrite the two reduction-bound sweeps as
//! **4-wide blocked accumulations with a scalar tail** and stream each
//! packed row exactly once (the row's entries serve `y[i]`'s dot
//! product and the `y[j] += A(i,j)·x[i]` scatter in the same pass).
//! Those loops auto-vectorize on every SIMD target without `unsafe` or
//! nightly intrinsics. The price is a *different summation order*:
//! results are no longer bit-identical to `Strict`, only
//! tolerance-equivalent (relative ~1e-12 on log-densities; see
//! [`super::KernelMode`] for the full contract). Within `Fast` mode
//! results remain deterministic — the blocked order is fixed, so every
//! thread count agrees bit for bit.
//!
//! ## Multi-query kernels (query blocking)
//!
//! The batch scoring read path is memory-bound: every query that scores
//! a mixture independently re-streams all `K` packed triangles
//! (`K·D(D+1)/2` doubles at ~1 flop/byte), so at large `D` throughput
//! is bandwidth, not compute. The `*_multi` kernels below take a `B×D`
//! block of residuals and walk the packed matrix **row-outer /
//! query-inner**: each packed row (≤ `D` contiguous doubles — L1-sized
//! even at `D` in the thousands) is loaded once and applied to every
//! query in the block while hot, raising arithmetic intensity `B×`.
//!
//! Crucially, blocking only reorders *which query* consumes a value
//! next — never the floating-point operations *within* a query. Each
//! query keeps its own accumulators and folds in exactly the per-point
//! kernel's order, so:
//!
//! - [`quad_form_multi`] / [`spmv_multi`] are **bit-identical** per
//!   query to [`quad_form`] / [`spmv`] (the `Strict` contract extends
//!   to query blocks), and
//! - [`quad_form_multi_fast`] / [`spmv_multi_fast`] are
//!   **bit-identical** per query to [`quad_form_with_fast`] /
//!   [`spmv_fast`] (the `Fast`-mode value of a query does not depend
//!   on its block, its block size, or its position in the block).
//!
//! On top of the row-outer sweep, the hot inner loops register-tile
//! four queries at a time (independent accumulator chains, so the four
//! serial FP dependences overlap), with a per-query tail for ragged
//! blocks.
//!
//! ## Explicit-SIMD tier ([`SimdTier`]) and f32 replica kernels
//!
//! Above `Fast` sits a runtime-dispatched ladder for the multi-query
//! read path only: [`simd_tier`] probes the CPU once (cached in a
//! `OnceLock`) and [`quad_form_multi_simd`] / [`quad_form_multi_f32`]
//! route to `#[target_feature(enable = "avx2,fma")]` wrappers whose
//! bodies are portable fused `mul_add` loops — LLVM compiles them with
//! FMA contraction and full vector width, no intrinsics, no nightly.
//! When the *build* itself enables `avx512f` (the CI
//! `-C target-cpu=native` job on a capable host), detection reports
//! [`SimdTier::Avx512`] and the same fused bodies run crate-wide at
//! 512-bit width. On every other target the ladder degrades to the
//! portable blocked kernels above — forcing a tier the CPU lacks via
//! the `*_tier` entry points clamps down, never UB.
//!
//! The explicit tier keeps `Fast`'s tolerance contract: same math,
//! fused/wider summation order, results within ~1e-12 relative of the
//! `Fast` kernels and deterministic for a fixed tier.
//!
//! ### f32 tolerance contract
//!
//! The `*_f32` kernels score against f32 copies of the packed arenas
//! (the snapshot read replicas of `gmm::ReplicaStore`): inputs,
//! accumulation, and the assembled `w = A·e` are all f32 — halving the
//! bytes streamed per sweep, which is the entire win on the
//! bandwidth-bound path — and only the final quadratic form is widened
//! to f64. Accuracy is therefore f32-grade: relative error
//! `O(√D · 2⁻²⁴)` on the quadratic form (≈3e-6 at D = 3072), far
//! inside the `ReplicaMode::F32 { tol }` gate (default 1e-3) but
//! nowhere near f64 bit-identity. Results are deterministic for a
//! fixed [`SimdTier`]; across hosts with different detected tiers the
//! f32 bits may differ within the same tolerance — acceptable because
//! replicas are opt-in and tolerance-gated, exactly like
//! [`KernelMode::Fast`] is against `Strict`.

use super::{KernelMode, Matrix};
use std::sync::OnceLock;

/// Packed length of a symmetric `d×d` matrix: `d·(d+1)/2`.
#[inline]
pub fn packed_len(d: usize) -> usize {
    d * (d + 1) / 2
}

/// Offset of packed row `i` (the diagonal element `(i, i)`):
/// `Σ_{r<i} (d − r) = i·d − i·(i−1)/2`, written underflow-free.
#[inline]
pub fn row_start(i: usize, d: usize) -> usize {
    i * (2 * d + 1 - i) / 2
}

/// Symmetric element access for arbitrary `(i, j)`.
#[inline]
pub fn sym_at(ap: &[f64], d: usize, i: usize, j: usize) -> f64 {
    if i <= j {
        ap[row_start(i, d) + (j - i)]
    } else {
        ap[row_start(j, d) + (i - j)]
    }
}

/// Pack the upper triangle of a (symmetric) dense matrix.
pub fn pack_symmetric(m: &Matrix) -> Vec<f64> {
    assert_eq!(m.rows(), m.cols(), "pack_symmetric: square only");
    pack_symmetric_slice(m.as_slice(), m.rows())
}

/// Pack the upper triangle of a row-major `d×d` slice.
pub fn pack_symmetric_slice(flat: &[f64], d: usize) -> Vec<f64> {
    assert_eq!(flat.len(), d * d, "pack_symmetric_slice: shape mismatch");
    let mut out = Vec::with_capacity(packed_len(d));
    for i in 0..d {
        out.extend_from_slice(&flat[i * d + i..(i + 1) * d]);
    }
    out
}

/// Expand a packed symmetric matrix back to dense (both triangles).
pub fn unpack_symmetric(ap: &[f64], d: usize) -> Matrix {
    assert_eq!(ap.len(), packed_len(d), "unpack_symmetric: length mismatch");
    let mut m = Matrix::zeros(d, d);
    for i in 0..d {
        let rs = row_start(i, d);
        for j in i..d {
            let v = ap[rs + (j - i)];
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
    }
    m
}

/// Packed diagonal matrix from the given entries.
pub fn from_diag(entries: &[f64]) -> Vec<f64> {
    let d = entries.len();
    let mut out = vec![0.0; packed_len(d)];
    for (i, &v) in entries.iter().enumerate() {
        out[row_start(i, d)] = v;
    }
    out
}

/// Symmetric mat-vec `y = A·x` — bit-identical to
/// [`Matrix::matvec_into`] on the dense expansion (same accumulation
/// order, same values).
pub fn spmv(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv: x length");
    assert_eq!(y.len(), d, "spmv: y length");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = row_dot(ap, d, i, x);
    }
}

/// Quadratic form `xᵀ·A·x` — bit-identical to [`Matrix::quad_form`].
pub fn quad_form(ap: &[f64], d: usize, x: &[f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form: x length");
    let mut total = 0.0;
    for i in 0..d {
        total += x[i] * row_dot(ap, d, i, x);
    }
    total
}

/// Quadratic form that also writes `w = A·x` — bit-identical to
/// [`Matrix::quad_form_with`]. The learn hot path reuses `w` for the
/// fused rank-one update (see `rank_one::figmn_fused_update_packed`).
pub fn quad_form_with(ap: &[f64], d: usize, x: &[f64], w: &mut [f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form_with: x length");
    assert_eq!(w.len(), d, "quad_form_with: w length");
    let mut total = 0.0;
    for i in 0..d {
        let acc = row_dot(ap, d, i, x);
        w[i] = acc;
        total += x[i] * acc;
    }
    total
}

/// `Σⱼ A(i,j)·xⱼ` in ascending `j` — the dense row dot product, reading
/// the `j < i` entries from earlier packed rows (their `(j, i)` slot).
#[inline]
fn row_dot(ap: &[f64], d: usize, i: usize, x: &[f64]) -> f64 {
    let mut acc = 0.0;
    // Entries (i, j) with j < i: element (j, i) at pk(j, i); successive
    // j differ by d − j − 1 (one shorter packed row each step).
    let mut idx = i; // pk(0, i) = i
    for (j, &xj) in x[..i].iter().enumerate() {
        acc += ap[idx] * xj;
        idx += d - j - 1;
    }
    // Entries (i, j) with j ≥ i: the contiguous packed row i.
    let rs = row_start(i, d);
    for (a, &xj) in ap[rs..rs + d - i].iter().zip(x[i..].iter()) {
        acc += a * xj;
    }
    acc
}

/// Symmetric rank-one accumulate `A += α·u·uᵀ` on packed storage —
/// per-entry expressions identical to [`super::rank_one::syr`].
pub fn syr_packed(ap: &mut [f64], d: usize, alpha: f64, u: &[f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    debug_assert_eq!(u.len(), d);
    for i in 0..d {
        let ui = u[i];
        if ui == 0.0 {
            continue;
        }
        let rs = row_start(i, d);
        for (r, &uj) in ap[rs..rs + d - i].iter_mut().zip(u[i..].iter()) {
            *r += alpha * (ui * uj);
        }
    }
}

/// Scale every entry in place — the packed analog of
/// [`Matrix::scale_in_place`].
pub fn scale(ap: &mut [f64], s: f64) {
    for v in ap {
        *v *= s;
    }
}

// ---- Fast-mode kernels ------------------------------------------------
//
// See the module docs: same math, blocked summation order, explicitly
// NOT bit-identical to the strict kernels above.

/// Dot product in four independent accumulator lanes plus a scalar
/// tail. The lane sums combine as `(s0+s2) + (s1+s3) + tail` — a fixed
/// order, so fast-mode results are deterministic, just not equal to the
/// strict left-fold.
#[inline]
fn dot_blocked(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        lanes[0] += xa[0] * xb[0];
        lanes[1] += xa[1] * xb[1];
        lanes[2] += xa[2] * xb[2];
        lanes[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        tail += x * y;
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]) + tail
}

/// Fast symmetric mat-vec `y = A·x`: one pass over the packed rows.
/// Row `i`'s contiguous entries `(i, i..d)` feed both `y[i]`'s blocked
/// dot product and the `y[j] += A(i,j)·x[i]` update for `j > i`, so
/// each packed element is touched in cache-friendly contiguous loops
/// that LLVM vectorizes (the strict kernel's `j < i` column walk is a
/// strided scalar chain).
pub fn spmv_fast(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv_fast: x length");
    assert_eq!(y.len(), d, "spmv_fast: y length");
    y.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        let diag_dot = dot_blocked(row, &x[i..]);
        let xi = x[i];
        for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
            *yj += aij * xi;
        }
        y[i] += diag_dot;
        rs += len;
    }
}

/// Fast quadratic form `xᵀ·A·x` that also writes `w = A·x` — the
/// fast-mode analog of [`quad_form_with`]. `xᵀ·w` is taken as one final
/// blocked dot over the assembled `w`.
pub fn quad_form_with_fast(ap: &[f64], d: usize, x: &[f64], w: &mut [f64]) -> f64 {
    spmv_fast(ap, d, x, w);
    dot_blocked(x, w)
}

// ---- Multi-query kernels ----------------------------------------------
//
// See the module docs: row-outer/query-inner sweeps that stream each
// packed row once per query block. Per query, the floating-point
// operations run in exactly the corresponding per-point kernel's order,
// so strict multi ≡ strict per-point and fast multi ≡ fast per-point,
// bit for bit.

/// Multi-query quadratic forms `out[q] = e_qᵀ·A·e_q` over a `b×d`
/// row-major block of residuals `es` — bit-identical per query to
/// [`quad_form`] on `es[q·d..(q+1)·d]`.
///
/// Row-outer/query-inner: packed row `i` (plus its strided `j < i`
/// column prefix) is touched once per block instead of once per query,
/// and four queries are register-tiled so their serial accumulator
/// chains overlap.
pub fn quad_form_multi(ap: &[f64], d: usize, es: &[f64], b: usize, out: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(es.len(), b * d, "quad_form_multi: residual block shape");
    assert_eq!(out.len(), b, "quad_form_multi: out length");
    out.fill(0.0);
    for i in 0..d {
        let rs = row_start(i, d);
        let row = &ap[rs..rs + d - i];
        let mut q = 0usize;
        while q + 4 <= b {
            let x0 = &es[q * d..(q + 1) * d];
            let x1 = &es[(q + 1) * d..(q + 2) * d];
            let x2 = &es[(q + 2) * d..(q + 3) * d];
            let x3 = &es[(q + 3) * d..(q + 4) * d];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            // Entries (i, j) with j < i — the same strided index walk as
            // `row_dot`, each element applied to all four queries.
            let mut idx = i; // pk(0, i) = i
            for j in 0..i {
                let a = ap[idx];
                a0 += a * x0[j];
                a1 += a * x1[j];
                a2 += a * x2[j];
                a3 += a * x3[j];
                idx += d - j - 1;
            }
            // Entries (i, j) with j ≥ i — the contiguous packed row.
            for (t, &a) in row.iter().enumerate() {
                let j = i + t;
                a0 += a * x0[j];
                a1 += a * x1[j];
                a2 += a * x2[j];
                a3 += a * x3[j];
            }
            out[q] += x0[i] * a0;
            out[q + 1] += x1[i] * a1;
            out[q + 2] += x2[i] * a2;
            out[q + 3] += x3[i] * a3;
            q += 4;
        }
        // Ragged tail: plain per-query row dot, same order.
        for bi in q..b {
            let x = &es[bi * d..(bi + 1) * d];
            out[bi] += x[i] * row_dot(ap, d, i, x);
        }
    }
}

/// Multi-RHS symmetric mat-vec `ys[q] = A·xs[q]` over `b×d` row-major
/// blocks — bit-identical per query to [`spmv`]. Row-outer/query-inner,
/// so each packed row (and its column prefix) is streamed once per
/// block.
///
/// This is the strict reference of the multi-RHS pair
/// ([`spmv_multi_fast`] backs the fast blocked quadratic forms); no
/// scoring surface needs the full strict mat-vec per query yet — the
/// blocked conditional path works on index subsets via
/// `gmm::inference::precision_conditional_multi` — so its callers today
/// are the equivalence tests that pin it to [`spmv`].
pub fn spmv_multi(ap: &[f64], d: usize, xs: &[f64], b: usize, ys: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(xs.len(), b * d, "spmv_multi: x block shape");
    assert_eq!(ys.len(), b * d, "spmv_multi: y block shape");
    for i in 0..d {
        for bi in 0..b {
            ys[bi * d + i] = row_dot(ap, d, i, &xs[bi * d..(bi + 1) * d]);
        }
    }
}

/// Fast-mode multi-RHS symmetric mat-vec — bit-identical per query to
/// [`spmv_fast`]: one pass over the packed rows serving every query,
/// with the `j > i` scatter register-tiled four queries wide (each row
/// element is loaded once per tile instead of once per query).
pub fn spmv_multi_fast(ap: &[f64], d: usize, xs: &[f64], b: usize, ys: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(xs.len(), b * d, "spmv_multi_fast: x block shape");
    assert_eq!(ys.len(), b * d, "spmv_multi_fast: y block shape");
    ys.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        let mut q = 0usize;
        while q + 4 <= b {
            let x0 = &xs[q * d..(q + 1) * d];
            let x1 = &xs[(q + 1) * d..(q + 2) * d];
            let x2 = &xs[(q + 2) * d..(q + 3) * d];
            let x3 = &xs[(q + 3) * d..(q + 4) * d];
            // Per query: blocked diagonal dot, then the j > i scatter,
            // then the y[i] update — exactly `spmv_fast`'s order (the
            // queries' FP streams are independent, so interleaving them
            // cannot change any query's bits).
            let d0 = dot_blocked(row, &x0[i..]);
            let d1 = dot_blocked(row, &x1[i..]);
            let d2 = dot_blocked(row, &x2[i..]);
            let d3 = dot_blocked(row, &x3[i..]);
            let (xi0, xi1, xi2, xi3) = (x0[i], x1[i], x2[i], x3[i]);
            let tile = &mut ys[q * d..(q + 4) * d];
            let (y01, y23) = tile.split_at_mut(2 * d);
            let (y0, y1) = y01.split_at_mut(d);
            let (y2, y3) = y23.split_at_mut(d);
            for (t, &aij) in row[1..].iter().enumerate() {
                let j = i + 1 + t;
                y0[j] += aij * xi0;
                y1[j] += aij * xi1;
                y2[j] += aij * xi2;
                y3[j] += aij * xi3;
            }
            y0[i] += d0;
            y1[i] += d1;
            y2[i] += d2;
            y3[i] += d3;
            q += 4;
        }
        // Ragged tail: the per-point fast body, one query at a time.
        for bi in q..b {
            let x = &xs[bi * d..(bi + 1) * d];
            let y = &mut ys[bi * d..(bi + 1) * d];
            let diag_dot = dot_blocked(row, &x[i..]);
            let xi = x[i];
            for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
                *yj += aij * xi;
            }
            y[i] += diag_dot;
        }
        rs += len;
    }
}

/// Fast-mode multi-query quadratic forms — bit-identical per query to
/// [`quad_form_with_fast`]: the block mat-vec assembles `w_q = A·e_q`
/// into the caller's `b×d` scratch `ws` (streamed from L2 while the
/// matrix streams from memory once per block), then each query's form
/// is one final blocked dot.
pub fn quad_form_multi_fast(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
) {
    assert_eq!(out.len(), b, "quad_form_multi_fast: out length");
    spmv_multi_fast(ap, d, es, b, ws);
    for (bi, o) in out.iter_mut().enumerate() {
        *o = dot_blocked(&es[bi * d..(bi + 1) * d], &ws[bi * d..(bi + 1) * d]);
    }
}

/// Mode dispatcher for the multi-query quadratic form. `ws` is the
/// fast path's `b×d` w-block scratch; the strict path never reads it
/// (callers pass an empty slice in strict mode).
#[inline]
pub fn quad_form_multi_mode(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
    mode: KernelMode,
) {
    match mode {
        KernelMode::Strict => quad_form_multi(ap, d, es, b, out),
        KernelMode::Fast => quad_form_multi_fast(ap, d, es, b, ws, out),
    }
}

/// Mode dispatcher for the *learn-side* multi-query distance pass: like
/// [`quad_form_multi_mode`], but **both** arms also assemble the `b×d`
/// w-block `ws[q] = A·e_q`, which the fused rank-one update stage of the
/// mini-batch learn pipeline reuses (`gmm::learn_pipeline`). Each arm is
/// bit-identical per query to the per-point learn kernel of its mode:
///
/// - `Strict`: [`spmv_multi`] assembles the w-block row-outer, then each
///   query's form is the ascending left-fold `Σᵢ xᵢ·wᵢ` — exactly
///   [`quad_form_with`]'s `total` accumulation order, so the pass scores
///   precisely what the online strict path would.
/// - `Fast`: [`quad_form_multi_fast`], already bit-identical per query
///   to [`quad_form_with_fast`].
#[inline]
pub fn quad_form_with_multi_mode(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
    mode: KernelMode,
) {
    match mode {
        KernelMode::Strict => {
            assert_eq!(out.len(), b, "quad_form_with_multi_mode: out length");
            spmv_multi(ap, d, es, b, ws);
            for (bi, o) in out.iter_mut().enumerate() {
                *o = super::dot(&es[bi * d..(bi + 1) * d], &ws[bi * d..(bi + 1) * d]);
            }
        }
        KernelMode::Fast => quad_form_multi_fast(ap, d, es, b, ws, out),
    }
}

/// Mode dispatcher for the distance-pass kernel: strict scalar loops or
/// the blocked fast sweep.
#[inline]
pub fn quad_form_with_mode(
    ap: &[f64],
    d: usize,
    x: &[f64],
    w: &mut [f64],
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Strict => quad_form_with(ap, d, x, w),
        KernelMode::Fast => quad_form_with_fast(ap, d, x, w),
    }
}

/// Mode dispatcher for the plain quadratic form. The fast path needs a
/// `D`-float scratch buffer for `w = A·x` (the strict path ignores it),
/// so scoring loops hand in their per-thread scratch arena instead of
/// allocating.
#[inline]
pub fn quad_form_scratch(
    ap: &[f64],
    d: usize,
    x: &[f64],
    scratch: &mut [f64],
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Strict => quad_form(ap, d, x),
        KernelMode::Fast => quad_form_with_fast(ap, d, x, scratch),
    }
}

/// Mode dispatcher for the symmetric mat-vec.
#[inline]
pub fn spmv_mode(ap: &[f64], d: usize, x: &[f64], y: &mut [f64], mode: KernelMode) {
    match mode {
        KernelMode::Strict => spmv(ap, d, x, y),
        KernelMode::Fast => spmv_fast(ap, d, x, y),
    }
}

/// Gershgorin lower bound on the smallest eigenvalue of a packed
/// symmetric matrix, clamped at zero: `max(0, minᵢ(aᵢᵢ − Σ_{j≠i}|aᵢⱼ|))`.
///
/// One pass over the packed upper triangle, accumulating each entry into
/// the off-diagonal sums of *both* its row and its column. Used by the
/// candidate index to turn a Euclidean distance-to-cell bound into a
/// valid Mahalanobis lower bound (`d²_Λ ≥ λ_min·d²_euclid`); a zero
/// return makes the bound vacuous, never wrong.
pub fn gershgorin_floor(ap: &[f64], d: usize) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    let mut diag = vec![0.0; d];
    let mut off = vec![0.0; d];
    let mut idx = 0;
    for i in 0..d {
        diag[i] = ap[idx];
        idx += 1;
        for j in i + 1..d {
            let a = ap[idx].abs();
            off[i] += a;
            off[j] += a;
            idx += 1;
        }
    }
    let mut floor = f64::INFINITY;
    for i in 0..d {
        floor = floor.min(diag[i] - off[i]);
    }
    floor.max(0.0)
}

// ---- Explicit-SIMD tier ------------------------------------------------
//
// See the module docs: a runtime-dispatched ladder above `Fast` for the
// multi-query read path. The tier functions never change which queries
// are scored or what math runs — only the summation order (fused
// multiply-adds, wider lanes), so everything here is tolerance-bound to
// the `Fast` kernels, and the f32 variants to the f64 ones.

/// SIMD dispatch tier for the multi-query scoring kernels, ordered
/// `Scalar < Fma < Avx512` so a requested tier can be clamped to the
/// detected one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdTier {
    /// Portable blocked kernels — the guaranteed fallback on every
    /// target (and the only tier on non-x86-64).
    Scalar,
    /// AVX2 + FMA, runtime-detected on x86-64: `#[target_feature]`
    /// wrappers around fused `mul_add` bodies.
    Fma,
    /// 512-bit vectors when the build enables `avx512f`
    /// (`-C target-cpu=native` on a capable host); the fused bodies are
    /// then compiled crate-wide at full width, no wrapper needed.
    Avx512,
}

impl SimdTier {
    /// Stable lower-case name (stats/logging).
    pub fn as_str(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Fma => "fma",
            SimdTier::Avx512 => "avx512",
        }
    }
}

impl std::fmt::Display for SimdTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The best [`SimdTier`] this process can safely run — probed once,
/// cached for the process lifetime.
pub fn simd_tier() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(detect_simd_tier)
}

fn detect_simd_tier() -> SimdTier {
    #[cfg(target_arch = "x86_64")]
    {
        if cfg!(target_feature = "avx512f") {
            return SimdTier::Avx512;
        }
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdTier::Fma;
        }
    }
    SimdTier::Scalar
}

/// Fused dot product: eight independent `mul_add` lanes plus a fused
/// scalar tail, combined in a fixed pairwise order. Compiled inside a
/// `target_feature` wrapper (or an AVX-512 build) the `mul_add`s lower
/// to hardware FMA; elsewhere this body is never selected (libm `fma`
/// would be slow, not wrong).
#[inline(always)]
fn dot_fused(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = xa[l].mul_add(xb[l], *lane);
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        tail = x.mul_add(*y, tail);
    }
    ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
        + tail
}

/// Fused f64 multi-query quadratic form body — `quad_form_multi_fast`'s
/// row-outer sweep with `mul_add` accumulation and 8-wide lane blocks.
/// `#[inline(always)]` so each `target_feature` wrapper recompiles it
/// at that feature set's full vector width.
#[inline(always)]
fn quad_form_multi_f64_fused(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(es.len(), b * d, "quad_form_multi_simd: residual block shape");
    assert_eq!(ws.len(), b * d, "quad_form_multi_simd: scratch shape");
    assert_eq!(out.len(), b, "quad_form_multi_simd: out length");
    ws.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        for bi in 0..b {
            let x = &es[bi * d..(bi + 1) * d];
            let y = &mut ws[bi * d..(bi + 1) * d];
            let diag_dot = dot_fused(row, &x[i..]);
            let xi = x[i];
            for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
                *yj = aij.mul_add(xi, *yj);
            }
            y[i] += diag_dot;
        }
        rs += len;
    }
    for (bi, o) in out.iter_mut().enumerate() {
        *o = dot_fused(&es[bi * d..(bi + 1) * d], &ws[bi * d..(bi + 1) * d]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn quad_form_multi_f64_fma(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
) {
    quad_form_multi_f64_fused(ap, d, es, b, ws, out)
}

/// Explicit-SIMD multi-query quadratic form: [`quad_form_multi_fast`]
/// semantics at the best tier the CPU supports (within ~1e-12 relative
/// of the `Fast` kernel — see the module docs). `ws` is the `b×d`
/// w-block scratch.
pub fn quad_form_multi_simd(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
) {
    quad_form_multi_simd_tier(ap, d, es, b, ws, out, simd_tier())
}

/// Tier-forcing variant of [`quad_form_multi_simd`] (tests, benches).
/// The requested tier is clamped to the detected one: forcing `Scalar`
/// works everywhere and runs the portable `Fast` kernel bit-for-bit;
/// forcing a tier the CPU lacks degrades safely, never UB.
pub fn quad_form_multi_simd_tier(
    ap: &[f64],
    d: usize,
    es: &[f64],
    b: usize,
    ws: &mut [f64],
    out: &mut [f64],
    tier: SimdTier,
) {
    let eff = tier.min(simd_tier());
    match eff {
        SimdTier::Scalar => quad_form_multi_fast(ap, d, es, b, ws, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `eff ≤ simd_tier()`, and `Fma` is only ever detected
        // when avx2+fma are present on the running CPU.
        SimdTier::Fma => unsafe { quad_form_multi_f64_fma(ap, d, es, b, ws, out) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Fma => quad_form_multi_fast(ap, d, es, b, ws, out),
        // Only reachable when the build enables avx512f globally, so the
        // plain body already compiles at full width.
        SimdTier::Avx512 => quad_form_multi_f64_fused(ap, d, es, b, ws, out),
    }
}

/// Fused f64 symmetric mat-vec body — [`spmv_fast`]'s one-pass row
/// sweep with `mul_add` accumulation ([`dot_fused`] diagonal dots, fused
/// `j > i` scatter). `#[inline(always)]` so each `target_feature`
/// wrapper recompiles it at that feature set's full vector width.
#[inline(always)]
fn spmv_f64_fused(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv_simd: x length");
    assert_eq!(y.len(), d, "spmv_simd: y length");
    y.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        let diag_dot = dot_fused(row, &x[i..]);
        let xi = x[i];
        for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
            *yj = aij.mul_add(xi, *yj);
        }
        y[i] += diag_dot;
        rs += len;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn spmv_f64_fma(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    spmv_f64_fused(ap, d, x, y)
}

/// Explicit-SIMD symmetric mat-vec: [`spmv_fast`] semantics at the best
/// tier the CPU supports — the ladder's **write-path** extension (the
/// `Λ·e` sweep of the learn distance pass). Same tolerance contract as
/// [`quad_form_multi_simd`]: within ~1e-12 relative of the `Fast`
/// kernel, deterministic for a fixed tier.
pub fn spmv_simd(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    spmv_simd_tier(ap, d, x, y, simd_tier())
}

/// Tier-forcing variant of [`spmv_simd`] (tests, benches). The
/// requested tier is clamped to the detected one; forced `Scalar` runs
/// the portable [`spmv_fast`] kernel bit for bit.
pub fn spmv_simd_tier(ap: &[f64], d: usize, x: &[f64], y: &mut [f64], tier: SimdTier) {
    let eff = tier.min(simd_tier());
    match eff {
        SimdTier::Scalar => spmv_fast(ap, d, x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `eff ≤ simd_tier()`, and `Fma` is only ever detected
        // when avx2+fma are present on the running CPU.
        SimdTier::Fma => unsafe { spmv_f64_fma(ap, d, x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Fma => spmv_fast(ap, d, x, y),
        // Only reachable when the build enables avx512f globally, so the
        // plain body already compiles at full width.
        SimdTier::Avx512 => spmv_f64_fused(ap, d, x, y),
    }
}

// ---- f32 replica kernels -----------------------------------------------

/// f32 blocked dot: eight lanes plus tail, f32 accumulation. `FMA`
/// selects fused `mul_add` lanes (only compiled into feature-gated or
/// AVX-512 builds) vs plain mul+add (the portable fallback).
#[inline(always)]
fn dot_blocked_f32<const FMA: bool>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            if FMA {
                *lane = xa[l].mul_add(xb[l], *lane);
            } else {
                *lane += xa[l] * xb[l];
            }
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        if FMA {
            tail = x.mul_add(*y, tail);
        } else {
            tail += x * y;
        }
    }
    ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
        + tail
}

/// f32 multi-query quadratic form body — the row-outer sweep over an
/// f32 packed triangle and `b×d` f32 residual block, f32 scratch `ws`,
/// each query's final form widened to f64 on output.
#[inline(always)]
fn quad_form_multi_f32_body<const FMA: bool>(
    ap: &[f32],
    d: usize,
    es: &[f32],
    b: usize,
    ws: &mut [f32],
    out: &mut [f64],
) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(es.len(), b * d, "quad_form_multi_f32: residual block shape");
    assert_eq!(ws.len(), b * d, "quad_form_multi_f32: scratch shape");
    assert_eq!(out.len(), b, "quad_form_multi_f32: out length");
    ws.fill(0.0);
    let mut rs = 0usize;
    for i in 0..d {
        let len = d - i;
        let row = &ap[rs..rs + len];
        for bi in 0..b {
            let x = &es[bi * d..(bi + 1) * d];
            let y = &mut ws[bi * d..(bi + 1) * d];
            let diag_dot = dot_blocked_f32::<FMA>(row, &x[i..]);
            let xi = x[i];
            for (yj, &aij) in y[i + 1..].iter_mut().zip(row[1..].iter()) {
                if FMA {
                    *yj = aij.mul_add(xi, *yj);
                } else {
                    *yj += aij * xi;
                }
            }
            y[i] += diag_dot;
        }
        rs += len;
    }
    for (bi, o) in out.iter_mut().enumerate() {
        *o = dot_blocked_f32::<FMA>(&es[bi * d..(bi + 1) * d], &ws[bi * d..(bi + 1) * d]) as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn quad_form_multi_f32_fma(
    ap: &[f32],
    d: usize,
    es: &[f32],
    b: usize,
    ws: &mut [f32],
    out: &mut [f64],
) {
    quad_form_multi_f32_body::<true>(ap, d, es, b, ws, out)
}

/// f32 multi-query quadratic forms over an f32 packed triangle at the
/// best detected [`SimdTier`] — the replica read path's kernel. See the
/// module docs for the tolerance contract; `ws` is a `b×d` f32 scratch.
pub fn quad_form_multi_f32(
    ap: &[f32],
    d: usize,
    es: &[f32],
    b: usize,
    ws: &mut [f32],
    out: &mut [f64],
) {
    quad_form_multi_f32_tier(ap, d, es, b, ws, out, simd_tier())
}

/// Tier-forcing variant of [`quad_form_multi_f32`]; the requested tier
/// is clamped to the detected one (see [`quad_form_multi_simd_tier`]).
pub fn quad_form_multi_f32_tier(
    ap: &[f32],
    d: usize,
    es: &[f32],
    b: usize,
    ws: &mut [f32],
    out: &mut [f64],
    tier: SimdTier,
) {
    let eff = tier.min(simd_tier());
    match eff {
        SimdTier::Scalar => quad_form_multi_f32_body::<false>(ap, d, es, b, ws, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `eff ≤ simd_tier()`, so avx2+fma are present.
        SimdTier::Fma => unsafe { quad_form_multi_f32_fma(ap, d, es, b, ws, out) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdTier::Fma => quad_form_multi_f32_body::<false>(ap, d, es, b, ws, out),
        SimdTier::Avx512 => quad_form_multi_f32_body::<true>(ap, d, es, b, ws, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rank_one::syr;
    use crate::rng::Pcg64;
    use crate::testutil::random_spd;

    fn random_sym(n: usize, rng: &mut Pcg64) -> Matrix {
        let mut m = random_spd(n, rng);
        m.symmetrize();
        m
    }

    #[test]
    fn gershgorin_floor_bounds_lambda_min() {
        // Diagonally dominant: floor is min_i(a_ii − Σ|a_ij|) > 0.
        let m = Matrix::from_rows(3, 3, &[5.0, 1.0, -0.5, 1.0, 4.0, 0.25, -0.5, 0.25, 3.0]);
        let ap = pack_symmetric(&m);
        let floor = gershgorin_floor(&ap, 3);
        assert!((floor - (5.0 - 1.5)).abs() < 1e-12);
        // The bound is a true eigenvalue lower bound: x^T A x >= floor·‖x‖².
        let mut rng = Pcg64::seed(7);
        for _ in 0..20 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            assert!(quad_form(&ap, 3, &x) >= floor * crate::linalg::norm2(&x) - 1e-12);
        }
        // Not diagonally dominant → clamps to 0 (vacuous, never negative).
        let w = Matrix::from_rows(2, 2, &[1.0, 5.0, 5.0, 1.0]);
        assert_eq!(gershgorin_floor(&pack_symmetric(&w), 2), 0.0);
    }

    #[test]
    fn indexing_round_trips() {
        for d in [1usize, 2, 3, 5, 8] {
            assert_eq!(packed_len(d), (0..d).map(|i| d - i).sum::<usize>());
            let mut seen = vec![false; packed_len(d)];
            for i in 0..d {
                for j in i..d {
                    let idx = row_start(i, d) + (j - i);
                    assert!(!seen[idx], "slot ({i},{j}) collides at {idx}");
                    seen[idx] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "packed slots not covered for d={d}");
        }
    }

    #[test]
    fn pack_unpack_round_trip() {
        let mut rng = Pcg64::seed(5);
        for n in 1..8 {
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            assert_eq!(ap.len(), packed_len(n));
            let back = unpack_symmetric(&ap, n);
            assert_eq!(back.as_slice(), m.as_slice(), "n={n}");
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(sym_at(&ap, n, i, j), m[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn from_diag_places_diagonal() {
        let ap = from_diag(&[2.0, 3.0, 4.0]);
        let m = unpack_symmetric(&ap, 3);
        assert_eq!(m.as_slice(), Matrix::diag(&[2.0, 3.0, 4.0]).as_slice());
    }

    /// The bit-identity contract: packed kernels equal their dense
    /// counterparts *exactly*, not just to tolerance.
    #[test]
    fn kernels_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(42);
        for trial in 0..60 {
            let n = 1 + (trial % 9);
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut y_dense = vec![0.0; n];
            m.matvec_into(&x, &mut y_dense);
            let mut y_packed = vec![0.0; n];
            spmv(&ap, n, &x, &mut y_packed);
            assert_eq!(y_dense, y_packed, "trial {trial}: spmv bits differ");

            assert!(
                m.quad_form(&x).to_bits() == quad_form(&ap, n, &x).to_bits(),
                "trial {trial}: quad_form bits differ"
            );

            let mut w_dense = vec![0.0; n];
            let q_dense = m.quad_form_with(&x, &mut w_dense);
            let mut w_packed = vec![0.0; n];
            let q_packed = quad_form_with(&ap, n, &x, &mut w_packed);
            assert_eq!(w_dense, w_packed, "trial {trial}: w bits differ");
            assert!(q_dense.to_bits() == q_packed.to_bits(), "trial {trial}: q bits differ");
        }
    }

    /// The fast-mode contract: blocked kernels agree with the strict
    /// ones to tight relative tolerance (they are the same math in a
    /// different summation order), and are deterministic run to run.
    #[test]
    fn fast_kernels_match_strict_within_tolerance() {
        let mut rng = Pcg64::seed(77);
        for trial in 0..80 {
            let n = 1 + (trial % 17);
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut y_strict = vec![0.0; n];
            spmv(&ap, n, &x, &mut y_strict);
            let mut y_fast = vec![0.0; n];
            spmv_fast(&ap, n, &x, &mut y_fast);
            for (i, (a, b)) in y_strict.iter().zip(y_fast.iter()).enumerate() {
                let tol = 1e-12 * (1.0 + a.abs());
                assert!((a - b).abs() <= tol, "trial {trial}: spmv[{i}] {a} vs {b}");
            }

            let mut w_strict = vec![0.0; n];
            let q_strict = quad_form_with(&ap, n, &x, &mut w_strict);
            let mut w_fast = vec![0.0; n];
            let q_fast = quad_form_with_fast(&ap, n, &x, &mut w_fast);
            assert!(
                (q_strict - q_fast).abs() <= 1e-12 * (1.0 + q_strict.abs()),
                "trial {trial}: quad_form {q_strict} vs {q_fast}"
            );
            assert_eq!(y_fast, w_fast, "trial {trial}: fast w must equal fast spmv");

            // Determinism within a mode: re-running gives the same bits.
            let mut w_again = vec![0.0; n];
            let q_again = quad_form_with_fast(&ap, n, &x, &mut w_again);
            assert_eq!(w_fast, w_again, "trial {trial}: fast w not deterministic");
            assert!(q_fast.to_bits() == q_again.to_bits(), "trial {trial}: fast q bits");
        }
    }

    /// Mode dispatchers route to the right kernel: `Strict` stays
    /// bit-identical to the reference loops, `Fast` to the blocked ones.
    #[test]
    fn mode_dispatchers_route_correctly() {
        let mut rng = Pcg64::seed(8);
        let n = 13;
        let m = random_sym(n, &mut rng);
        let ap = pack_symmetric(&m);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut scratch = vec![0.0; n];

        let q_ref = quad_form(&ap, n, &x);
        assert!(
            quad_form_scratch(&ap, n, &x, &mut scratch, KernelMode::Strict).to_bits()
                == q_ref.to_bits()
        );
        let mut w_fast = vec![0.0; n];
        let q_fast_ref = quad_form_with_fast(&ap, n, &x, &mut w_fast);
        assert!(
            quad_form_scratch(&ap, n, &x, &mut scratch, KernelMode::Fast).to_bits()
                == q_fast_ref.to_bits()
        );

        let mut w = vec![0.0; n];
        assert!(
            quad_form_with_mode(&ap, n, &x, &mut w, KernelMode::Strict).to_bits()
                == quad_form_with(&ap, n, &x, &mut scratch).to_bits()
        );
        let mut y_mode = vec![0.0; n];
        let mut y_fast = vec![0.0; n];
        spmv_mode(&ap, n, &x, &mut y_mode, KernelMode::Fast);
        spmv_fast(&ap, n, &x, &mut y_fast);
        assert_eq!(y_mode, y_fast);
    }

    /// The multi-query contract: strict multi kernels are bit-identical
    /// per query to the strict per-point kernels, across block sizes
    /// that exercise the 4-query register tile and its ragged tail.
    #[test]
    fn multi_kernels_bit_identical_to_per_point() {
        let mut rng = Pcg64::seed(61);
        for &b in &[1usize, 2, 3, 4, 5, 7, 8, 9, 33] {
            for n in [1usize, 2, 5, 13, 24] {
                let m = random_sym(n, &mut rng);
                let ap = pack_symmetric(&m);
                let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

                let mut out = vec![0.0; b];
                quad_form_multi(&ap, n, &es, b, &mut out);
                let mut ys = vec![0.0; b * n];
                spmv_multi(&ap, n, &es, b, &mut ys);
                for bi in 0..b {
                    let x = &es[bi * n..(bi + 1) * n];
                    let expect = quad_form(&ap, n, x);
                    assert!(
                        out[bi].to_bits() == expect.to_bits(),
                        "b={b} n={n}: quad_form_multi[{bi}] bits differ"
                    );
                    let mut y = vec![0.0; n];
                    spmv(&ap, n, x, &mut y);
                    assert_eq!(&ys[bi * n..(bi + 1) * n], &y[..], "b={b} n={n}: spmv_multi[{bi}]");
                }
            }
        }
    }

    /// Fast multi kernels are bit-identical per query to the fast
    /// per-point kernels — the `Fast`-mode value of a query does not
    /// depend on its block, the block size, or its tile position.
    #[test]
    fn fast_multi_kernels_bit_identical_to_fast_per_point() {
        let mut rng = Pcg64::seed(62);
        for &b in &[1usize, 3, 4, 6, 8, 33] {
            for n in [1usize, 2, 5, 16, 24] {
                let m = random_sym(n, &mut rng);
                let ap = pack_symmetric(&m);
                let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

                let mut ys = vec![0.0; b * n];
                spmv_multi_fast(&ap, n, &es, b, &mut ys);
                let mut ws = vec![0.0; b * n];
                let mut out = vec![0.0; b];
                quad_form_multi_fast(&ap, n, &es, b, &mut ws, &mut out);
                for bi in 0..b {
                    let x = &es[bi * n..(bi + 1) * n];
                    let mut y = vec![0.0; n];
                    spmv_fast(&ap, n, x, &mut y);
                    assert_eq!(
                        &ys[bi * n..(bi + 1) * n],
                        &y[..],
                        "b={b} n={n}: spmv_multi_fast[{bi}]"
                    );
                    let mut w = vec![0.0; n];
                    let expect = quad_form_with_fast(&ap, n, x, &mut w);
                    assert!(
                        out[bi].to_bits() == expect.to_bits(),
                        "b={b} n={n}: quad_form_multi_fast[{bi}] bits differ"
                    );
                    assert_eq!(&ws[bi * n..(bi + 1) * n], &w[..], "b={b} n={n}: w block[{bi}]");
                }
            }
        }
    }

    /// Block composition cannot change a query's value: scoring a batch
    /// in one call equals scoring any partition of it, bitwise, in both
    /// modes.
    #[test]
    fn multi_kernels_are_block_boundary_invariant() {
        let mut rng = Pcg64::seed(63);
        let n = 11;
        let b = 9;
        let m = random_sym(n, &mut rng);
        let ap = pack_symmetric(&m);
        let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

        let mut whole = vec![0.0; b];
        quad_form_multi(&ap, n, &es, b, &mut whole);
        let mut whole_fast = vec![0.0; b];
        let mut ws = vec![0.0; b * n];
        quad_form_multi_fast(&ap, n, &es, b, &mut ws, &mut whole_fast);
        // Split 9 = 4 + 5 (one full tile + tile-with-tail).
        for (lo, hi) in [(0usize, 4usize), (4, 9)] {
            let part = &es[lo * n..hi * n];
            let pb = hi - lo;
            let mut out = vec![0.0; pb];
            quad_form_multi(&ap, n, part, pb, &mut out);
            assert_eq!(&whole[lo..hi], &out[..], "strict split {lo}..{hi}");
            let mut wpart = vec![0.0; pb * n];
            quad_form_multi_fast(&ap, n, part, pb, &mut wpart, &mut out);
            assert_eq!(&whole_fast[lo..hi], &out[..], "fast split {lo}..{hi}");
        }
    }

    /// The multi mode dispatcher routes to the matching kernel.
    #[test]
    fn multi_mode_dispatcher_routes_correctly() {
        let mut rng = Pcg64::seed(64);
        let n = 7;
        let b = 5;
        let m = random_sym(n, &mut rng);
        let ap = pack_symmetric(&m);
        let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

        let mut expect = vec![0.0; b];
        quad_form_multi(&ap, n, &es, b, &mut expect);
        let mut out = vec![0.0; b];
        quad_form_multi_mode(&ap, n, &es, b, &mut [], &mut out, KernelMode::Strict);
        assert_eq!(out, expect);

        let mut ws = vec![0.0; b * n];
        quad_form_multi_fast(&ap, n, &es, b, &mut ws, &mut expect);
        let mut ws2 = vec![0.0; b * n];
        quad_form_multi_mode(&ap, n, &es, b, &mut ws2, &mut out, KernelMode::Fast);
        assert_eq!(out, expect);
        assert_eq!(ws, ws2);
    }

    #[test]
    fn syr_and_scale_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(9);
        for trial in 0..40 {
            let n = 1 + (trial % 7);
            let mut dense = random_sym(n, &mut rng);
            let mut ap = pack_symmetric(&dense);
            let u: Vec<f64> = (0..n)
                .map(|_| if rng.uniform() < 0.2 { 0.0 } else { rng.normal() })
                .collect();
            let alpha = rng.normal();

            syr(&mut dense, alpha, &u);
            syr_packed(&mut ap, n, alpha, &u);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: syr bits differ");

            let s = rng.normal();
            dense.scale_in_place(s);
            scale(&mut ap, s);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: scale bits differ");
        }
    }

    /// The explicit-SIMD tier keeps the `Fast` tolerance contract:
    /// dispatched results are within 1e-12 relative of the `Fast`
    /// kernel, forced `Scalar` IS the `Fast` kernel bit for bit, and a
    /// forced tier above the detected one clamps down to the dispatched
    /// result (the runtime fallback on CPUs lacking the feature).
    #[test]
    fn simd_tier_matches_fast_within_tolerance() {
        let mut rng = Pcg64::seed(91);
        for &b in &[1usize, 3, 8, 33] {
            for n in [1usize, 2, 5, 16, 64] {
                let m = random_sym(n, &mut rng);
                let ap = pack_symmetric(&m);
                let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

                let mut ws = vec![0.0; b * n];
                let mut fast = vec![0.0; b];
                quad_form_multi_fast(&ap, n, &es, b, &mut ws, &mut fast);

                let mut simd = vec![0.0; b];
                quad_form_multi_simd(&ap, n, &es, b, &mut ws, &mut simd);
                for (bi, (f, s)) in fast.iter().zip(simd.iter()).enumerate() {
                    let tol = 1e-12 * (1.0 + f.abs());
                    assert!((f - s).abs() <= tol, "b={b} n={n} q={bi}: {f} vs {s}");
                }

                // Forced Scalar == the portable Fast kernel, bitwise.
                let mut scalar = vec![0.0; b];
                quad_form_multi_simd_tier(&ap, n, &es, b, &mut ws, &mut scalar, SimdTier::Scalar);
                for bi in 0..b {
                    assert!(
                        scalar[bi].to_bits() == fast[bi].to_bits(),
                        "b={b} n={n} q={bi}: forced-scalar bits differ from fast"
                    );
                }

                // Forcing above the detected tier clamps to the detected
                // one — identical bits to the auto dispatch.
                let mut clamped = vec![0.0; b];
                quad_form_multi_simd_tier(&ap, n, &es, b, &mut ws, &mut clamped, SimdTier::Avx512);
                for bi in 0..b {
                    assert!(
                        clamped[bi].to_bits() == simd[bi].to_bits(),
                        "b={b} n={n} q={bi}: clamped tier diverges from dispatch"
                    );
                }

                // Determinism for a fixed tier: re-running gives the
                // same bits.
                let mut again = vec![0.0; b];
                quad_form_multi_simd(&ap, n, &es, b, &mut ws, &mut again);
                assert_eq!(
                    simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    again.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "b={b} n={n}: simd tier not deterministic"
                );
            }
        }
    }

    /// The f32 replica kernels match the f64 path to f32-grade relative
    /// tolerance across tiers, and every tier agrees with every other
    /// within the same bound.
    #[test]
    fn f32_kernels_match_f64_within_f32_tolerance() {
        let mut rng = Pcg64::seed(92);
        for &b in &[1usize, 4, 9, 33] {
            for n in [1usize, 2, 5, 16, 64] {
                let m = random_sym(n, &mut rng);
                let ap = pack_symmetric(&m);
                let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();
                let ap32: Vec<f32> = ap.iter().map(|&v| v as f32).collect();
                let es32: Vec<f32> = es.iter().map(|&v| v as f32).collect();

                let mut expect = vec![0.0; b];
                quad_form_multi(&ap, n, &es, b, &mut expect);

                let mut ws32 = vec![0.0f32; b * n];
                let mut got = vec![0.0; b];
                quad_form_multi_f32(&ap32, n, &es32, b, &mut ws32, &mut got);
                let mut scalar = vec![0.0; b];
                quad_form_multi_f32_tier(
                    &ap32,
                    n,
                    &es32,
                    b,
                    &mut ws32,
                    &mut scalar,
                    SimdTier::Scalar,
                );
                for bi in 0..b {
                    let tol = 5e-4 * (1.0 + expect[bi].abs());
                    assert!(
                        (got[bi] - expect[bi]).abs() <= tol,
                        "b={b} n={n} q={bi}: f32 {} vs f64 {}",
                        got[bi],
                        expect[bi]
                    );
                    assert!(
                        (scalar[bi] - expect[bi]).abs() <= tol,
                        "b={b} n={n} q={bi}: forced-scalar f32 {} vs f64 {}",
                        scalar[bi],
                        expect[bi]
                    );
                }

                // Clamping and determinism, as for the f64 tier.
                let mut clamped = vec![0.0; b];
                quad_form_multi_f32_tier(
                    &ap32,
                    n,
                    &es32,
                    b,
                    &mut ws32,
                    &mut clamped,
                    SimdTier::Avx512,
                );
                let mut again = vec![0.0; b];
                quad_form_multi_f32(&ap32, n, &es32, b, &mut ws32, &mut again);
                for bi in 0..b {
                    assert!(
                        clamped[bi].to_bits() == got[bi].to_bits(),
                        "b={b} n={n} q={bi}: clamped f32 tier diverges from dispatch"
                    );
                    assert!(
                        again[bi].to_bits() == got[bi].to_bits(),
                        "b={b} n={n} q={bi}: f32 tier not deterministic"
                    );
                }
            }
        }
    }

    /// The learn-side multi-query dispatcher is bit-identical per query
    /// to the per-point learn kernel of its mode — values *and* the
    /// assembled w-block, across block sizes exercising the tiles and
    /// their ragged tails.
    #[test]
    fn with_multi_mode_bit_identical_to_per_point_learn_kernels() {
        let mut rng = Pcg64::seed(65);
        for &b in &[1usize, 3, 4, 7, 8, 33] {
            for n in [1usize, 2, 5, 16, 24] {
                let m = random_sym(n, &mut rng);
                let ap = pack_symmetric(&m);
                let es: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();

                for mode in [KernelMode::Strict, KernelMode::Fast] {
                    let mut ws = vec![0.0; b * n];
                    let mut out = vec![0.0; b];
                    quad_form_with_multi_mode(&ap, n, &es, b, &mut ws, &mut out, mode);
                    for bi in 0..b {
                        let x = &es[bi * n..(bi + 1) * n];
                        let mut w = vec![0.0; n];
                        let expect = quad_form_with_mode(&ap, n, x, &mut w, mode);
                        assert!(
                            out[bi].to_bits() == expect.to_bits(),
                            "b={b} n={n} q={bi} {mode:?}: quad form bits differ"
                        );
                        assert_eq!(
                            &ws[bi * n..(bi + 1) * n],
                            &w[..],
                            "b={b} n={n} q={bi} {mode:?}: w block bits differ"
                        );
                    }
                }
            }
        }
    }

    /// The write-path mat-vec tier keeps the ladder's contract: forced
    /// `Scalar` IS [`spmv_fast`] bit for bit, the dispatched tier is
    /// within 1e-12 relative of it, forcing above the detected tier
    /// clamps to the dispatched result, and a fixed tier is
    /// deterministic.
    #[test]
    fn spmv_simd_tier_matches_fast_within_tolerance() {
        let mut rng = Pcg64::seed(93);
        for n in [1usize, 2, 5, 16, 64, 129] {
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut fast = vec![0.0; n];
            spmv_fast(&ap, n, &x, &mut fast);

            let mut simd = vec![0.0; n];
            spmv_simd(&ap, n, &x, &mut simd);
            for (i, (f, s)) in fast.iter().zip(simd.iter()).enumerate() {
                let tol = 1e-12 * (1.0 + f.abs());
                assert!((f - s).abs() <= tol, "n={n} i={i}: {f} vs {s}");
            }

            let mut scalar = vec![0.0; n];
            spmv_simd_tier(&ap, n, &x, &mut scalar, SimdTier::Scalar);
            for i in 0..n {
                assert!(
                    scalar[i].to_bits() == fast[i].to_bits(),
                    "n={n} i={i}: forced-scalar bits differ from fast"
                );
            }

            let mut clamped = vec![0.0; n];
            spmv_simd_tier(&ap, n, &x, &mut clamped, SimdTier::Avx512);
            let mut again = vec![0.0; n];
            spmv_simd(&ap, n, &x, &mut again);
            for i in 0..n {
                assert!(
                    clamped[i].to_bits() == simd[i].to_bits(),
                    "n={n} i={i}: clamped tier diverges from dispatch"
                );
                assert!(
                    again[i].to_bits() == simd[i].to_bits(),
                    "n={n} i={i}: spmv tier not deterministic"
                );
            }
        }
    }

    /// Tier detection is consistent: cached, ordered, and `Scalar` at
    /// worst.
    #[test]
    fn simd_tier_detection_is_stable() {
        let t = simd_tier();
        assert_eq!(t, simd_tier(), "tier must be cached/stable");
        assert!(SimdTier::Scalar <= t);
        assert!(SimdTier::Scalar < SimdTier::Fma && SimdTier::Fma < SimdTier::Avx512);
        assert_eq!(SimdTier::Scalar.as_str(), "scalar");
        assert_eq!(format!("{}", SimdTier::Fma), "fma");
        assert_eq!(SimdTier::Avx512.to_string(), "avx512");
    }
}
