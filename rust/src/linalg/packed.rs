//! Packed upper-triangular storage for symmetric matrices.
//!
//! A symmetric `D×D` matrix is fully determined by its upper triangle —
//! `D·(D+1)/2` values instead of `D²`. The mixture's per-component
//! matrices (the precision `Λ` of the fast path, the covariance `C` of
//! the baseline) are kept *exactly* symmetric by the update rules (the
//! `α·(uᵢ·uⱼ)` trick in [`super::rank_one`]), so packing loses nothing —
//! and the component arenas of `gmm::ComponentStore` move roughly half
//! the bytes per kernel sweep.
//!
//! ## Layout
//!
//! Row-major upper triangle: row `i` stores entries `(i, i..D)`
//! contiguously, so element `(i, j)` with `i ≤ j` lives at
//! `row_start(i, d) + (j − i)`.
//!
//! ## Bit-identity contract
//!
//! Every kernel here performs the **same floating-point operations in
//! the same order** as its dense counterpart in [`super::Matrix`] /
//! [`super::rank_one`]: a mat-vec still accumulates `Σⱼ A(i,j)·xⱼ` in
//! ascending `j` (reading `(j, i)` from earlier packed rows when
//! `j < i` — the same value, since the dense matrices are exactly
//! symmetric), and per-entry updates use identical expressions. Packing
//! therefore changes *where a value is stored*, never the value — the
//! crate's determinism guarantee extends across layouts, enforced by
//! this module's side-by-side tests and `tests/layout_equivalence.rs`.

use super::Matrix;

/// Packed length of a symmetric `d×d` matrix: `d·(d+1)/2`.
#[inline]
pub fn packed_len(d: usize) -> usize {
    d * (d + 1) / 2
}

/// Offset of packed row `i` (the diagonal element `(i, i)`):
/// `Σ_{r<i} (d − r) = i·d − i·(i−1)/2`, written underflow-free.
#[inline]
pub fn row_start(i: usize, d: usize) -> usize {
    i * (2 * d + 1 - i) / 2
}

/// Symmetric element access for arbitrary `(i, j)`.
#[inline]
pub fn sym_at(ap: &[f64], d: usize, i: usize, j: usize) -> f64 {
    if i <= j {
        ap[row_start(i, d) + (j - i)]
    } else {
        ap[row_start(j, d) + (i - j)]
    }
}

/// Pack the upper triangle of a (symmetric) dense matrix.
pub fn pack_symmetric(m: &Matrix) -> Vec<f64> {
    assert_eq!(m.rows(), m.cols(), "pack_symmetric: square only");
    pack_symmetric_slice(m.as_slice(), m.rows())
}

/// Pack the upper triangle of a row-major `d×d` slice.
pub fn pack_symmetric_slice(flat: &[f64], d: usize) -> Vec<f64> {
    assert_eq!(flat.len(), d * d, "pack_symmetric_slice: shape mismatch");
    let mut out = Vec::with_capacity(packed_len(d));
    for i in 0..d {
        out.extend_from_slice(&flat[i * d + i..(i + 1) * d]);
    }
    out
}

/// Expand a packed symmetric matrix back to dense (both triangles).
pub fn unpack_symmetric(ap: &[f64], d: usize) -> Matrix {
    assert_eq!(ap.len(), packed_len(d), "unpack_symmetric: length mismatch");
    let mut m = Matrix::zeros(d, d);
    for i in 0..d {
        let rs = row_start(i, d);
        for j in i..d {
            let v = ap[rs + (j - i)];
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
    }
    m
}

/// Packed diagonal matrix from the given entries.
pub fn from_diag(entries: &[f64]) -> Vec<f64> {
    let d = entries.len();
    let mut out = vec![0.0; packed_len(d)];
    for (i, &v) in entries.iter().enumerate() {
        out[row_start(i, d)] = v;
    }
    out
}

/// Symmetric mat-vec `y = A·x` — bit-identical to
/// [`Matrix::matvec_into`] on the dense expansion (same accumulation
/// order, same values).
pub fn spmv(ap: &[f64], d: usize, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "spmv: x length");
    assert_eq!(y.len(), d, "spmv: y length");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = row_dot(ap, d, i, x);
    }
}

/// Quadratic form `xᵀ·A·x` — bit-identical to [`Matrix::quad_form`].
pub fn quad_form(ap: &[f64], d: usize, x: &[f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form: x length");
    let mut total = 0.0;
    for i in 0..d {
        total += x[i] * row_dot(ap, d, i, x);
    }
    total
}

/// Quadratic form that also writes `w = A·x` — bit-identical to
/// [`Matrix::quad_form_with`]. The learn hot path reuses `w` for the
/// fused rank-one update (see `rank_one::figmn_fused_update_packed`).
pub fn quad_form_with(ap: &[f64], d: usize, x: &[f64], w: &mut [f64]) -> f64 {
    debug_assert_eq!(ap.len(), packed_len(d));
    assert_eq!(x.len(), d, "quad_form_with: x length");
    assert_eq!(w.len(), d, "quad_form_with: w length");
    let mut total = 0.0;
    for i in 0..d {
        let acc = row_dot(ap, d, i, x);
        w[i] = acc;
        total += x[i] * acc;
    }
    total
}

/// `Σⱼ A(i,j)·xⱼ` in ascending `j` — the dense row dot product, reading
/// the `j < i` entries from earlier packed rows (their `(j, i)` slot).
#[inline]
fn row_dot(ap: &[f64], d: usize, i: usize, x: &[f64]) -> f64 {
    let mut acc = 0.0;
    // Entries (i, j) with j < i: element (j, i) at pk(j, i); successive
    // j differ by d − j − 1 (one shorter packed row each step).
    let mut idx = i; // pk(0, i) = i
    for (j, &xj) in x[..i].iter().enumerate() {
        acc += ap[idx] * xj;
        idx += d - j - 1;
    }
    // Entries (i, j) with j ≥ i: the contiguous packed row i.
    let rs = row_start(i, d);
    for (a, &xj) in ap[rs..rs + d - i].iter().zip(x[i..].iter()) {
        acc += a * xj;
    }
    acc
}

/// Symmetric rank-one accumulate `A += α·u·uᵀ` on packed storage —
/// per-entry expressions identical to [`super::rank_one::syr`].
pub fn syr_packed(ap: &mut [f64], d: usize, alpha: f64, u: &[f64]) {
    debug_assert_eq!(ap.len(), packed_len(d));
    debug_assert_eq!(u.len(), d);
    for i in 0..d {
        let ui = u[i];
        if ui == 0.0 {
            continue;
        }
        let rs = row_start(i, d);
        for (r, &uj) in ap[rs..rs + d - i].iter_mut().zip(u[i..].iter()) {
            *r += alpha * (ui * uj);
        }
    }
}

/// Scale every entry in place — the packed analog of
/// [`Matrix::scale_in_place`].
pub fn scale(ap: &mut [f64], s: f64) {
    for v in ap {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rank_one::syr;
    use crate::rng::Pcg64;
    use crate::testutil::random_spd;

    fn random_sym(n: usize, rng: &mut Pcg64) -> Matrix {
        let mut m = random_spd(n, rng);
        m.symmetrize();
        m
    }

    #[test]
    fn indexing_round_trips() {
        for d in [1usize, 2, 3, 5, 8] {
            assert_eq!(packed_len(d), (0..d).map(|i| d - i).sum::<usize>());
            let mut seen = vec![false; packed_len(d)];
            for i in 0..d {
                for j in i..d {
                    let idx = row_start(i, d) + (j - i);
                    assert!(!seen[idx], "slot ({i},{j}) collides at {idx}");
                    seen[idx] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "packed slots not covered for d={d}");
        }
    }

    #[test]
    fn pack_unpack_round_trip() {
        let mut rng = Pcg64::seed(5);
        for n in 1..8 {
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            assert_eq!(ap.len(), packed_len(n));
            let back = unpack_symmetric(&ap, n);
            assert_eq!(back.as_slice(), m.as_slice(), "n={n}");
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(sym_at(&ap, n, i, j), m[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn from_diag_places_diagonal() {
        let ap = from_diag(&[2.0, 3.0, 4.0]);
        let m = unpack_symmetric(&ap, 3);
        assert_eq!(m.as_slice(), Matrix::diag(&[2.0, 3.0, 4.0]).as_slice());
    }

    /// The bit-identity contract: packed kernels equal their dense
    /// counterparts *exactly*, not just to tolerance.
    #[test]
    fn kernels_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(42);
        for trial in 0..60 {
            let n = 1 + (trial % 9);
            let m = random_sym(n, &mut rng);
            let ap = pack_symmetric(&m);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut y_dense = vec![0.0; n];
            m.matvec_into(&x, &mut y_dense);
            let mut y_packed = vec![0.0; n];
            spmv(&ap, n, &x, &mut y_packed);
            assert_eq!(y_dense, y_packed, "trial {trial}: spmv bits differ");

            assert!(
                m.quad_form(&x).to_bits() == quad_form(&ap, n, &x).to_bits(),
                "trial {trial}: quad_form bits differ"
            );

            let mut w_dense = vec![0.0; n];
            let q_dense = m.quad_form_with(&x, &mut w_dense);
            let mut w_packed = vec![0.0; n];
            let q_packed = quad_form_with(&ap, n, &x, &mut w_packed);
            assert_eq!(w_dense, w_packed, "trial {trial}: w bits differ");
            assert!(q_dense.to_bits() == q_packed.to_bits(), "trial {trial}: q bits differ");
        }
    }

    #[test]
    fn syr_and_scale_bit_identical_to_dense() {
        let mut rng = Pcg64::seed(9);
        for trial in 0..40 {
            let n = 1 + (trial % 7);
            let mut dense = random_sym(n, &mut rng);
            let mut ap = pack_symmetric(&dense);
            let u: Vec<f64> = (0..n)
                .map(|_| if rng.uniform() < 0.2 { 0.0 } else { rng.normal() })
                .collect();
            let alpha = rng.normal();

            syr(&mut dense, alpha, &u);
            syr_packed(&mut ap, n, alpha, &u);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: syr bits differ");

            let s = rng.normal();
            dense.scale_in_place(s);
            scale(&mut ap, s);
            assert_eq!(pack_symmetric(&dense), ap, "trial {trial}: scale bits differ");
        }
    }
}
