//! Row-major dense `f64` matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense, row-major, heap-allocated `f64` matrix.
///
/// This is the workhorse type for the GMM substrate. It intentionally keeps
/// the API small and explicit; the hot-path routines live in
/// [`crate::linalg::rank_one`] and operate on `&mut Matrix` in place.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity of size `n × n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from the given entries.
    pub fn diag(entries: &[f64]) -> Self {
        let n = entries.len();
        let mut m = Matrix::zeros(n, n);
        for (i, &v) in entries.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Scaled identity `s·I`.
    pub fn scaled_identity(n: usize, s: f64) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = s;
        }
        m
    }

    /// Build from a row-major slice. Panics if `data.len() != rows*cols`.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_rows: shape mismatch");
        Matrix { rows, cols, data: data.to_vec() }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major backing slice.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix–vector product `y = A·x` (allocates `y`).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Matrix–vector product into a caller-provided buffer (no allocation).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec: x length");
        assert_eq!(y.len(), self.rows, "matvec: y length");
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
    }

    /// Quadratic form `xᵀ·A·x` that also writes `w = A·x` into a caller
    /// buffer — the learn hot path reuses `w` for the fused rank-one
    /// update (see `rank_one::figmn_fused_update`), saving a second
    /// O(D²) mat-vec.
    pub fn quad_form_with(&self, x: &[f64], w: &mut [f64]) -> f64 {
        assert_eq!(self.rows, self.cols, "quad_form_with: square only");
        assert_eq!(x.len(), self.cols, "quad_form_with: x length");
        assert_eq!(w.len(), self.rows, "quad_form_with: w length");
        let mut total = 0.0;
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            w[i] = acc;
            total += x[i] * acc;
        }
        total
    }

    /// Quadratic form `xᵀ·A·x` without allocating.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        assert_eq!(self.rows, self.cols, "quad_form: square only");
        assert_eq!(x.len(), self.cols, "quad_form: x length");
        let mut total = 0.0;
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            total += x[i] * acc;
        }
        total
    }

    /// Dense matrix product `A·B` (allocates).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: inner dims");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transpose (allocates).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// `self += s·B` elementwise.
    pub fn add_scaled(&mut self, other: &Matrix, s: f64) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Scale every entry in place.
    pub fn scale_in_place(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Extract the sub-matrix with the given row and column index sets.
    pub fn submatrix(&self, row_idx: &[usize], col_idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(row_idx.len(), col_idx.len());
        for (oi, &i) in row_idx.iter().enumerate() {
            for (oj, &j) in col_idx.iter().enumerate() {
                out[(oi, oj)] = self[(i, j)];
            }
        }
        out
    }

    /// Gauss–Jordan inverse with partial pivoting. `O(n³)` — this is the
    /// operation the paper eliminates from the hot path; it remains here
    /// for the covariance-baseline IGMN and for test oracles.
    ///
    /// Returns `None` if the matrix is numerically singular.
    pub fn inverse(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "inverse: square only");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Partial pivot.
            let mut piv = col;
            let mut best = a[(col, col)].abs();
            for r in col + 1..n {
                let v = a[(r, col)].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            if best < 1e-300 {
                return None;
            }
            if piv != col {
                a.swap_rows(piv, col);
                inv.swap_rows(piv, col);
            }
            let d = a[(col, col)];
            let dinv = 1.0 / d;
            for v in a.row_mut(col) {
                *v *= dinv;
            }
            for v in inv.row_mut(col) {
                *v *= dinv;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let v = a[(col, j)];
                    a[(r, j)] -= f * v;
                    let w = inv[(col, j)];
                    inv[(r, j)] -= f * w;
                }
            }
        }
        Some(inv)
    }

    /// Determinant via LU with partial pivoting. `O(n³)`; baseline/oracle
    /// use only (the fast path tracks determinants incrementally via the
    /// Matrix Determinant Lemma).
    pub fn determinant(&self) -> f64 {
        assert_eq!(self.rows, self.cols, "determinant: square only");
        let n = self.rows;
        let mut a = self.clone();
        let mut det = 1.0;
        for col in 0..n {
            let mut piv = col;
            let mut best = a[(col, col)].abs();
            for r in col + 1..n {
                let v = a[(r, col)].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            if best < 1e-300 {
                return 0.0;
            }
            if piv != col {
                a.swap_rows(piv, col);
                det = -det;
            }
            let d = a[(col, col)];
            det *= d;
            for r in col + 1..n {
                let f = a[(r, col)] / d;
                if f == 0.0 {
                    continue;
                }
                for j in col..n {
                    let v = a[(col, j)];
                    a[(r, j)] -= f * v;
                }
            }
        }
        det
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        let cols = self.cols;
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (a, b) = self.data.split_at_mut(hi * cols);
        a[lo * cols..(lo + 1) * cols].swap_with_slice(&mut b[..cols]);
    }

    /// Max absolute elementwise difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Force exact symmetry: `A ← (A + Aᵀ)/2`. The rank-one update
    /// recurrences are symmetric in exact arithmetic; this keeps float
    /// drift from accumulating over millions of updates.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in i + 1..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matvec_is_identity() {
        let m = Matrix::identity(4);
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x.to_vec());
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn inverse_round_trip() {
        let a = Matrix::from_rows(3, 3, &[4.0, 1.0, 0.5, 1.0, 3.0, 0.2, 0.5, 0.2, 2.0]);
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::identity(3)) < 1e-12);
    }

    #[test]
    fn inverse_singular_is_none() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(a.inverse().is_none());
    }

    #[test]
    fn determinant_known() {
        let a = Matrix::from_rows(2, 2, &[3.0, 1.0, 1.0, 2.0]);
        assert!((a.determinant() - 5.0).abs() < 1e-12);
        let b = Matrix::from_rows(3, 3, &[2.0, 0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 4.0]);
        assert!((b.determinant() - 24.0).abs() < 1e-12);
    }

    #[test]
    fn determinant_matches_inverse_det() {
        let a = Matrix::from_rows(3, 3, &[4.0, 1.0, 0.5, 1.0, 3.0, 0.2, 0.5, 0.2, 2.0]);
        let d = a.determinant();
        let dinv = a.inverse().unwrap().determinant();
        assert!((d * dinv - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quad_form_matches_matvec() {
        let a = Matrix::from_rows(2, 2, &[2.0, 0.5, 0.5, 1.0]);
        let x = [1.0, 3.0];
        let y = a.matvec(&x);
        let direct: f64 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
        assert!((a.quad_form(&x) - direct).abs() < 1e-14);
    }

    #[test]
    fn submatrix_extracts() {
        let a = Matrix::from_rows(3, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        let s = a.submatrix(&[0, 2], &[1]);
        assert_eq!(s.as_slice(), &[2.0, 8.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetrize_fixes_drift() {
        let mut a = Matrix::from_rows(2, 2, &[1.0, 2.0 + 1e-13, 2.0, 1.0]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], a[(1, 0)]);
    }

    #[test]
    fn swap_rows_via_determinant_sign() {
        // det of permutation of identity is -1
        let mut a = Matrix::identity(3);
        a.swap_rows(0, 2);
        assert!((a.determinant() + 1.0).abs() < 1e-12);
    }
}
