//! Streaming views over datasets — the coordinator consumes these.
//!
//! The paper's motivation is single-pass learning on data streams; these
//! adapters turn in-memory datasets into replayable record streams and
//! compose them into non-stationary (concept-drift) scenarios.

use super::Dataset;
use crate::rng::Pcg64;

/// One stream element: features plus an optional label (unlabeled records
/// are inference-only traffic).
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub features: Vec<f64>,
    pub label: Option<usize>,
    /// Monotone sequence number assigned by the stream.
    pub seq: u64,
}

/// A pull-based record stream.
pub trait RecordStream {
    /// Next record, or `None` when the stream is exhausted.
    fn next_record(&mut self) -> Option<Record>;

    /// Total records if known ahead of time.
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Replays a dataset in a seeded random order.
pub struct ShuffledStream {
    data: Dataset,
    order: Vec<usize>,
    pos: usize,
    seq: u64,
}

impl ShuffledStream {
    pub fn new(data: Dataset, seed: u64) -> Self {
        let mut rng = Pcg64::seed(seed);
        let order = rng.permutation(data.len());
        ShuffledStream { data, order, pos: 0, seq: 0 }
    }
}

impl RecordStream for ShuffledStream {
    fn next_record(&mut self) -> Option<Record> {
        if self.pos >= self.order.len() {
            return None;
        }
        let i = self.order[self.pos];
        self.pos += 1;
        let seq = self.seq;
        self.seq += 1;
        Some(Record {
            features: self.data.features[i].clone(),
            label: Some(self.data.labels[i]),
            seq,
        })
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.order.len() - self.pos)
    }
}

/// Concatenates phases of different distributions — abrupt concept drift.
pub struct DriftStream {
    phases: Vec<Box<dyn RecordStream + Send>>,
    current: usize,
    seq: u64,
}

impl DriftStream {
    pub fn new(phases: Vec<Box<dyn RecordStream + Send>>) -> Self {
        DriftStream { phases, current: 0, seq: 0 }
    }
}

impl RecordStream for DriftStream {
    fn next_record(&mut self) -> Option<Record> {
        while self.current < self.phases.len() {
            if let Some(mut r) = self.phases[self.current].next_record() {
                r.seq = self.seq;
                self.seq += 1;
                return Some(r);
            }
            self.current += 1;
        }
        None
    }

    fn len_hint(&self) -> Option<usize> {
        self.phases[self.current..]
            .iter()
            .map(|p| p.len_hint())
            .try_fold(0usize, |acc, h| h.map(|v| acc + v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new("t", vec![vec![0.0], vec![1.0], vec![2.0]], vec![0, 1, 0], 2)
    }

    #[test]
    fn shuffled_stream_visits_all_once() {
        let mut s = ShuffledStream::new(tiny(), 3);
        assert_eq!(s.len_hint(), Some(3));
        let mut seen: Vec<f64> = Vec::new();
        while let Some(r) = s.next_record() {
            seen.push(r.features[0]);
        }
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen, vec![0.0, 1.0, 2.0]);
        assert!(s.next_record().is_none());
    }

    #[test]
    fn seq_is_monotone() {
        let mut s = ShuffledStream::new(tiny(), 3);
        let mut prev = None;
        while let Some(r) = s.next_record() {
            if let Some(p) = prev {
                assert!(r.seq > p);
            }
            prev = Some(r.seq);
        }
    }

    #[test]
    fn drift_stream_concatenates() {
        let a = ShuffledStream::new(tiny(), 1);
        let b = ShuffledStream::new(tiny(), 2);
        let mut d = DriftStream::new(vec![Box::new(a), Box::new(b)]);
        assert_eq!(d.len_hint(), Some(6));
        let mut n = 0;
        while let Some(r) = d.next_record() {
            assert_eq!(r.seq, n);
            n += 1;
        }
        assert_eq!(n, 6);
    }
}
