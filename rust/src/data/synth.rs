//! Synthetic dataset generators matching the paper's Table 1.
//!
//! Two generator families:
//!
//! - **low-D** (the UCI rows): per class, 1–2 full-covariance Gaussian
//!   prototypes with a random SPD covariance, sampled via Cholesky.
//! - **image-like** (MNIST 784-D, CIFAR-10 3072-D rows): per class, a
//!   smooth random "prototype image" plus a rank-R smooth perturbation
//!   basis and pixel noise. Full-covariance sampling at D = 3072 would be
//!   `O(D³)` just to factor; the low-rank model produces correlated,
//!   class-structured pixels at `O(D·R)` per sample while exercising the
//!   exact same consumer code paths (the learner still fits *full* D×D
//!   covariances — its cost is unchanged).

use super::Dataset;
use crate::rng::Pcg64;
use crate::testutil; // random_spd lives next to the test helpers
use crate::linalg::Cholesky;

/// A row of the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub instances: usize,
    pub attributes: usize,
    pub classes: usize,
    pub kind: SynthKind,
}

/// Which generator family reproduces this dataset's structure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// Class-conditional full-covariance Gaussians.
    Gaussian,
    /// Smooth-prototype image-like data (MNIST/CIFAR rows).
    ImageLike,
    /// The exact two-spirals construction.
    TwoSpirals,
}

/// The paper's Table 1, verbatim (N, D, classes).
pub const TABLE1: [DatasetSpec; 12] = [
    DatasetSpec { name: "breast-cancer", instances: 286, attributes: 9, classes: 2, kind: SynthKind::Gaussian },
    DatasetSpec { name: "german-credit", instances: 1000, attributes: 20, classes: 2, kind: SynthKind::Gaussian },
    DatasetSpec { name: "pima-diabetes", instances: 768, attributes: 8, classes: 2, kind: SynthKind::Gaussian },
    DatasetSpec { name: "Glass", instances: 214, attributes: 9, classes: 7, kind: SynthKind::Gaussian },
    DatasetSpec { name: "ionosphere", instances: 351, attributes: 34, classes: 2, kind: SynthKind::Gaussian },
    DatasetSpec { name: "iris", instances: 150, attributes: 4, classes: 3, kind: SynthKind::Gaussian },
    DatasetSpec { name: "labor-neg-data", instances: 57, attributes: 16, classes: 2, kind: SynthKind::Gaussian },
    DatasetSpec { name: "soybean", instances: 683, attributes: 35, classes: 19, kind: SynthKind::Gaussian },
    DatasetSpec { name: "twospirals", instances: 193, attributes: 2, classes: 2, kind: SynthKind::TwoSpirals },
    DatasetSpec { name: "MNIST", instances: 1000, attributes: 784, classes: 10, kind: SynthKind::ImageLike },
    DatasetSpec { name: "CIFAR-10", instances: 1000, attributes: 3072, classes: 10, kind: SynthKind::ImageLike },
    DatasetSpec { name: "CIFAR-10b", instances: 100, attributes: 3072, classes: 10, kind: SynthKind::ImageLike },
];

/// Look up a Table 1 spec by name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    TABLE1.iter().find(|s| s.name == name)
}

/// Generate the synthetic stand-in for a Table 1 dataset.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Dataset {
    match spec.kind {
        SynthKind::TwoSpirals => super::twospirals(spec.instances, 0.05, seed),
        SynthKind::Gaussian => gaussian_classes(spec, seed),
        SynthKind::ImageLike => image_like(spec, seed),
    }
}

/// Generate every Table 1 dataset (used by the bench harness).
pub fn generate_all(seed: u64) -> Vec<Dataset> {
    TABLE1.iter().map(|s| generate(s, seed)).collect()
}

/// Class-conditional Gaussian data for the low-D UCI stand-ins.
fn gaussian_classes(spec: &DatasetSpec, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed(seed ^ hash_name(spec.name));
    let d = spec.attributes;
    let k = spec.classes;

    // Per class: center spread so classes overlap moderately (learnable
    // but not trivial), covariance random SPD scaled to unit-ish variance.
    let mut centers = Vec::with_capacity(k);
    let mut chols = Vec::with_capacity(k);
    for _ in 0..k {
        let c: Vec<f64> = (0..d).map(|_| rng.normal() * 2.0).collect();
        let mut cov = testutil::random_spd(d, &mut rng);
        // Normalize trace to d (average variance 1).
        let tr: f64 = (0..d).map(|i| cov[(i, i)]).sum();
        cov.scale_in_place(d as f64 / tr);
        centers.push(c);
        chols.push(Cholesky::new(&cov).expect("spd"));
    }

    let mut features = Vec::with_capacity(spec.instances);
    let mut labels = Vec::with_capacity(spec.instances);
    let mut z = vec![0.0; d];
    for i in 0..spec.instances {
        let class = i % k; // balanced, deterministic
        rng.fill_normal(&mut z);
        let noise = chols[class].sample_transform(&z);
        let row: Vec<f64> =
            centers[class].iter().zip(noise.iter()).map(|(c, n)| c + n).collect();
        features.push(row);
        labels.push(class);
    }
    Dataset::new(spec.name, features, labels, k)
}

/// Image-like generator: smooth per-class prototype + rank-R smooth
/// variation + pixel noise. `O(D·R)` per sample.
fn image_like(spec: &DatasetSpec, seed: u64) -> Dataset {
    const RANK: usize = 12;
    let mut rng = Pcg64::seed(seed ^ hash_name(spec.name));
    let d = spec.attributes;
    let k = spec.classes;

    // Smooth 1-D profiles: random sinusoid mixtures over pixel index —
    // cheap stand-ins for spatial correlation.
    let mut smooth = |amp: f64| -> Vec<f64> {
        let f1 = rng.uniform_in(1.0, 8.0);
        let f2 = rng.uniform_in(8.0, 40.0);
        let p1 = rng.uniform_in(0.0, std::f64::consts::TAU);
        let p2 = rng.uniform_in(0.0, std::f64::consts::TAU);
        let a2 = rng.uniform();
        (0..d)
            .map(|i| {
                let t = i as f64 / d as f64 * std::f64::consts::TAU;
                amp * ((f1 * t + p1).sin() + a2 * (f2 * t + p2).sin())
            })
            .collect()
    };

    let prototypes: Vec<Vec<f64>> = (0..k).map(|_| smooth(2.0)).collect();
    let bases: Vec<Vec<Vec<f64>>> =
        (0..k).map(|_| (0..RANK).map(|_| smooth(0.8)).collect()).collect();

    let mut features = Vec::with_capacity(spec.instances);
    let mut labels = Vec::with_capacity(spec.instances);
    for i in 0..spec.instances {
        let class = i % k;
        let mut row = prototypes[class].clone();
        for basis in &bases[class] {
            let w = rng.normal();
            for (r, b) in row.iter_mut().zip(basis.iter()) {
                *r += w * b;
            }
        }
        for r in row.iter_mut() {
            *r += rng.normal() * 0.3; // pixel noise
        }
        features.push(row);
        labels.push(class);
    }
    Dataset::new(spec.name, features, labels, k)
}

/// Shape of a [`drift_stream`]: class-conditional Gaussians whose means
/// jump once (piecewise mean shift) and whose covariance scale ramps up
/// after the shift — the concept-drift scenario the decay/max-age knobs
/// (`GmmConfig::with_decay` / `with_max_age`) are built for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftSpec {
    pub dim: usize,
    pub classes: usize,
    pub instances: usize,
    /// Stream index where every class mean jumps. Points before it come
    /// from the original mixture, points at or after it from the
    /// shifted one.
    pub shift_at: usize,
    /// Euclidean distance of each class-mean jump (each class moves in
    /// its own random direction). Ignored when `swap_classes` is set.
    pub shift: f64,
    /// Adversarial drift: instead of a random jump, class `c` moves to
    /// class `(c + 1) % classes`' pre-shift mean. A model that keeps
    /// its pre-shift mass is then not merely stale but actively
    /// *wrong* — old components vote the old label at the new
    /// location — which is what the decay/max-age recovery tests need.
    pub swap_classes: bool,
    /// Covariance scale multiplier reached at the end of the stream:
    /// post-shift noise ramps linearly from 1× to `cov_ramp`× standard
    /// deviation (1.0 = mean shift only).
    pub cov_ramp: f64,
}

/// Drift-injection stream: piecewise mean shift plus covariance ramp.
///
/// Same generator family as the Table 1 Gaussian stand-ins (random SPD
/// covariance per class, Cholesky sampling, balanced `i % k` labels),
/// but the class means jump by `spec.shift` at `spec.shift_at` and the
/// noise scale then ramps toward `spec.cov_ramp`. Order matters: rows
/// are a *stream*, not an i.i.d. set — feed them to `learn` in index
/// order.
pub fn drift_stream(spec: &DriftSpec, seed: u64) -> Dataset {
    assert!(spec.classes > 0 && spec.dim > 0);
    assert!(spec.shift_at <= spec.instances);
    assert!(spec.cov_ramp >= 1.0, "cov_ramp is a scale-up factor");
    let mut rng = Pcg64::seed(seed ^ hash_name("drift-stream"));
    let d = spec.dim;
    let k = spec.classes;

    let mut centers = Vec::with_capacity(k);
    let mut shifted = Vec::with_capacity(k);
    let mut chols = Vec::with_capacity(k);
    for _ in 0..k {
        let c: Vec<f64> = (0..d).map(|_| rng.normal() * 2.0).collect();
        // Random unit direction scaled to the requested jump distance.
        let mut dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
        for v in dir.iter_mut() {
            *v *= spec.shift / norm;
        }
        shifted.push(c.iter().zip(dir.iter()).map(|(a, b)| a + b).collect::<Vec<f64>>());
        let mut cov = testutil::random_spd(d, &mut rng);
        let tr: f64 = (0..d).map(|i| cov[(i, i)]).sum();
        cov.scale_in_place(d as f64 / tr);
        centers.push(c);
        chols.push(Cholesky::new(&cov).expect("spd"));
    }
    if spec.swap_classes {
        for c in 0..k {
            shifted[c] = centers[(c + 1) % k].clone();
        }
    }

    let post = (spec.instances - spec.shift_at).max(1) as f64;
    let mut features = Vec::with_capacity(spec.instances);
    let mut labels = Vec::with_capacity(spec.instances);
    let mut z = vec![0.0; d];
    for i in 0..spec.instances {
        let class = i % k;
        let (mean, scale) = if i < spec.shift_at {
            (&centers[class], 1.0)
        } else {
            let t = (i - spec.shift_at) as f64 / post;
            (&shifted[class], 1.0 + (spec.cov_ramp - 1.0) * t)
        };
        rng.fill_normal(&mut z);
        let noise = chols[class].sample_transform(&z);
        let row: Vec<f64> =
            mean.iter().zip(noise.iter()).map(|(c, n)| c + scale * n).collect();
        features.push(row);
        labels.push(class);
    }
    Dataset::new("drift-stream", features, labels, k)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, so each dataset gets an independent stream from one seed.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shapes_match_paper() {
        // Spot-check the exact numbers printed in the paper's Table 1.
        let m = spec("MNIST").unwrap();
        assert_eq!((m.instances, m.attributes, m.classes), (1000, 784, 10));
        let c = spec("CIFAR-10").unwrap();
        assert_eq!((c.instances, c.attributes, c.classes), (1000, 3072, 10));
        let i = spec("iris").unwrap();
        assert_eq!((i.instances, i.attributes, i.classes), (150, 4, 3));
        let s = spec("soybean").unwrap();
        assert_eq!((s.instances, s.attributes, s.classes), (683, 35, 19));
        assert_eq!(TABLE1.len(), 12);
    }

    #[test]
    fn generated_shapes_match_spec() {
        for s in TABLE1.iter().filter(|s| s.attributes <= 40) {
            let d = generate(s, 1);
            assert_eq!(d.len(), s.instances, "{}", s.name);
            assert_eq!(d.dim(), s.attributes, "{}", s.name);
            assert_eq!(d.n_classes, s.classes, "{}", s.name);
            // Every class appears.
            assert!(d.class_counts().iter().all(|&c| c > 0), "{}", s.name);
        }
    }

    #[test]
    fn image_like_shape_and_structure() {
        let s = spec("MNIST").unwrap();
        let d = generate(s, 1);
        assert_eq!(d.len(), 1000);
        assert_eq!(d.dim(), 784);
        // Same-class rows are closer than cross-class rows on average
        // (class structure exists).
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        // rows 0 and 10 are class 0; row 1 is class 1.
        let same = dist(&d.features[0], &d.features[10]);
        let cross = dist(&d.features[0], &d.features[1]);
        assert!(same < cross, "same {same} cross {cross}");
    }

    #[test]
    fn drift_stream_shifts_means_and_ramps_noise() {
        let spec = DriftSpec {
            dim: 4,
            classes: 2,
            instances: 2000,
            shift_at: 1000,
            shift: 8.0,
            swap_classes: false,
            cov_ramp: 3.0,
        };
        let d = drift_stream(&spec, 5);
        assert_eq!(d.len(), 2000);
        assert_eq!(d.dim(), 4);
        // Per-class mean jumps by about `shift` across the boundary.
        for class in 0..2 {
            let mean = |range: std::ops::Range<usize>| -> Vec<f64> {
                let rows: Vec<&Vec<f64>> = range
                    .filter(|&i| d.labels[i] == class)
                    .map(|i| &d.features[i])
                    .collect();
                (0..4)
                    .map(|j| rows.iter().map(|r| r[j]).sum::<f64>() / rows.len() as f64)
                    .collect()
            };
            let pre = mean(0..1000);
            let post = mean(1000..2000);
            let jump: f64 =
                pre.iter().zip(&post).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            assert!(
                (jump - 8.0).abs() < 2.5,
                "class {class} mean jumped {jump}, wanted ~8"
            );
        }
        // Noise widens along the post-shift ramp: late scatter beats
        // early post-shift scatter.
        let scatter = |range: std::ops::Range<usize>| -> f64 {
            let rows: Vec<&Vec<f64>> =
                range.filter(|&i| d.labels[i] == 0).map(|i| &d.features[i]).collect();
            let m: Vec<f64> = (0..4)
                .map(|j| rows.iter().map(|r| r[j]).sum::<f64>() / rows.len() as f64)
                .collect();
            rows.iter()
                .map(|r| r.iter().zip(&m).map(|(x, c)| (x - c) * (x - c)).sum::<f64>())
                .sum::<f64>()
                / rows.len() as f64
        };
        assert!(scatter(1800..2000) > scatter(1000..1200) * 1.5);
        // Deterministic given the seed.
        let e = drift_stream(&spec, 5);
        assert_eq!(d.features, e.features);
    }

    #[test]
    fn drift_stream_swap_moves_classes_onto_each_other() {
        let spec = DriftSpec {
            dim: 3,
            classes: 2,
            instances: 2000,
            shift_at: 1000,
            shift: 0.0,
            swap_classes: true,
            cov_ramp: 1.0,
        };
        let d = drift_stream(&spec, 11);
        let mean = |class: usize, range: std::ops::Range<usize>| -> Vec<f64> {
            let rows: Vec<&Vec<f64>> = range
                .filter(|&i| d.labels[i] == class)
                .map(|i| &d.features[i])
                .collect();
            (0..3)
                .map(|j| rows.iter().map(|r| r[j]).sum::<f64>() / rows.len() as f64)
                .collect()
        };
        // Post-shift class 0 sits where class 1 used to be (and vice
        // versa) — sample means agree to sampling noise.
        for c in 0..2 {
            let post = mean(c, 1000..2000);
            let other_pre = mean(1 - c, 0..1000);
            let gap: f64 = post
                .iter()
                .zip(&other_pre)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            assert!(gap < 0.5, "class {c} did not land on its partner (gap {gap})");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = spec("iris").unwrap();
        let a = generate(s, 7);
        let b = generate(s, 7);
        assert_eq!(a.features, b.features);
        let c = generate(s, 8);
        assert_ne!(a.features, c.features);
    }
}
