//! The classic two-spirals benchmark (Lang & Witbrock 1988 style),
//! generated exactly — in the paper it is a synthetic dataset too.

use super::Dataset;
use crate::rng::Pcg64;

/// `n` points on two interleaved spirals with additive Gaussian noise.
pub fn twospirals(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed(seed);
    let mut features = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 2;
        // Radius grows with angle; second spiral is rotated by π.
        let t = 0.5 + 3.0 * (i / 2) as f64 / (n as f64 / 2.0).max(1.0) * std::f64::consts::PI;
        let r = t / (3.0 * std::f64::consts::PI);
        let phase = if class == 0 { 0.0 } else { std::f64::consts::PI };
        let x = r * (t + phase).cos() + noise * rng.normal();
        let y = r * (t + phase).sin() + noise * rng.normal();
        features.push(vec![x, y]);
        labels.push(class);
    }
    Dataset::new("twospirals", features, labels, 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_balance() {
        let d = twospirals(193, 0.05, 1);
        assert_eq!(d.len(), 193);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.n_classes, 2);
        let counts = d.class_counts();
        assert!((counts[0] as i64 - counts[1] as i64).abs() <= 1);
    }

    #[test]
    fn spirals_interleave() {
        // Points stay within the unit-ish disc and both classes span it.
        let d = twospirals(200, 0.0, 2);
        for row in &d.features {
            let r = (row[0] * row[0] + row[1] * row[1]).sqrt();
            assert!(r <= 1.2, "radius {r}");
        }
        // Noise-free: same index offset on different spirals are rotated
        // by π — their midpoint is ~the origin.
        let a = &d.features[10];
        let b = &d.features[11];
        assert!((a[0] + b[0]).abs() < 0.05 && (a[1] + b[1]).abs() < 0.05);
    }
}
