//! CSV parsing/writing for labeled datasets.
//!
//! Format: numeric feature columns, label in the **last** column (either a
//! class name or an integer). An optional header row is auto-detected
//! (non-numeric first cell in a non-label column).

use super::Dataset;
use std::collections::BTreeMap;

/// Parse CSV text into a [`Dataset`].
pub fn parse_csv(name: &str, text: &str) -> Result<Dataset, String> {
    let mut rows: Vec<(Vec<f64>, String)> = Vec::new();
    let mut lines = text.lines().enumerate().peekable();

    // Header detection: first non-empty line whose first cell isn't a number.
    if let Some((_, first)) = lines.peek() {
        let first_cell = first.split(',').next().unwrap_or("").trim();
        if !first_cell.is_empty() && first_cell.parse::<f64>().is_err() {
            lines.next();
        }
    }

    for (lineno, line) in lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if cells.len() < 2 {
            return Err(format!("line {}: need ≥2 columns", lineno + 1));
        }
        let (feat_cells, label_cell) = cells.split_at(cells.len() - 1);
        let mut feats = Vec::with_capacity(feat_cells.len());
        for (col, c) in feat_cells.iter().enumerate() {
            feats.push(
                c.parse::<f64>()
                    .map_err(|_| format!("line {}: column {} not numeric: '{c}'", lineno + 1, col + 1))?,
            );
        }
        rows.push((feats, label_cell[0].to_string()));
    }
    if rows.is_empty() {
        return Err("no data rows".into());
    }
    let d = rows[0].0.len();
    if rows.iter().any(|(f, _)| f.len() != d) {
        return Err("inconsistent column counts".into());
    }

    // Map label strings to class indices in first-seen order… but keep it
    // deterministic across shuffles by sorting the distinct labels.
    let mut distinct: Vec<String> = rows.iter().map(|(_, l)| l.clone()).collect();
    distinct.sort();
    distinct.dedup();
    let index: BTreeMap<&str, usize> =
        distinct.iter().enumerate().map(|(i, s)| (s.as_str(), i)).collect();

    let features: Vec<Vec<f64>> = rows.iter().map(|(f, _)| f.clone()).collect();
    let labels: Vec<usize> = rows.iter().map(|(_, l)| index[l.as_str()]).collect();
    Ok(Dataset::new(name, features, labels, distinct.len()))
}

/// Serialize a dataset to CSV (labels as `c<index>`).
pub fn write_csv(ds: &Dataset) -> String {
    let mut out = String::new();
    for (row, &label) in ds.features.iter().zip(ds.labels.iter()) {
        for v in row {
            out.push_str(&format!("{v:?},"));
        }
        out.push_str(&format!("c{label}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header() {
        let text = "x,y,class\n1.0,2.0,a\n3.0,4.0,b\n5.0,6.0,a\n";
        let d = parse_csv("t", text).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.n_classes, 2);
        assert_eq!(d.labels, vec![0, 1, 0]);
    }

    #[test]
    fn parses_numeric_labels_without_header() {
        let text = "1.5,0\n2.5,1\n";
        let d = parse_csv("t", text).unwrap();
        assert_eq!(d.dim(), 1);
        assert_eq!(d.labels, vec![0, 1]);
    }

    #[test]
    fn round_trip() {
        let text = "1.25,2.5,a\n-3.0,4.0,b\n";
        let d = parse_csv("t", text).unwrap();
        let d2 = parse_csv("t", &write_csv(&d)).unwrap();
        assert_eq!(d.features, d2.features);
        assert_eq!(d.labels, d2.labels);
    }

    #[test]
    fn rejects_bad_rows() {
        assert!(parse_csv("t", "").is_err());
        assert!(parse_csv("t", "1.0,x,a\n").is_err());
        assert!(parse_csv("t", "1.0,a\n2.0,3.0,b\n").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# comment\n\n1.0,a\n\n2.0,b\n";
        let d = parse_csv("t", text).unwrap();
        assert_eq!(d.len(), 2);
    }
}
