//! Dataset substrate.
//!
//! The paper evaluates on 9 UCI datasets plus MNIST/CIFAR-10 subsets
//! (Table 1), none of which ship with this offline environment.
//! Per DESIGN.md §5 each is replaced by a seeded synthetic generator with
//! the **exact same (N, D, #classes)** — the quantities the timing
//! experiments (Tables 2–3) depend on — and class-conditional Gaussian
//! structure so the accuracy experiment (Table 4) ranks classifiers on a
//! learnable problem. `twospirals` is generated exactly (it is synthetic
//! in the paper as well).
//!
//! Also here: CSV and (Weka-style) ARFF parsers so the library can run on
//! real files a downstream user supplies, normalization, and streaming
//! views used by the coordinator.

mod arff;
mod csv;
mod normalize;
mod stream;
pub mod synth;
mod twospirals;

pub use arff::parse_arff;
pub use csv::{parse_csv, write_csv};
pub use normalize::{MinMaxScaler, StandardScaler};
pub use stream::{DriftStream, Record, RecordStream, ShuffledStream};
pub use twospirals::twospirals;

use crate::stats::column_stds;

/// An in-memory labeled dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// Row-major feature rows; all rows have equal length.
    pub features: Vec<Vec<f64>>,
    /// Class index per row, in `0..n_classes`.
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(name: &str, features: Vec<Vec<f64>>, labels: Vec<usize>, n_classes: usize) -> Self {
        assert_eq!(features.len(), labels.len(), "features/labels length mismatch");
        assert!(!features.is_empty(), "empty dataset");
        let d = features[0].len();
        assert!(features.iter().all(|r| r.len() == d), "ragged feature rows");
        assert!(labels.iter().all(|&l| l < n_classes), "label out of range");
        Dataset { name: name.to_string(), features, labels, n_classes }
    }

    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }

    /// Feature dimensionality `D`.
    pub fn dim(&self) -> usize {
        self.features[0].len()
    }

    /// Per-feature standard deviations (for `σ_ini = δ·std`, Eq. 13).
    pub fn feature_stds(&self) -> Vec<f64> {
        column_stds(&self.features)
    }

    /// Subset by row indices (copies).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            features: idx.iter().map(|&i| self.features[i].clone()).collect(),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            n_classes: self.n_classes,
        }
    }

    /// Count of rows per class.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0; self.n_classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_invariants() {
        let d = Dataset::new("t", vec![vec![1.0, 2.0], vec![3.0, 4.0]], vec![0, 1], 2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.class_counts(), vec![1, 1]);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged() {
        Dataset::new("t", vec![vec![1.0], vec![1.0, 2.0]], vec![0, 0], 1);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_label() {
        Dataset::new("t", vec![vec![1.0]], vec![5], 2);
    }

    #[test]
    fn subset_picks_rows() {
        let d = Dataset::new("t", vec![vec![0.0], vec![1.0], vec![2.0]], vec![0, 1, 0], 2);
        let s = d.subset(&[2, 0]);
        assert_eq!(s.features, vec![vec![2.0], vec![0.0]]);
        assert_eq!(s.labels, vec![0, 0]);
    }
}
