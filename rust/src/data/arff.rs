//! Minimal Weka ARFF parser — the format the paper's experiments consumed
//! (the authors published Weka packages). Supports `numeric` attributes
//! and one nominal `class` attribute (any position); `@relation`,
//! comments, and case-insensitive keywords.

use super::Dataset;

/// Parse ARFF text. The single nominal attribute is treated as the class;
/// if several nominals exist, the **last** one is the class and the rest
/// are rejected (encode them numerically upstream).
pub fn parse_arff(text: &str) -> Result<Dataset, String> {
    #[derive(PartialEq)]
    enum Kind {
        Numeric,
        Nominal(Vec<String>),
    }
    let mut relation = String::from("arff");
    let mut attrs: Vec<(String, Kind)> = Vec::new();
    let mut in_data = false;
    let mut rows: Vec<Vec<String>> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let lower = line.to_ascii_lowercase();
        if !in_data {
            if lower.starts_with("@relation") {
                relation = line[9..].trim().trim_matches(|c| c == '\'' || c == '"').to_string();
            } else if lower.starts_with("@attribute") {
                let rest = line[10..].trim();
                // name may be quoted
                let (name, tail) = if let Some(stripped) = rest.strip_prefix('\'') {
                    let end = stripped.find('\'').ok_or(format!("line {}: unterminated name", lineno + 1))?;
                    (stripped[..end].to_string(), stripped[end + 1..].trim())
                } else {
                    let mut it = rest.splitn(2, char::is_whitespace);
                    let n = it.next().unwrap_or("").to_string();
                    (n, it.next().unwrap_or("").trim())
                };
                let kind = if tail.starts_with('{') {
                    let inner = tail
                        .trim_start_matches('{')
                        .trim_end_matches('}')
                        .split(',')
                        .map(|s| s.trim().trim_matches('\'').to_string())
                        .collect::<Vec<_>>();
                    Kind::Nominal(inner)
                } else if tail.to_ascii_lowercase().starts_with("numeric")
                    || tail.to_ascii_lowercase().starts_with("real")
                    || tail.to_ascii_lowercase().starts_with("integer")
                {
                    Kind::Numeric
                } else {
                    return Err(format!("line {}: unsupported attribute type '{tail}'", lineno + 1));
                };
                attrs.push((name, kind));
            } else if lower.starts_with("@data") {
                in_data = true;
            }
        } else {
            let cells: Vec<String> = line.split(',').map(|s| s.trim().trim_matches('\'').to_string()).collect();
            if cells.len() != attrs.len() {
                return Err(format!(
                    "line {}: {} cells but {} attributes",
                    lineno + 1,
                    cells.len(),
                    attrs.len()
                ));
            }
            rows.push(cells);
        }
    }

    if attrs.is_empty() || rows.is_empty() {
        return Err("no attributes or no data".into());
    }
    // Identify the class column: last nominal attribute.
    let class_col = attrs
        .iter()
        .rposition(|(_, k)| matches!(k, Kind::Nominal(_)))
        .ok_or("no nominal (class) attribute found")?;
    let n_nominal = attrs.iter().filter(|(_, k)| matches!(k, Kind::Nominal(_))).count();
    if n_nominal > 1 {
        return Err("multiple nominal attributes unsupported (encode them numerically)".into());
    }
    let class_values = match &attrs[class_col].1 {
        Kind::Nominal(v) => v.clone(),
        _ => unreachable!(),
    };

    let mut features = Vec::with_capacity(rows.len());
    let mut labels = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let mut feats = Vec::with_capacity(attrs.len() - 1);
        for (col, cell) in row.iter().enumerate() {
            if col == class_col {
                let idx = class_values
                    .iter()
                    .position(|v| v == cell)
                    .ok_or(format!("row {}: unknown class '{cell}'", i + 1))?;
                labels.push(idx);
            } else {
                // Missing values ('?') become 0.0 — Weka's default
                // ReplaceMissingValues-with-mean is out of scope here.
                feats.push(if cell == "?" { 0.0 } else {
                    cell.parse::<f64>().map_err(|_| format!("row {}: bad numeric '{cell}'", i + 1))?
                });
            }
        }
        features.push(feats);
    }
    Ok(Dataset::new(&relation, features, labels, class_values.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
% comment
@RELATION iris-mini

@ATTRIBUTE sepallength NUMERIC
@ATTRIBUTE sepalwidth  REAL
@ATTRIBUTE class {setosa, versicolor}

@DATA
5.1, 3.5, setosa
7.0, 3.2, versicolor
6.3, ?, versicolor
";

    #[test]
    fn parses_sample() {
        let d = parse_arff(SAMPLE).unwrap();
        assert_eq!(d.name, "iris-mini");
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.n_classes, 2);
        assert_eq!(d.labels, vec![0, 1, 1]);
        assert_eq!(d.features[2][1], 0.0); // missing → 0
    }

    #[test]
    fn class_not_required_last() {
        let text = "@relation t\n@attribute class {a,b}\n@attribute x numeric\n@data\na,1.0\nb,2.0\n";
        let d = parse_arff(text).unwrap();
        assert_eq!(d.dim(), 1);
        assert_eq!(d.labels, vec![0, 1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_arff("").is_err());
        assert!(parse_arff("@relation t\n@attribute x numeric\n@data\n1.0\n").is_err()); // no class
        assert!(parse_arff("@relation t\n@attribute c {a}\n@data\na,extra\n").is_err());
        assert!(parse_arff("@relation t\n@attribute x string\n@data\nz\n").is_err());
    }
}
