//! Feature scaling. Both scalers are fit-once/apply-many and serialize
//! their parameters so the coordinator can ship them with checkpoints.

/// Min–max scaling to `[0, 1]` (constant columns map to 0.5).
#[derive(Debug, Clone)]
pub struct MinMaxScaler {
    mins: Vec<f64>,
    maxs: Vec<f64>,
}

impl MinMaxScaler {
    pub fn fit(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut mins = vec![f64::INFINITY; d];
        let mut maxs = vec![f64::NEG_INFINITY; d];
        for r in rows {
            for j in 0..d {
                mins[j] = mins[j].min(r[j]);
                maxs[j] = maxs[j].max(r[j]);
            }
        }
        MinMaxScaler { mins, maxs }
    }

    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .enumerate()
            .map(|(j, &v)| {
                let range = self.maxs[j] - self.mins[j];
                if range > 0.0 {
                    (v - self.mins[j]) / range
                } else {
                    0.5
                }
            })
            .collect()
    }

    pub fn transform_all(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform(r)).collect()
    }
}

/// Z-score standardization (constant columns pass through centred at 0).
#[derive(Debug, Clone)]
pub struct StandardScaler {
    means: Vec<f64>,
    stds: Vec<f64>,
}

impl StandardScaler {
    pub fn fit(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let n = rows.len() as f64;
        let mut means = vec![0.0; d];
        for r in rows {
            for j in 0..d {
                means[j] += r[j];
            }
        }
        for m in &mut means {
            *m /= n;
        }
        let mut stds = vec![0.0; d];
        for r in rows {
            for j in 0..d {
                let e = r[j] - means[j];
                stds[j] += e * e;
            }
        }
        for s in &mut stds {
            *s = (*s / n).sqrt();
            if *s == 0.0 {
                *s = 1.0;
            }
        }
        StandardScaler { means, stds }
    }

    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        row.iter().enumerate().map(|(j, &v)| (v - self.means[j]) / self.stds[j]).collect()
    }

    pub fn transform_all(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform(r)).collect()
    }

    pub fn means(&self) -> &[f64] {
        &self.means
    }

    pub fn stds(&self) -> &[f64] {
        &self.stds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{mean, std_dev};

    #[test]
    fn minmax_maps_to_unit() {
        let rows = vec![vec![0.0, 10.0], vec![5.0, 20.0], vec![10.0, 30.0]];
        let s = MinMaxScaler::fit(&rows);
        let t = s.transform_all(&rows);
        assert_eq!(t[0], vec![0.0, 0.0]);
        assert_eq!(t[2], vec![1.0, 1.0]);
        assert_eq!(t[1], vec![0.5, 0.5]);
    }

    #[test]
    fn minmax_constant_column() {
        let rows = vec![vec![3.0], vec![3.0]];
        let s = MinMaxScaler::fit(&rows);
        assert_eq!(s.transform(&[3.0]), vec![0.5]);
    }

    #[test]
    fn standard_gives_zero_mean_unit_var() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 * 0.3 + 5.0]).collect();
        let s = StandardScaler::fit(&rows);
        let t: Vec<f64> = rows.iter().map(|r| s.transform(r)[0]).collect();
        assert!(mean(&t).abs() < 1e-12);
        assert!((std_dev(&t) - 1.0).abs() < 0.01);
    }
}
