//! Deterministic random numbers.
//!
//! The offline vendor set does not include the `rand` crate (only the
//! trait-level `rand_core`), so experiments use this self-contained
//! PCG-XSH-RR 64/32-based generator. Every experiment in the repo is
//! seeded, so tables/benches are exactly reproducible run-to-run.

/// PCG64: two independent PCG-XSH-RR 64/32 streams fused into a 64-bit
/// output. Small, fast, and statistically solid for simulation workloads.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal from the Box–Muller pair.
    cached_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed deterministically from a single `u64`.
    pub fn seed(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128).wrapping_mul(0x9E37_79B9_7F4A_7C15) << 1) | 1,
            cached_normal: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        Pcg64::seed(s)
    }

    /// Next raw 64-bit output (PCG-XSL-RR 128/64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free for the
    /// sizes used here).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % (n as u64)) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid u == 0 exactly.
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out {
            *v = self.normal();
        }
    }

    /// Fisher–Yates shuffle of indices `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed(123);
        let mut b = Pcg64::seed(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seed(9);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(1234);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Pcg64::seed(5);
        let p = rng.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut rng = Pcg64::seed(17);
        for _ in 0..1000 {
            let i = rng.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seed(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
