//! Shared evaluation runners for the table-reproduction benches.

use crate::baselines::Classifier;
use crate::data::Dataset;
use crate::engine::EngineConfig;
use crate::eval::{stratified_kfold, CvTimings, FoldResult, Stopwatch};
use crate::gmm::supervised::{supervised_figmn, supervised_igmn};
use crate::gmm::GmmConfig;

/// Which IGMN variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Original,
    Fast,
}

/// Train + test one fold of a (F)IGMN classifier, timing the two phases
/// separately (the paper's Tables 2/3 protocol). Both phases run through
/// the batch API (`learn_batch` / `predict_batch`), so an attached
/// engine shards the component work; results are identical to the
/// serial per-point loop either way.
pub fn run_gmm_fold_engine(
    train: &Dataset,
    test: &Dataset,
    cfg: &GmmConfig,
    variant: Variant,
    engine: Option<EngineConfig>,
) -> FoldResult {
    let stds = train.feature_stds();
    let mut sw_train = Stopwatch::new();
    let mut sw_test = Stopwatch::new();
    let scores: Vec<Vec<f64>> = match variant {
        Variant::Fast => {
            let mut clf = supervised_figmn(cfg.clone(), &stds, train.n_classes);
            clf.model_mut().set_engine(engine);
            sw_train.time(|| clf.train_batch(&train.features, &train.labels));
            sw_test.time(|| clf.class_scores_batch(&test.features))
        }
        Variant::Original => {
            let mut clf = supervised_igmn(cfg.clone(), &stds, train.n_classes);
            clf.model_mut().set_engine(engine);
            sw_train.time(|| clf.train_batch(&train.features, &train.labels));
            sw_test.time(|| clf.class_scores_batch(&test.features))
        }
    };
    FoldResult {
        timings: CvTimings { train_seconds: sw_train.seconds(), test_seconds: sw_test.seconds() },
        scores,
        truth: test.labels.clone(),
    }
}

/// [`run_gmm_fold_engine`] without an engine (serial component passes).
pub fn run_gmm_fold(
    train: &Dataset,
    test: &Dataset,
    cfg: &GmmConfig,
    variant: Variant,
) -> FoldResult {
    run_gmm_fold_engine(train, test, cfg, variant, None)
}

/// 2-fold CV for a (F)IGMN variant; returns per-fold results.
pub fn run_gmm_cv(data: &Dataset, cfg: &GmmConfig, variant: Variant, seed: u64) -> Vec<FoldResult> {
    run_gmm_cv_engine(data, cfg, variant, seed, None)
}

/// 2-fold CV with an optional component-sharded engine on every fold's
/// model.
pub fn run_gmm_cv_engine(
    data: &Dataset,
    cfg: &GmmConfig,
    variant: Variant,
    seed: u64,
    engine: Option<EngineConfig>,
) -> Vec<FoldResult> {
    stratified_kfold(&data.labels, data.n_classes, 2, seed)
        .into_iter()
        .map(|(tr, te)| {
            run_gmm_fold_engine(&data.subset(&tr), &data.subset(&te), cfg, variant, engine)
        })
        .collect()
}

/// 2-fold CV for a batch [`Classifier`]; returns per-fold results.
pub fn run_classifier_cv(
    data: &Dataset,
    make: &mut dyn FnMut() -> Box<dyn Classifier>,
    seed: u64,
) -> Vec<FoldResult> {
    stratified_kfold(&data.labels, data.n_classes, 2, seed)
        .into_iter()
        .map(|(tr, te)| {
            let train = data.subset(&tr);
            let test = data.subset(&te);
            let mut clf = make();
            let mut sw_train = Stopwatch::new();
            sw_train.time(|| clf.fit(&train));
            let mut sw_test = Stopwatch::new();
            let scores = sw_test
                .time(|| test.features.iter().map(|x| clf.class_scores(x)).collect::<Vec<_>>());
            FoldResult {
                timings: CvTimings {
                    train_seconds: sw_train.seconds(),
                    test_seconds: sw_test.seconds(),
                },
                scores,
                truth: test.labels.clone(),
            }
        })
        .collect()
}

/// Estimate the per-point training cost of the **original** IGMN on a
/// dataset too large to run in a bench budget: run `sample` points, then
/// extrapolate linearly in N (cost per point is N-independent at K=1).
/// Returns estimated seconds for `n_total` points.
pub fn extrapolate_igmn_train(data: &Dataset, cfg: &GmmConfig, sample: usize, n_total: usize) -> f64 {
    let stds = data.feature_stds();
    let mut clf = supervised_igmn(cfg.clone(), &stds, data.n_classes);
    let sample = sample.min(data.len());
    let mut sw = Stopwatch::new();
    sw.time(|| {
        for i in 0..sample {
            clf.train_one(&data.features[i], data.labels[i]);
        }
    });
    sw.seconds() / sample as f64 * n_total as f64
}

/// Same extrapolation for testing time.
pub fn extrapolate_igmn_test(data: &Dataset, cfg: &GmmConfig, train_n: usize, sample: usize, n_total: usize) -> f64 {
    let stds = data.feature_stds();
    let mut clf = supervised_igmn(cfg.clone(), &stds, data.n_classes);
    for i in 0..train_n.min(data.len()) {
        clf.train_one(&data.features[i], data.labels[i]);
    }
    let sample = sample.min(data.len());
    let mut sw = Stopwatch::new();
    sw.time(|| {
        for i in 0..sample {
            let _ = clf.class_scores(&data.features[i]);
        }
    });
    sw.seconds() / sample as f64 * n_total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn cv_produces_two_folds_with_scores() {
        let data = synth::generate(synth::spec("iris").unwrap(), 1);
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();
        let folds = run_gmm_cv(&data, &cfg, Variant::Fast, 7);
        assert_eq!(folds.len(), 2);
        for f in &folds {
            assert_eq!(f.scores.len(), f.truth.len());
            assert!(f.timings.train_seconds > 0.0);
            let auc = f.auc(data.n_classes);
            assert!(auc > 0.5, "auc {auc}");
        }
    }

    #[test]
    fn fast_equals_original_fold_scores() {
        let data = synth::generate(synth::spec("Glass").unwrap(), 2);
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();
        let a = run_gmm_cv(&data, &cfg, Variant::Fast, 3);
        let b = run_gmm_cv(&data, &cfg, Variant::Original, 3);
        for (fa, fb) in a.iter().zip(b.iter()) {
            assert!(
                (fa.auc(data.n_classes) - fb.auc(data.n_classes)).abs() < 1e-9,
                "paper's Table 4 equality violated"
            );
        }
    }

    #[test]
    fn engine_fold_matches_serial_fold() {
        let data = synth::generate(synth::spec("ionosphere").unwrap(), 3);
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();
        let a = run_gmm_cv(&data, &cfg, Variant::Fast, 5);
        let b = run_gmm_cv_engine(&data, &cfg, Variant::Fast, 5, Some(EngineConfig::new(2)));
        for (fa, fb) in a.iter().zip(b.iter()) {
            assert_eq!(fa.scores, fb.scores, "engine changed fold scores");
        }
    }

    #[test]
    fn extrapolation_is_positive_and_scales() {
        let data = synth::generate(synth::spec("ionosphere").unwrap(), 1);
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();
        let est100 = extrapolate_igmn_train(&data, &cfg, 30, 100);
        let est200 = extrapolate_igmn_train(&data, &cfg, 30, 200);
        assert!(est100 > 0.0);
        assert!(est200 > est100);
    }
}
