//! In-repo benchmark harness.
//!
//! `criterion` is not in the offline vendor set (DESIGN.md §5), so the
//! `benches/` binaries (registered with `harness = false`) use this
//! module: repeated timing with mean ± std, paper-style table rendering
//! with the ○/● significance marks, and log-log slope fitting for the
//! complexity-scaling experiment.

pub mod gmm_eval;

use crate::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode, LearnMode, SearchMode};
use crate::json::Json;
use crate::rng::Pcg64;
use crate::stats::{mean, paired_t_test, std_dev};
use std::time::Instant;

/// Config under which [`grow_stream`] grows **exactly** `k` components:
/// σ_ini tiny (every far-apart center is novel), component count capped
/// at `k` (everything after the cap updates), pruning off.
pub fn grow_config(d: usize, k: usize, mode: KernelMode) -> GmmConfig {
    GmmConfig::new(d)
        .with_delta(0.001)
        .with_beta(0.3)
        .with_max_components(k)
        .with_kernel_mode(mode)
        .without_pruning()
}

/// Training stream for [`grow_config`]: `k` far-apart centers (each
/// creates a component) followed by one noisy revisit per center (cap
/// full → updates, so sp/log_det move off their initial values). One
/// recipe shared by the blocked-scoring benches and
/// `tests/blocked_scoring_equivalence.rs`, so the grow-exactly-K
/// behavior cannot drift between them.
pub fn grow_stream(d: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal() * 1e3).collect())
        .collect();
    let mut out: Vec<Vec<f64>> = centers.clone();
    for c in &centers {
        out.push(c.iter().map(|&v| v + rng.normal() * 0.1).collect());
    }
    out
}

/// A trained [`Figmn`] with exactly `k` components at dimension `d`.
pub fn grown_model(d: usize, k: usize, mode: KernelMode, seed: u64) -> Figmn {
    let mut m = Figmn::new(grow_config(d, k, mode), &vec![1.0; d]);
    for x in grow_stream(d, k, seed) {
        m.learn(&x);
    }
    assert_eq!(m.num_components(), k, "grow stream must create exactly K={k} components");
    m
}

/// A `k`-component model at dimension `d` built directly in the arenas
/// (no training): well-separated means (scale 40, so components stay
/// astronomically apart at D≥8), diagonal precisions `λ = 1/0.25`, and
/// realistic `sp`/`v` bookkeeping. Growing state this size via `learn`
/// is `O(N·K·D²)` — minutes of setup at K=16384 before the first
/// measurement — and the K-scaling bench only needs *some* realistic
/// K-component state to sweep; every measured arm re-materializes from
/// the same arenas (see [`rematerialize`]), so the shortcut cannot
/// favor one search mode over the other.
pub fn synthetic_grown_model(d: usize, k: usize, mode: SearchMode, seed: u64) -> Figmn {
    use crate::gmm::ComponentStore;
    use crate::linalg::packed;

    let mut rng = Pcg64::seed(seed);
    let sigma = 0.5_f64;
    let lambda = packed::from_diag(&vec![1.0 / (sigma * sigma); d]);
    // log|C| for C = σ²·I.
    let log_det = d as f64 * (sigma * sigma).ln();
    let mut store = ComponentStore::with_capacity(d, k);
    for j in 0..k {
        let mean: Vec<f64> = (0..d).map(|_| rng.normal() * 40.0).collect();
        store.push(&mean, &lambda, log_det, 2.0 + (j % 7) as f64 * 0.25, 2);
    }
    let cfg = GmmConfig::new(d)
        .with_delta(sigma)
        .with_beta(0.05)
        .with_max_components(k)
        .with_search_mode(mode)
        .without_pruning();
    let sigma_ini = cfg.sigma_ini(&vec![1.0; d]);
    Figmn::from_parts(cfg, sigma_ini, store, 2 * k as u64)
}

/// The centers [`synthetic_grown_model`] drew for seed `seed` — probe
/// and update streams are built around these.
pub fn synthetic_centers(d: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    (0..k).map(|_| (0..d).map(|_| rng.normal() * 40.0).collect()).collect()
}

/// Re-materialize `m` over a clone of its arenas under a different
/// [`SearchMode`]. Both models share bit-identical component state, so
/// benches can compare full-K vs top-C sweeps (or strict vs strict at
/// different thread counts) without paying to grow the model twice —
/// growing a full-mode model at K=16384 is O(N·K·D²) and infeasible,
/// while growing once and cloning the arenas is a memcpy.
pub fn rematerialize(m: &Figmn, mode: SearchMode) -> Figmn {
    Figmn::from_parts(
        m.config().clone().with_search_mode(mode),
        m.sigma_ini().to_vec(),
        m.store().clone(),
        m.points_seen(),
    )
}

/// Re-materialize `m` over a clone of its arenas under a different
/// [`LearnMode`] — the write-path analogue of [`rematerialize`], so the
/// mini-batch bench can compare online vs staged arms over
/// bit-identical component state.
pub fn rematerialize_learn_mode(m: &Figmn, mode: LearnMode) -> Figmn {
    Figmn::from_parts(
        m.config().clone().with_learn_mode(mode),
        m.sigma_ini().to_vec(),
        m.store().clone(),
        m.points_seen(),
    )
}

/// True when benches should run in CI-smoke "quick mode"
/// (`FIGMN_BENCH_QUICK=1`): shrunken sweeps, perf assertions skipped.
pub fn quick_mode() -> bool {
    std::env::var("FIGMN_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Write a bench result document to `BENCH_<name>.json` in the current
/// directory and return the path. The CI bench-smoke job uploads these
/// as artifacts, seeding the repo's perf trajectory.
pub fn write_bench_json(name: &str, payload: &Json) -> std::io::Result<String> {
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, payload.to_string_compact())?;
    Ok(path)
}

/// Time `f` once, returning seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t = Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

/// Run `f` `reps` times; returns per-rep seconds.
pub fn time_reps(reps: usize, mut f: impl FnMut()) -> Vec<f64> {
    assert!(reps >= 1);
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// `mean ± std` cell, paper style (3 decimals).
pub fn fmt_cell(samples: &[f64]) -> String {
    format!("{:9.3} ±{:7.3}", mean(samples), std_dev(samples))
}

/// The paper's table convention: compare `b` against baseline `a` with a
/// paired t-test at α; returns `'●'` (significant decrease), `'○'`
/// (significant increase) or `' '`.
pub fn significance_mark(a: &[f64], b: &[f64], alpha: f64) -> char {
    if a.len() != b.len() || a.len() < 2 {
        return ' ';
    }
    paired_t_test(a, b).mark(alpha)
}

/// Fit `y = c·xᵖ` by least squares in log-log space; returns `p`.
/// This is the exponent check for the O(D³) → O(D²) claim.
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|&v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|&v| v.ln()).collect();
    let mx = mean(&lx);
    let my = mean(&ly);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in lx.iter().zip(ly.iter()) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den
}

/// Fixed-width table printer.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths.iter()) {
            line.push_str(&format!("{h:<w$} ", w = w));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        TablePrinter { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(self.widths.iter()) {
            line.push_str(&format!("{c:<w$} ", w = w));
        }
        println!("{line}");
    }
}

/// Percentile of a sample (nearest-rank); used by latency reports.
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
    samples[rank.saturating_sub(1).min(samples.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_recovers_exponents() {
        let xs = [8.0, 16.0, 32.0, 64.0, 128.0];
        let cubic: Vec<f64> = xs.iter().map(|&x| 2e-9 * x * x * x).collect();
        let quad: Vec<f64> = xs.iter().map(|&x| 3e-8 * x * x).collect();
        assert!((fit_power_law(&xs, &cubic) - 3.0).abs() < 1e-9);
        assert!((fit_power_law(&xs, &quad) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn time_reps_returns_reps() {
        let t = time_reps(3, || std::thread::sleep(std::time::Duration::from_micros(100)));
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut s = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&mut s, 50.0), 5.0);
        assert_eq!(percentile(&mut s, 100.0), 10.0);
        assert_eq!(percentile(&mut s, 1.0), 1.0);
    }

    #[test]
    fn quick_mode_reads_env_value() {
        // Only asserts the accessor is callable; the env var is global
        // state, so don't mutate it here.
        let _ = quick_mode();
    }

    #[test]
    fn bench_json_writes_file() {
        let payload = Json::obj(vec![("ok", true.into())]);
        let path = write_bench_json("unit_test", &payload).unwrap();
        assert_eq!(path, "BENCH_unit_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(text, r#"{"ok":true}"#);
    }

    #[test]
    fn significance_marks_direction() {
        let slow = [1.0, 1.1, 1.05, 0.95];
        let fast = [0.1, 0.12, 0.11, 0.09];
        assert_eq!(significance_mark(&slow, &fast, 0.05), '●');
        assert_eq!(significance_mark(&fast, &slow, 0.05), '○');
        assert_eq!(significance_mark(&slow, &slow, 0.05), ' ');
    }
}
