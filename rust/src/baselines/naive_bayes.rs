//! Gaussian naive Bayes (the paper's Naive Bayes column).

use super::Classifier;
use crate::data::Dataset;

/// Per-class independent Gaussians per feature, with Laplace-smoothed
/// priors and a variance floor for constant features.
#[derive(Default)]
pub struct GaussianNaiveBayes {
    priors: Vec<f64>,
    means: Vec<Vec<f64>>,
    vars: Vec<Vec<f64>>,
}

impl GaussianNaiveBayes {
    pub fn new() -> Self {
        Self::default()
    }
}

const VAR_FLOOR: f64 = 1e-9;

impl Classifier for GaussianNaiveBayes {
    fn fit(&mut self, data: &Dataset) {
        let k = data.n_classes;
        let d = data.dim();
        let counts = data.class_counts();
        self.priors = counts
            .iter()
            .map(|&c| (c as f64 + 1.0) / (data.len() as f64 + k as f64))
            .collect();
        self.means = vec![vec![0.0; d]; k];
        self.vars = vec![vec![0.0; d]; k];
        for (row, &label) in data.features.iter().zip(data.labels.iter()) {
            for j in 0..d {
                self.means[label][j] += row[j];
            }
        }
        for c in 0..k {
            let n = counts[c].max(1) as f64;
            for j in 0..d {
                self.means[c][j] /= n;
            }
        }
        for (row, &label) in data.features.iter().zip(data.labels.iter()) {
            for j in 0..d {
                let e = row[j] - self.means[label][j];
                self.vars[label][j] += e * e;
            }
        }
        for c in 0..k {
            let n = counts[c].max(1) as f64;
            for j in 0..d {
                self.vars[c][j] = (self.vars[c][j] / n).max(VAR_FLOOR);
            }
        }
    }

    fn class_scores(&self, x: &[f64]) -> Vec<f64> {
        assert!(!self.priors.is_empty(), "fit before predict");
        let k = self.priors.len();
        let mut log_scores = Vec::with_capacity(k);
        let mut best = f64::NEG_INFINITY;
        for c in 0..k {
            let mut s = self.priors[c].ln();
            for (j, &xj) in x.iter().enumerate() {
                let var = self.vars[c][j];
                let e = xj - self.means[c][j];
                s += -0.5 * ((2.0 * std::f64::consts::PI * var).ln() + e * e / var);
            }
            log_scores.push(s);
            best = best.max(s);
        }
        // Softmax to a proper distribution for AUC scoring.
        let mut total = 0.0;
        let mut out: Vec<f64> = log_scores
            .iter()
            .map(|&s| {
                let v = (s - best).exp();
                total += v;
                v
            })
            .collect();
        for v in &mut out {
            *v /= total;
        }
        out
    }

    fn name(&self) -> &'static str {
        "Naive Bayes"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_support::check_learns;
    use crate::data::Dataset;

    #[test]
    fn learns_blobs() {
        check_learns(&mut GaussianNaiveBayes::new(), 0.95);
    }

    #[test]
    fn handles_constant_feature() {
        let d = Dataset::new(
            "t",
            vec![vec![1.0, 0.0], vec![1.0, 0.1], vec![1.0, 5.0], vec![1.0, 5.1]],
            vec![0, 0, 1, 1],
            2,
        );
        let mut nb = GaussianNaiveBayes::new();
        nb.fit(&d);
        assert_eq!(nb.predict(&[1.0, 0.05]), 0);
        assert_eq!(nb.predict(&[1.0, 5.05]), 1);
        assert!(nb.class_scores(&[1.0, 2.5]).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn recovers_gaussian_parameters() {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for i in 0..1000 {
            let t = (i as f64 / 1000.0 - 0.5) * 3.46; // ~uniform, var≈1
            rows.push(vec![t + 10.0]);
            labels.push(0);
        }
        let d = Dataset::new("t", rows, labels, 1);
        let mut nb = GaussianNaiveBayes::new();
        nb.fit(&d);
        assert!((nb.means[0][0] - 10.0).abs() < 0.01);
        assert!((nb.vars[0][0] - 1.0).abs() < 0.1);
    }
}
