//! Dropout multi-layer perceptron — the paper's "Neural Network" column:
//! one hidden layer of 50 units, 50% hidden dropout, 20% input dropout
//! (Hinton et al. 2012, as cited), softmax output, SGD with momentum.

use super::Classifier;
use crate::data::{Dataset, StandardScaler};
use crate::rng::Pcg64;

/// MLP hyper-parameters (defaults = the paper's Table 4 settings).
#[derive(Debug, Clone, Copy)]
pub struct MlpConfig {
    pub hidden: usize,
    pub input_dropout: f64,
    pub hidden_dropout: f64,
    pub learning_rate: f64,
    pub momentum: f64,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig {
            hidden: 50,
            input_dropout: 0.2,
            hidden_dropout: 0.5,
            learning_rate: 0.05,
            momentum: 0.9,
            epochs: 60,
            seed: 1,
        }
    }
}

/// Single-hidden-layer dropout MLP with ReLU hidden units.
pub struct Mlp {
    cfg: MlpConfig,
    scaler: Option<StandardScaler>,
    // Weights: w1[h][d], b1[h], w2[c][h], b2[c].
    w1: Vec<Vec<f64>>,
    b1: Vec<f64>,
    w2: Vec<Vec<f64>>,
    b2: Vec<f64>,
    n_classes: usize,
}

impl Mlp {
    pub fn new(cfg: MlpConfig) -> Self {
        Mlp { cfg, scaler: None, w1: vec![], b1: vec![], w2: vec![], b2: vec![], n_classes: 0 }
    }

    fn forward(&self, x: &[f64], hidden_scale: f64) -> (Vec<f64>, Vec<f64>) {
        // Inference-time dropout scaling: multiply activations by keep-prob.
        let h: Vec<f64> = self
            .w1
            .iter()
            .zip(self.b1.iter())
            .map(|(w, &b)| {
                let z: f64 = w.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>() + b;
                z.max(0.0) * hidden_scale
            })
            .collect();
        let logits: Vec<f64> = self
            .w2
            .iter()
            .zip(self.b2.iter())
            .map(|(w, &b)| w.iter().zip(h.iter()).map(|(a, b)| a * b).sum::<f64>() + b)
            .collect();
        (h, logits)
    }
}

fn softmax(logits: &[f64]) -> Vec<f64> {
    let best = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut total = 0.0;
    let mut out: Vec<f64> = logits
        .iter()
        .map(|&z| {
            let v = (z - best).exp();
            total += v;
            v
        })
        .collect();
    for v in &mut out {
        *v /= total;
    }
    out
}

impl Classifier for Mlp {
    fn fit(&mut self, data: &Dataset) {
        let scaler = StandardScaler::fit(&data.features);
        let xs = scaler.transform_all(&data.features);
        let d = data.dim();
        let h = self.cfg.hidden;
        let k = data.n_classes;
        self.n_classes = k;
        let mut rng = Pcg64::seed(self.cfg.seed);

        // He init for ReLU.
        let scale1 = (2.0 / d as f64).sqrt();
        let scale2 = (2.0 / h as f64).sqrt();
        self.w1 = (0..h).map(|_| (0..d).map(|_| rng.normal() * scale1).collect()).collect();
        self.b1 = vec![0.0; h];
        self.w2 = (0..k).map(|_| (0..h).map(|_| rng.normal() * scale2).collect()).collect();
        self.b2 = vec![0.0; k];

        let mut vw1 = vec![vec![0.0; d]; h];
        let mut vb1 = vec![0.0; h];
        let mut vw2 = vec![vec![0.0; h]; k];
        let mut vb2 = vec![0.0; k];

        let n = xs.len();
        let lr = self.cfg.learning_rate;
        let mom = self.cfg.momentum;
        for _epoch in 0..self.cfg.epochs {
            for _ in 0..n {
                let i = rng.below(n);
                // Input dropout mask.
                let xi: Vec<f64> = xs[i]
                    .iter()
                    .map(|&v| if rng.uniform() < self.cfg.input_dropout { 0.0 } else { v })
                    .collect();
                // Hidden forward with dropout mask.
                let mut hmask = vec![false; h];
                let mut hact = vec![0.0; h];
                for j in 0..h {
                    if rng.uniform() < self.cfg.hidden_dropout {
                        continue; // dropped
                    }
                    hmask[j] = true;
                    let z: f64 = self.w1[j].iter().zip(xi.iter()).map(|(a, b)| a * b).sum::<f64>()
                        + self.b1[j];
                    hact[j] = z.max(0.0);
                }
                let logits: Vec<f64> = (0..k)
                    .map(|c| {
                        self.w2[c].iter().zip(hact.iter()).map(|(a, b)| a * b).sum::<f64>()
                            + self.b2[c]
                    })
                    .collect();
                let probs = softmax(&logits);

                // Backprop (cross-entropy): δ_out = p − y.
                let y = data.labels[i];
                let dout: Vec<f64> =
                    probs.iter().enumerate().map(|(c, &p)| p - if c == y { 1.0 } else { 0.0 }).collect();
                // Hidden deltas.
                let mut dh = vec![0.0; h];
                for c in 0..k {
                    for j in 0..h {
                        if hmask[j] && hact[j] > 0.0 {
                            dh[j] += dout[c] * self.w2[c][j];
                        }
                    }
                }
                // Update output layer.
                for c in 0..k {
                    for j in 0..h {
                        let g = dout[c] * hact[j];
                        vw2[c][j] = mom * vw2[c][j] - lr * g;
                        self.w2[c][j] += vw2[c][j];
                    }
                    vb2[c] = mom * vb2[c] - lr * dout[c];
                    self.b2[c] += vb2[c];
                }
                // Update hidden layer.
                for j in 0..h {
                    if !hmask[j] || dh[j] == 0.0 {
                        continue;
                    }
                    for (w, (&xv, v)) in
                        self.w1[j].iter_mut().zip(xi.iter().zip(vw1[j].iter_mut()))
                    {
                        let g = dh[j] * xv;
                        *v = mom * *v - lr * g;
                        *w += *v;
                    }
                    vb1[j] = mom * vb1[j] - lr * dh[j];
                    self.b1[j] += vb1[j];
                    // Max-norm constraint (Hinton et al. 2012 §A.1, the
                    // standard companion to dropout): rescale the unit's
                    // incoming weights to ‖w‖ ≤ c. Keeps high-D training
                    // (e.g. D=3072) from exploding at fixed η.
                    const MAX_NORM: f64 = 4.0;
                    let norm2: f64 = self.w1[j].iter().map(|w| w * w).sum();
                    if norm2 > MAX_NORM * MAX_NORM {
                        let s = MAX_NORM / norm2.sqrt();
                        for w in self.w1[j].iter_mut() {
                            *w *= s;
                        }
                    }
                }
            }
        }
        self.scaler = Some(scaler);
    }

    fn class_scores(&self, x: &[f64]) -> Vec<f64> {
        assert!(self.n_classes > 0, "fit before predict");
        let x = self.scaler.as_ref().unwrap().transform(x);
        // Dropout inference scaling: hidden activations × keep-prob; input
        // scaling folded in the same way.
        let xin: Vec<f64> = x.iter().map(|&v| v * (1.0 - self.cfg.input_dropout)).collect();
        let (_, logits) = self.forward(&xin, 1.0 - self.cfg.hidden_dropout);
        softmax(&logits)
    }

    fn name(&self) -> &'static str {
        "Neural Network"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_support::check_learns;

    #[test]
    fn learns_blobs() {
        check_learns(&mut Mlp::new(MlpConfig { epochs: 30, ..Default::default() }), 0.93);
    }

    #[test]
    fn scores_are_distribution() {
        let d = crate::baselines::test_support::blobs(90, 5);
        let mut mlp = Mlp::new(MlpConfig { epochs: 5, ..Default::default() });
        mlp.fit(&d);
        let s = mlp.class_scores(&d.features[0]);
        assert_eq!(s.len(), 3);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = crate::baselines::test_support::blobs(60, 6);
        let mut a = Mlp::new(MlpConfig { epochs: 3, ..Default::default() });
        let mut b = Mlp::new(MlpConfig { epochs: 3, ..Default::default() });
        a.fit(&d);
        b.fit(&d);
        assert_eq!(a.class_scores(&d.features[1]), b.class_scores(&d.features[1]));
    }
}
