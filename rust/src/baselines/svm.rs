//! Linear SVM trained with Pegasos (Shalev-Shwartz et al. 2011) in a
//! one-vs-rest arrangement — standing in for Weka's linear-kernel SMO
//! (the paper's SVM column).

use super::Classifier;
use crate::data::Dataset;
use crate::data::StandardScaler;
use crate::rng::Pcg64;

/// Pegasos hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SvmConfig {
    /// Regularization λ.
    pub lambda: f64,
    /// Number of stochastic epochs over the training set.
    pub epochs: usize,
    /// RNG seed for the stochastic sampling.
    pub seed: u64,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig { lambda: 1e-4, epochs: 30, seed: 1 }
    }
}

/// One-vs-rest linear SVM. Scores are the (standardized-input) margins
/// squashed through a logistic for AUC-friendly ranking.
pub struct LinearSvm {
    cfg: SvmConfig,
    scaler: Option<StandardScaler>,
    /// Per class: (weights, bias).
    machines: Vec<(Vec<f64>, f64)>,
}

impl LinearSvm {
    pub fn new(cfg: SvmConfig) -> Self {
        LinearSvm { cfg, scaler: None, machines: Vec::new() }
    }

    fn train_binary(&self, xs: &[Vec<f64>], ys: &[f64], seed: u64) -> (Vec<f64>, f64) {
        let d = xs[0].len();
        let n = xs.len();
        let mut w = vec![0.0; d];
        let mut b = 0.0;
        let mut rng = Pcg64::seed(seed);
        let lambda = self.cfg.lambda;
        let mut t: f64 = 1.0;
        for _ in 0..self.cfg.epochs {
            for _ in 0..n {
                let i = rng.below(n);
                t += 1.0;
                let eta = 1.0 / (lambda * t);
                let margin: f64 =
                    ys[i] * (xs[i].iter().zip(w.iter()).map(|(a, b)| a * b).sum::<f64>() + b);
                // w ← (1 − ηλ)w (+ η y x if margin < 1)
                let shrink = 1.0 - eta * lambda;
                for wj in w.iter_mut() {
                    *wj *= shrink;
                }
                if margin < 1.0 {
                    for (wj, &xj) in w.iter_mut().zip(xs[i].iter()) {
                        *wj += eta * ys[i] * xj;
                    }
                    b += eta * ys[i];
                }
            }
        }
        (w, b)
    }
}

impl Classifier for LinearSvm {
    fn fit(&mut self, data: &Dataset) {
        let scaler = StandardScaler::fit(&data.features);
        let xs = scaler.transform_all(&data.features);
        self.machines = (0..data.n_classes)
            .map(|c| {
                let ys: Vec<f64> =
                    data.labels.iter().map(|&l| if l == c { 1.0 } else { -1.0 }).collect();
                self.train_binary(&xs, &ys, self.cfg.seed.wrapping_add(c as u64))
            })
            .collect();
        self.scaler = Some(scaler);
    }

    fn class_scores(&self, x: &[f64]) -> Vec<f64> {
        assert!(!self.machines.is_empty(), "fit before predict");
        let x = self.scaler.as_ref().unwrap().transform(x);
        let mut scores: Vec<f64> = self
            .machines
            .iter()
            .map(|(w, b)| {
                let m: f64 = w.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>() + b;
                1.0 / (1.0 + (-m).exp()) // logistic squash of the margin
            })
            .collect();
        let total: f64 = scores.iter().sum();
        if total > 0.0 {
            for s in &mut scores {
                *s /= total;
            }
        }
        scores
    }

    fn name(&self) -> &'static str {
        "SVM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_support::check_learns;
    use crate::data::Dataset;

    #[test]
    fn learns_blobs() {
        check_learns(&mut LinearSvm::new(SvmConfig::default()), 0.95);
    }

    #[test]
    fn separates_linearly_separable() {
        let mut features = Vec::new();
        let mut labels = Vec::new();
        for i in 0..100 {
            let t = i as f64 / 50.0 - 1.0;
            features.push(vec![t, 1.5 + t * 0.1]);
            labels.push(1);
            features.push(vec![t, -1.5 - t * 0.1]);
            labels.push(0);
        }
        let d = Dataset::new("sep", features, labels, 2);
        let mut svm = LinearSvm::new(SvmConfig::default());
        svm.fit(&d);
        assert_eq!(svm.predict(&[0.0, 2.0]), 1);
        assert_eq!(svm.predict(&[0.0, -2.0]), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = crate::baselines::test_support::blobs(60, 3);
        let mut a = LinearSvm::new(SvmConfig::default());
        let mut b = LinearSvm::new(SvmConfig::default());
        a.fit(&d);
        b.fit(&d);
        assert_eq!(a.class_scores(&d.features[0]), b.class_scores(&d.features[0]));
    }
}
