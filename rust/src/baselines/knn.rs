//! k-nearest neighbours (the paper's 1-NN column).

use super::Classifier;
use crate::data::Dataset;

/// Brute-force k-NN with Euclidean distance. Scores are the
/// distance-weighted vote shares of the k nearest neighbours (for k = 1
/// this degenerates to a one-hot vote, like Weka's IB1).
pub struct Knn {
    k: usize,
    train: Option<Dataset>,
}

impl Knn {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Knn { k, train: None }
    }
}

impl Classifier for Knn {
    fn fit(&mut self, data: &Dataset) {
        self.train = Some(data.clone());
    }

    fn class_scores(&self, x: &[f64]) -> Vec<f64> {
        let train = self.train.as_ref().expect("fit before predict");
        let mut dists: Vec<(f64, usize)> = train
            .features
            .iter()
            .zip(train.labels.iter())
            .map(|(row, &label)| {
                let d2: f64 = row.iter().zip(x.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
                (d2, label)
            })
            .collect();
        let k = self.k.min(dists.len());
        dists.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut scores = vec![0.0; train.n_classes];
        for &(d2, label) in &dists[..k] {
            scores[label] += 1.0 / (1.0 + d2);
        }
        let total: f64 = scores.iter().sum();
        if total > 0.0 {
            for s in &mut scores {
                *s /= total;
            }
        }
        scores
    }

    fn name(&self) -> &'static str {
        if self.k == 1 {
            "1-NN"
        } else {
            "k-NN"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_support::check_learns;
    use crate::data::Dataset;

    #[test]
    fn learns_blobs() {
        check_learns(&mut Knn::new(1), 0.95);
        check_learns(&mut Knn::new(5), 0.95);
    }

    #[test]
    fn exact_match_wins() {
        let d = Dataset::new(
            "t",
            vec![vec![0.0, 0.0], vec![10.0, 10.0]],
            vec![0, 1],
            2,
        );
        let mut knn = Knn::new(1);
        knn.fit(&d);
        assert_eq!(knn.predict(&[0.1, -0.1]), 0);
        assert_eq!(knn.predict(&[9.9, 10.2]), 1);
    }

    #[test]
    #[should_panic]
    fn predict_before_fit_panics() {
        Knn::new(1).class_scores(&[0.0]);
    }
}
