//! Table 4 comparator classifiers, implemented from scratch (the paper
//! used Weka's): a dropout MLP (Hinton et al. 2012, the paper's "Neural
//! Network" column), 1-NN, Gaussian naive Bayes, and a linear SVM trained
//! with Pegasos (≈ Weka's linear SMO). All implement [`Classifier`] so
//! the Table 4 harness can sweep them uniformly.

mod knn;
mod mlp;
mod naive_bayes;
mod svm;

pub use knn::Knn;
pub use mlp::{Mlp, MlpConfig};
pub use naive_bayes::GaussianNaiveBayes;
pub use svm::{LinearSvm, SvmConfig};

use crate::data::Dataset;

/// A batch-trained classifier producing per-class confidence scores
/// (usable as AUC ranking scores).
pub trait Classifier {
    /// Fit on a training set (may be called once only).
    fn fit(&mut self, data: &Dataset);

    /// Per-class scores for one example; higher = more confident. Scores
    /// need not be calibrated probabilities but must rank correctly.
    fn class_scores(&self, x: &[f64]) -> Vec<f64>;

    /// Hard prediction: argmax of the scores.
    fn predict(&self, x: &[f64]) -> usize {
        self.class_scores(x)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Display name for result tables.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::data::Dataset;
    use crate::rng::Pcg64;

    /// Three well-separated Gaussian blobs in 2-D.
    pub fn blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::seed(seed);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        let mut features = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            features.push(vec![
                centers[c][0] + rng.normal() * 0.8,
                centers[c][1] + rng.normal() * 0.8,
            ]);
            labels.push(c);
        }
        Dataset::new("blobs", features, labels, 3)
    }

    /// Generic smoke check: ≥`min_acc` holdout accuracy on the blobs.
    pub fn check_learns(clf: &mut dyn super::Classifier, min_acc: f64) {
        let train = blobs(300, 1);
        let test = blobs(90, 2);
        clf.fit(&train);
        let correct = test
            .features
            .iter()
            .zip(test.labels.iter())
            .filter(|(x, &y)| clf.predict(x) == y)
            .count();
        let acc = correct as f64 / test.len() as f64;
        assert!(acc >= min_acc, "{} accuracy {acc} < {min_acc}", clf.name());
        // Scores have the right arity everywhere.
        let s = clf.class_scores(&test.features[0]);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|v| v.is_finite()));
    }
}
