//! The staged learn pipeline shared by the online and mini-batch write
//! paths.
//!
//! Historically `Figmn::learn`/`learn_full`/`learn_topc` were a monolith
//! inside `figmn.rs`; this module factors the write path into its three
//! stages so the per-point and blocked paths share one set of bodies:
//!
//! 1. **Distance/score pass** — squared Mahalanobis distances to every
//!    (candidate) component, saving each component's `w = Λ·e` for the
//!    fused update. Per-point this is [`distance_pass`] /
//!    [`candidate_distance_pass`]; the blocked variant
//!    [`block_distance_pass`] streams each packed component row **once
//!    per B-point block** through
//!    [`packed::quad_form_with_multi_mode`] — the same `K×B` tiling
//!    that took the scoring read path off the memory wall (PR 5), now
//!    on the write path.
//! 2. **Novelty/assignment decisions** — the χ² update-vs-create test
//!    (§2.1), the `max_components` cap, and posterior assignment via
//!    [`super::softmax_posteriors`]. Always sequential and
//!    data-dependent, so results are thread-count independent by
//!    construction.
//! 3. **Fused rank-one updates** — Eqs. 4–9 plus the fused
//!    Sherman–Morrison/determinant-lemma update, one component row at a
//!    time ([`update_component`]), sharded over the component axis via
//!    [`update_pass`] / [`candidate_update_pass`] /
//!    [`block_update_pass`].
//!
//! ## Learn modes
//!
//! [`LearnMode::Online`] (the default) consumes one point per step —
//! stage 1 → 2 → 3 per point — and is **bit-identical to the
//! pre-pipeline learn path at every thread count**: the stage bodies
//! are the exact functions that used to live in `figmn.rs`, performing
//! the same floating-point operations in the same order.
//!
//! [`LearnMode::MiniBatch`]`{b}` stages `b`-point blocks: one blocked
//! distance pass over the `K×B` tile, then sequential per-point
//! decisions against the **frozen** block scores, then a
//! component-outer update stage that streams each packed row once per
//! block instead of once per point. Within a block the posteriors,
//! `sp` weights and `w = Λ·e` vectors are frozen at block start — the
//! classical mini-batch approximation (Hosseini & Sra 2019-style
//! stochastic EM): points later in a block do not see the updates of
//! earlier ones. Two exactness properties are preserved:
//!
//! - a block of length 1 routes through the online bodies, so
//!   `MiniBatch{b: 1}` is bit-identical to `Online`;
//! - results are bit-deterministic across thread counts (stage 2 is
//!   serial; stages 1/3 are component-sharded with per-row instruction
//!   sequences independent of the shard partition).
//!
//! Novel points inside a block are still decided sequentially: a point
//! that fails χ² against the frozen scores is checked against the
//! components created *earlier in the same block* (exact per-point
//! kernels) before a create is allowed, so a drifting stream does not
//! spawn `b` duplicate components where the online path would create
//! one.
//!
//! ## The masked TopC blocked pass — the union/mask contract
//!
//! TopC models no longer fall back to per-point dispatch: their blocks
//! stage through [`topc_block_pass`], which precomputes each point's
//! top-C candidate set against the **block-start** store/index, takes
//! the **union** of those sets, and streams each union row's packed
//! arena data **once per block** through the PR 5 multi-query kernels —
//! but only over the compact residual tile of the points whose
//! candidate **mask** contains that row. Flop count is exactly the
//! per-point path's `Σ|cands| = C·B`; the win is bandwidth (each packed
//! row read once per block instead of once per masking point) and it
//! grows with in-block candidate overlap.
//!
//! Exactness contract (TopC + MiniBatch is **bit-identical** to the
//! TopC per-point path at every thread count):
//!
//! - the multi-query kernels are per-query bit-identical to the
//!   per-point kernels (the PR 5 contract), so a frozen tile entry
//!   equals what the per-point pass would compute against the same row
//!   state;
//! - the decision stage replays the **exact per-point TopC body** per
//!   point — live index re-query, per-point decay, the exact
//!   χ²-fallback gate, per-point update/drift/prune — consuming a
//!   frozen entry only when the row is provably untouched since block
//!   start (not updated with `p > 0` by an earlier in-block point, no
//!   mid-block prune renumbering — see [`TopcBlockTile`]) *and* the row
//!   was in that point's precomputed mask; every other (point, row)
//!   pair is recomputed with the per-point kernel, whose arithmetic is
//!   self-contained per pair.
//!
//! ## Drift adaptation
//!
//! Two per-model knobs make the write path track non-stationary
//! streams (`GmmConfig::decay` / `GmmConfig::max_age`):
//!
//! - **`sp`/`v` decay** — every learned point first multiplies all
//!   `sp` accumulators by `decay` and scales the integer ages `v` the
//!   same way (truncating toward zero — [`ComponentStore::decay_sps`];
//!   Strict blocks apply `decay^B` once at block start, TopC blocks
//!   decay per replayed point). Old evidence decays exponentially, so
//!   components stranded by a mean shift lose their priors, and the
//!   §2.3 `v > v_min && sp < sp_min` spuriousness gate compares an age
//!   and a mass measured over the *same* decayed time window instead
//!   of a lifetime count against decayed mass.
//! - **max-age eviction** — the learn path stamps the posterior-argmax
//!   winner of every point ([`ComponentStore::set_stamp`]); the prune
//!   sweep additionally evicts components that have not won a point in
//!   `max_age` points ([`ComponentStore::prune_aged`]).
//!
//! Both knobs default off (`decay = 1.0`, `max_age = 0`) and add no
//! floating-point work when off, preserving the default path's
//! bit-identity contract.

use super::store::ComponentStore;
use super::{log_gaussian, softmax_posteriors, GmmConfig, LearnOutcome};
use crate::engine::{worth_sharding, worth_sharding_batch, SharedMut, WorkerPool};
use crate::linalg::rank_one::figmn_fused_update_packed_mode;
use crate::linalg::{norm2, packed, sub_into, KernelMode};

/// Cap on live `K·B·D` w-slots in the blocked learn path: mini-batch
/// blocks are clamped to `LEARN_BLOCK_SLOTS / (K·D)` points so the
/// frozen `w` tile stays bounded (16 MiB of f64) no matter how large a
/// block the caller or the coalescing server driver hands over.
pub(crate) const LEARN_BLOCK_SLOTS: usize = 1 << 21;

/// How the write path consumes the stream (per model;
/// `GmmConfig::learn_mode`). Carried in checkpoints and selectable over
/// the coordinator protocol and the CLI
/// (`train --learn-mode online|minibatch:B`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LearnMode {
    /// One point per step — bit-identical to the pre-pipeline learn
    /// path at every thread count.
    #[default]
    Online,
    /// Stage `b`-point blocks through the batched distance pass (see
    /// the module docs for the freeze semantics). `b = 1` is
    /// bit-identical to [`LearnMode::Online`].
    MiniBatch {
        /// Block length in points (≥ 1).
        b: usize,
    },
}

impl LearnMode {
    /// Wire/CLI form: `"online"` or `"minibatch:B"`.
    pub fn to_wire(&self) -> String {
        match self {
            LearnMode::Online => "online".to_string(),
            LearnMode::MiniBatch { b } => format!("minibatch:{b}"),
        }
    }

    /// Parse a wire/CLI form; `None` for anything unknown (including
    /// `minibatch:0` — an empty block is meaningless).
    pub fn parse(s: &str) -> Option<LearnMode> {
        if s == "online" {
            return Some(LearnMode::Online);
        }
        let b: usize = s.strip_prefix("minibatch:")?.parse().ok()?;
        (b > 0).then_some(LearnMode::MiniBatch { b })
    }

    /// Block length this mode stages (`1` for online).
    pub fn block_len(&self) -> usize {
        match self {
            LearnMode::Online => 1,
            LearnMode::MiniBatch { b } => (*b).max(1),
        }
    }
}

impl std::fmt::Display for LearnMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_wire())
    }
}

/// Reusable scratch for the blocked learn path (one per model, like
/// `Figmn`'s per-point `buf_*` fields): after warm-up, a mini-batch
/// block allocates nothing.
#[derive(Default)]
pub(crate) struct BlockScratch {
    /// Frozen squared Mahalanobis distances, `K×B` component-major
    /// (`d2[j·B + bi]`).
    pub(crate) d2: Vec<f64>,
    /// Frozen `w = Λ·e` vectors, `K×B×D` (`ws[(j·B + bi)·D ..]`).
    pub(crate) ws: Vec<f64>,
    /// `B×D` residual tile (serial stage 1) / per-point kernel scratch
    /// (stage 2's fresh-component checks).
    pub(crate) es: Vec<f64>,
    /// Per-point log-likelihood scratch (`K`), stage 2.
    pub(crate) ll: Vec<f64>,
    /// Frozen posteriors of accepted points, `K×B` component-major.
    pub(crate) post: Vec<f64>,
    /// Points accepted against the frozen scores (ascending `bi`).
    pub(crate) accepted: Vec<u32>,
    /// Components created earlier in the current block.
    pub(crate) fresh: Vec<u32>,
}

/// Index of the largest element (ties → lowest index). Used to pick the
/// posterior-argmax winner a learned point re-stamps.
pub(crate) fn argmax(xs: &[f64]) -> usize {
    let mut best = 0usize;
    let mut bv = xs[0];
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Append a σ_ini-shaped component at `x` (Eq. 13): diagonal precision
/// `1/σ_ini²` and the matching `log|C| = Σ ln σ_ini²`. The shared create
/// body of the online and blocked paths.
pub(crate) fn init_component(store: &mut ComponentStore, x: &[f64], sigma_ini: &[f64], d: usize) {
    let mut lambda = vec![0.0; store.mat_len()];
    let mut log_det = 0.0;
    for i in 0..d {
        let s2 = sigma_ini[i] * sigma_ini[i];
        lambda[packed::row_start(i, d)] = 1.0 / s2;
        log_det += s2.ln();
    }
    store.push(x, &lambda, log_det, 1.0, 1);
}

/// Stage 1 (online): squared Mahalanobis distances to every component
/// (Eq. 22), saving each component's `w = Λ·e` for the fused update.
/// Free function so the caller can split `Figmn`'s field borrows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn distance_pass(
    store: &ComponentStore,
    x: &[f64],
    d: usize,
    buf_d2: &mut [f64],
    buf_ws: &mut [f64],
    buf_e: &mut [f64],
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let k = store.len();
    match pool {
        Some(pool) if worth_sharding(k, d, pool.threads()) => {
            let d2 = SharedMut::new(buf_d2.as_mut_ptr());
            let ws = SharedMut::new(buf_ws.as_mut_ptr());
            pool.run(k, &move |_, range, scratch| {
                scratch.ensure(d);
                for j in range {
                    let e = &mut scratch.e[..d];
                    sub_into(x, store.mean(j), e);
                    // Safety: slot j / row j are owned by this shard only.
                    unsafe {
                        *d2.at(j) = packed::quad_form_with_mode(
                            store.mat(j),
                            d,
                            e,
                            ws.slice(j * d, d),
                            mode,
                        );
                    }
                }
            });
        }
        _ => {
            let e = &mut buf_e[..d];
            for (j, slot) in buf_d2.iter_mut().enumerate() {
                sub_into(x, store.mean(j), e);
                *slot = packed::quad_form_with_mode(
                    store.mat(j),
                    d,
                    e,
                    &mut buf_ws[j * d..(j + 1) * d],
                    mode,
                );
            }
        }
    }
}

/// Stage 1 (blocked): the `K×B` tile variant. Per component the
/// residual block `e_bi = x_bi − μ_j` is built once and the packed row
/// is streamed **once for the whole block** through
/// [`packed::quad_form_with_multi_mode`] — whose per-query results are
/// bit-identical to the per-point kernel of the same mode, so a block's
/// frozen scores equal B per-point distance passes against the same
/// frozen store.
#[allow(clippy::too_many_arguments)]
pub(crate) fn block_distance_pass(
    store: &ComponentStore,
    xs: &[Vec<f64>],
    d: usize,
    buf_d2: &mut [f64],
    buf_ws: &mut [f64],
    buf_es: &mut Vec<f64>,
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let k = store.len();
    let b = xs.len();
    match pool {
        Some(pool) if worth_sharding_batch(b, k, d, pool.threads()) => {
            let d2 = SharedMut::new(buf_d2.as_mut_ptr());
            let ws = SharedMut::new(buf_ws.as_mut_ptr());
            pool.run(k, &move |_, range, scratch| {
                for j in range {
                    let (es, _, _) = scratch.split3(b * d, 0, 0);
                    let mean = store.mean(j);
                    for (bi, x) in xs.iter().enumerate() {
                        sub_into(x, mean, &mut es[bi * d..(bi + 1) * d]);
                    }
                    // Safety: row j of the d2/ws tiles is owned by this
                    // shard only.
                    unsafe {
                        packed::quad_form_with_multi_mode(
                            store.mat(j),
                            d,
                            es,
                            b,
                            ws.slice(j * b * d, b * d),
                            d2.slice(j * b, b),
                            mode,
                        );
                    }
                }
            });
        }
        _ => {
            buf_es.resize(b * d, 0.0);
            for j in 0..k {
                let mean = store.mean(j);
                for (bi, x) in xs.iter().enumerate() {
                    sub_into(x, mean, &mut buf_es[bi * d..(bi + 1) * d]);
                }
                packed::quad_form_with_multi_mode(
                    store.mat(j),
                    d,
                    buf_es,
                    b,
                    &mut buf_ws[j * b * d..(j + 1) * b * d],
                    &mut buf_d2[j * b..(j + 1) * b],
                    mode,
                );
            }
        }
    }
}

/// Frozen per-point candidate tile of one TopC mini-batch block (see
/// the module docs' union/mask contract). Entries are laid out
/// point-major: point `bi`'s candidates occupy the flat slots
/// `offs[bi]..offs[bi+1]`, ascending by component row — the same order
/// the per-point candidate pass produces — with parallel `d2`/`en`
/// arrays and a `total×D` `ws` tile.
///
/// The replay stage consumes an entry only while it provably equals
/// what the per-point pass would compute *now*:
/// - a row updated with `p > 0` by an earlier in-block point is marked
///   [`TopcBlockTile::mark_dirty`] (its mean/Λ changed; `sp`/`v`-only
///   updates don't affect `d2`/`en`/`ws`);
/// - a mid-block prune renumbers arbitrary rows, so it
///   [`TopcBlockTile::invalidate`]s the whole tile;
/// - rows created mid-block are never present (the tile only knows
///   block-start rows), so their lookups miss naturally.
pub(crate) struct TopcBlockTile {
    d: usize,
    /// Flat per-point candidate rows (ascending within each point).
    cands: Vec<u32>,
    /// Point `bi`'s span in `cands` is `offs[bi]..offs[bi+1]`.
    offs: Vec<usize>,
    d2: Vec<f64>,
    en: Vec<f64>,
    /// `total×D` frozen `w = Λ·e` rows, parallel to `cands`.
    ws: Vec<f64>,
    /// Block-start rows touched by an in-block `p > 0` update.
    dirty: Vec<bool>,
    valid: bool,
    /// Union rows the masked kernel streamed (counter feed).
    pub(crate) rows: usize,
}

impl TopcBlockTile {
    /// The frozen `(d2, en, w)` of `(point bi, component j)`, or `None`
    /// when the entry is absent or no longer equal to a live compute.
    pub(crate) fn lookup(&self, bi: usize, j: u32) -> Option<(f64, f64, &[f64])> {
        if !self.valid || (j as usize) < self.dirty.len() && self.dirty[j as usize] {
            return None;
        }
        let span = &self.cands[self.offs[bi]..self.offs[bi + 1]];
        let p = span.binary_search(&j).ok()?;
        let slot = self.offs[bi] + p;
        Some((self.d2[slot], self.en[slot], &self.ws[slot * self.d..(slot + 1) * self.d]))
    }

    /// Mark block-start row `j` as mutated (mean/Λ changed): its frozen
    /// entries are stale for every later point. Rows created mid-block
    /// (`j ≥` block-start K) are not tracked — they are never in the
    /// tile.
    pub(crate) fn mark_dirty(&mut self, j: u32) {
        if let Some(slot) = self.dirty.get_mut(j as usize) {
            *slot = true;
        }
    }

    /// Drop every frozen entry: a prune renumbered the arena rows, so
    /// no tile entry can be matched to a live row anymore.
    pub(crate) fn invalidate(&mut self) {
        self.valid = false;
    }
}

/// Stage 1 (blocked, TopC): the masked union-row variant of
/// [`block_distance_pass`]. `cands`/`offs` hold each point's top-C
/// candidate set against the block-start store (ascending rows per
/// point). Per **union** row the residuals of only the masking points
/// are gathered into a compact tile and the packed row is streamed
/// once through [`packed::quad_form_with_multi_mode`]; results scatter
/// back to the point-major tile slots. Engine-sharded over the union
/// rows; each `(point, row)` result is bit-identical to the per-point
/// candidate pass (per-query kernel identity + scatter slots are
/// disjoint across rows).
pub(crate) fn topc_block_pass(
    store: &ComponentStore,
    xs: &[Vec<f64>],
    d: usize,
    cands: Vec<u32>,
    offs: Vec<usize>,
    scr: &mut BlockScratch,
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) -> TopcBlockTile {
    let b = xs.len();
    let total = cands.len();
    debug_assert_eq!(offs.len(), b + 1);

    // Union CSR: (row, point, flat slot) triples sorted by (row, point)
    // — deterministic, and grouping by row gives each union row its
    // masking-point list in ascending point order.
    let mut trips: Vec<(u32, u32, u32)> = Vec::with_capacity(total);
    for bi in 0..b {
        for (i, &j) in cands[offs[bi]..offs[bi + 1]].iter().enumerate() {
            trips.push((j, bi as u32, (offs[bi] + i) as u32));
        }
    }
    trips.sort_unstable();
    let mut row_off: Vec<usize> = Vec::new();
    for (t, &(j, ..)) in trips.iter().enumerate() {
        if t == 0 || trips[t - 1].0 != j {
            row_off.push(t);
        }
    }
    let rows = row_off.len();
    row_off.push(total);

    let mut d2 = vec![0.0; total];
    let mut en = vec![0.0; total];
    let mut ws = vec![0.0; total * d];
    let m_avg = if rows > 0 { (total + rows - 1) / rows } else { 0 };
    match pool {
        Some(pool) if rows > 0 && worth_sharding_batch(m_avg, rows, d, pool.threads()) => {
            let d2p = SharedMut::new(d2.as_mut_ptr());
            let enp = SharedMut::new(en.as_mut_ptr());
            let wsp = SharedMut::new(ws.as_mut_ptr());
            let trips = &trips;
            let row_off = &row_off;
            pool.run(rows, &move |_, range, scratch| {
                for r in range {
                    let span = &trips[row_off[r]..row_off[r + 1]];
                    let j = span[0].0 as usize;
                    let m = span.len();
                    let (es, tws, td2) = scratch.split3(b * d, b * d, b);
                    let mean = store.mean(j);
                    for (t, &(_, bi, _)) in span.iter().enumerate() {
                        sub_into(&xs[bi as usize], mean, &mut es[t * d..(t + 1) * d]);
                    }
                    packed::quad_form_with_multi_mode(
                        store.mat(j),
                        d,
                        &es[..m * d],
                        m,
                        &mut tws[..m * d],
                        &mut td2[..m],
                        mode,
                    );
                    for (t, &(_, _, slot)) in span.iter().enumerate() {
                        let s = slot as usize;
                        // Safety: flat slot s belongs to exactly one
                        // (point, row) pair, and row j is owned by this
                        // shard only.
                        unsafe {
                            *d2p.at(s) = td2[t];
                            *enp.at(s) = norm2(&es[t * d..(t + 1) * d]).sqrt();
                            wsp.slice(s * d, d).copy_from_slice(&tws[t * d..(t + 1) * d]);
                        }
                    }
                }
            });
        }
        _ => {
            scr.es.resize(b * d, 0.0);
            scr.ll.resize(b * d + b, 0.0);
            let (tws, td2) = scr.ll.split_at_mut(b * d);
            for r in 0..rows {
                let span = &trips[row_off[r]..row_off[r + 1]];
                let j = span[0].0 as usize;
                let m = span.len();
                let mean = store.mean(j);
                for (t, &(_, bi, _)) in span.iter().enumerate() {
                    sub_into(&xs[bi as usize], mean, &mut scr.es[t * d..(t + 1) * d]);
                }
                packed::quad_form_with_multi_mode(
                    store.mat(j),
                    d,
                    &scr.es[..m * d],
                    m,
                    &mut tws[..m * d],
                    &mut td2[..m],
                    mode,
                );
                for (t, &(_, _, slot)) in span.iter().enumerate() {
                    let s = slot as usize;
                    d2[s] = td2[t];
                    en[s] = norm2(&scr.es[t * d..(t + 1) * d]).sqrt();
                    ws[s * d..(s + 1) * d].copy_from_slice(&tws[t * d..(t + 1) * d]);
                }
            }
        }
    }

    TopcBlockTile {
        d,
        cands,
        offs,
        d2,
        en,
        ws,
        dirty: vec![false; store.len()],
        valid: true,
        rows,
    }
}

/// Stage 3 (online): apply Eqs. 4–9 and the fused rank-two update to
/// every component given its posterior. Component-local, so it shards
/// exactly like the distance pass — each worker streams the contiguous
/// arena rows of its component range.
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_pass(
    store: &mut ComponentStore,
    x: &[f64],
    d: usize,
    post: &[f64],
    buf_d2: &[f64],
    buf_ws: &[f64],
    buf_e: &mut [f64],
    sigma_ini: &[f64],
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let k = store.len();
    match pool {
        Some(pool) if worth_sharding(k, d, pool.threads()) => {
            let raw = store.raw_mut();
            pool.run(k, &move |_, range, scratch| {
                scratch.ensure(d);
                for j in range {
                    // Safety: arena row j is owned by exactly one shard.
                    let (mean, lambda, log_det, sp, v) = unsafe { raw.row_mut(j) };
                    update_component(
                        mean,
                        lambda,
                        log_det,
                        sp,
                        v,
                        x,
                        d,
                        post[j],
                        buf_d2[j],
                        &buf_ws[j * d..(j + 1) * d],
                        sigma_ini,
                        mode,
                        &mut scratch.e[..d],
                    );
                }
            });
        }
        _ => {
            for j in 0..k {
                let (mean, lambda, log_det, sp, v) = store.row_mut(j);
                update_component(
                    mean,
                    lambda,
                    log_det,
                    sp,
                    v,
                    x,
                    d,
                    post[j],
                    buf_d2[j],
                    &buf_ws[j * d..(j + 1) * d],
                    sigma_ini,
                    mode,
                    &mut buf_e[..d],
                );
            }
        }
    }
}

/// Stage 3 (blocked): apply every frozen-accepted point of the block to
/// the `k` components that existed at block start, **component-outer**:
/// each worker streams its packed rows once per block, applying the
/// block's points in ascending point order. Because a row's update
/// reads only that row plus the frozen `post`/`d2`/`w` tiles, the
/// component-outer order is bit-identical to the point-outer order the
/// online path would use with the same frozen inputs — and therefore
/// bit-deterministic across thread counts. Rows `≥ k` (components
/// created by stage 2 inside this block) are left untouched: their
/// points were assigned exactly at creation/fresh-assignment time.
#[allow(clippy::too_many_arguments)]
pub(crate) fn block_update_pass(
    store: &mut ComponentStore,
    xs: &[Vec<f64>],
    d: usize,
    k: usize,
    accepted: &[u32],
    post: &[f64],
    buf_d2: &[f64],
    buf_ws: &[f64],
    buf_e: &mut [f64],
    sigma_ini: &[f64],
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let b = xs.len();
    match pool {
        Some(pool) if worth_sharding_batch(accepted.len(), k, d, pool.threads()) => {
            let raw = store.raw_mut();
            pool.run(k, &move |_, range, scratch| {
                scratch.ensure(d);
                for j in range {
                    // Safety: arena row j is owned by exactly one shard.
                    let (mean, lambda, log_det, sp, v) = unsafe { raw.row_mut(j) };
                    for &bi in accepted {
                        let bi = bi as usize;
                        let s = (j * b + bi) * d;
                        update_component(
                            mean,
                            lambda,
                            log_det,
                            sp,
                            v,
                            &xs[bi],
                            d,
                            post[j * b + bi],
                            buf_d2[j * b + bi],
                            &buf_ws[s..s + d],
                            sigma_ini,
                            mode,
                            &mut scratch.e[..d],
                        );
                    }
                }
            });
        }
        _ => {
            for j in 0..k {
                let (mean, lambda, log_det, sp, v) = store.row_mut(j);
                for &bi in accepted {
                    let bi = bi as usize;
                    let s = (j * b + bi) * d;
                    update_component(
                        mean,
                        lambda,
                        log_det,
                        sp,
                        v,
                        &xs[bi],
                        d,
                        post[j * b + bi],
                        buf_d2[j * b + bi],
                        &buf_ws[s..s + d],
                        sigma_ini,
                        mode,
                        &mut buf_e[..d],
                    );
                }
            }
        }
    }
}

/// The component-local body shared by the serial and sharded update
/// paths — one instruction sequence, so the two are bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_component(
    mean: &mut [f64],
    lambda: &mut [f64],
    log_det: &mut f64,
    sp: &mut f64,
    v: &mut u64,
    x: &[f64],
    d: usize,
    p: f64,
    d2j: f64,
    w: &[f64],
    sigma_ini: &[f64],
    mode: KernelMode,
    e: &mut [f64],
) {
    *v += 1; // Eq. 4
    *sp += p; // Eq. 5
    let omega = p / *sp; // Eq. 7 (with the *updated* sp)
    if omega <= 0.0 {
        // ω = 0: Eqs. 8–11 are exact no-ops; skip the O(D²) work.
        return;
    }
    sub_into(x, mean, e); // Eq. 6
    for (m, &ei) in mean.iter_mut().zip(e.iter()) {
        *m += omega * ei; // Eqs. 8–9
    }
    // Fused rank-one form of Eqs. 20–21/25–26 (exact old-mean Eq. 11 —
    // DESIGN.md §Deviations; single-pass rewrite — EXPERIMENTS.md §Perf
    // L3-1), reusing w/q from the distance pass, on the packed row.
    match figmn_fused_update_packed_mode(lambda, d, w, d2j, omega, *log_det, mode) {
        Some(r) => *log_det = r.log_det,
        None => {
            // Float underflow destroyed positive-definiteness (reachable
            // only at extreme conditioning). Reset the component's shape
            // to σ_ini around its current mean. Multiply-by-zero, not
            // fill: the dense path's `scale_in_place(0.0)` preserves
            // the sign of zeros (−x·0.0 = −0.0), and the bit-identity
            // contract covers even this branch.
            for v in lambda.iter_mut() {
                *v *= 0.0;
            }
            let mut ld = 0.0;
            for i in 0..d {
                let s2 = sigma_ini[i] * sigma_ini[i];
                lambda[packed::row_start(i, d)] = 1.0 / s2;
                ld += s2.ln();
            }
            *log_det = ld;
        }
    }
}

/// Candidate-set variant of the distance pass: Mahalanobis distances
/// and `w = Λ·e` for the `cands` components only, plus each candidate's
/// Euclidean mean distance (index drift bookkeeping). With an engine
/// attached the *candidate positions* are sharded — the per-shard
/// candidate intersection of the engine docs — with merges unchanged.
#[allow(clippy::too_many_arguments)]
pub(crate) fn candidate_distance_pass(
    store: &ComponentStore,
    x: &[f64],
    d: usize,
    cands: &[u32],
    buf_d2: &mut [f64],
    buf_ws: &mut [f64],
    buf_en: &mut [f64],
    buf_e: &mut [f64],
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let cn = cands.len();
    match pool {
        Some(pool) if worth_sharding(cn, d, pool.threads()) => {
            let d2 = SharedMut::new(buf_d2.as_mut_ptr());
            let ws = SharedMut::new(buf_ws.as_mut_ptr());
            let en = SharedMut::new(buf_en.as_mut_ptr());
            pool.run(cn, &move |_, range, scratch| {
                scratch.ensure(d);
                for i in range {
                    let j = cands[i] as usize;
                    let e = &mut scratch.e[..d];
                    sub_into(x, store.mean(j), e);
                    // Safety: slot i is owned by exactly one shard.
                    unsafe {
                        *en.at(i) = norm2(e).sqrt();
                        *d2.at(i) = packed::quad_form_with_mode(
                            store.mat(j),
                            d,
                            e,
                            ws.slice(i * d, d),
                            mode,
                        );
                    }
                }
            });
        }
        _ => {
            let e = &mut buf_e[..d];
            for (i, &jc) in cands.iter().enumerate() {
                let j = jc as usize;
                sub_into(x, store.mean(j), e);
                buf_en[i] = norm2(e).sqrt();
                buf_d2[i] = packed::quad_form_with_mode(
                    store.mat(j),
                    d,
                    e,
                    &mut buf_ws[i * d..(i + 1) * d],
                    mode,
                );
            }
        }
    }
}

/// Candidate-set variant of the update pass: Eqs. 4–9 plus the fused
/// rank-two update for the `cands` components only. Candidate indices
/// are unique, so sharding the candidate positions gives each worker
/// exclusive ownership of its arena rows — same safety argument as the
/// full pass.
#[allow(clippy::too_many_arguments)]
pub(crate) fn candidate_update_pass(
    store: &mut ComponentStore,
    x: &[f64],
    d: usize,
    post: &[f64],
    cands: &[u32],
    buf_d2: &[f64],
    buf_ws: &[f64],
    buf_e: &mut [f64],
    sigma_ini: &[f64],
    mode: KernelMode,
    pool: Option<&WorkerPool>,
) {
    let cn = cands.len();
    match pool {
        Some(pool) if worth_sharding(cn, d, pool.threads()) => {
            let raw = store.raw_mut();
            pool.run(cn, &move |_, range, scratch| {
                scratch.ensure(d);
                for i in range {
                    let j = cands[i] as usize;
                    // Safety: candidate indices are unique, so arena row
                    // j is owned by exactly one shard position.
                    let (mean, lambda, log_det, sp, v) = unsafe { raw.row_mut(j) };
                    update_component(
                        mean,
                        lambda,
                        log_det,
                        sp,
                        v,
                        x,
                        d,
                        post[i],
                        buf_d2[i],
                        &buf_ws[i * d..(i + 1) * d],
                        sigma_ini,
                        mode,
                        &mut scratch.e[..d],
                    );
                }
            });
        }
        _ => {
            for (i, &jc) in cands.iter().enumerate() {
                let (mean, lambda, log_det, sp, v) = store.row_mut(jc as usize);
                update_component(
                    mean,
                    lambda,
                    log_det,
                    sp,
                    v,
                    x,
                    d,
                    post[i],
                    buf_d2[i],
                    &buf_ws[i * d..(i + 1) * d],
                    sigma_ini,
                    mode,
                    &mut buf_e[..d],
                );
            }
        }
    }
}

/// Learn one mini-batch block through the three stages (see the module
/// docs). Requires `xs.len() ≥ 2` (length-1 blocks route through the
/// online bodies) and a non-empty store in [`SearchMode::Strict`]; the
/// caller (`Figmn::learn_chunk`) guarantees both plus the
/// [`LEARN_BLOCK_SLOTS`] memory clamp, and runs the prune sweep after
/// the block. `points_base` is the stream position before this block;
/// point `bi` is stream position `points_base + bi + 1` for stamping.
/// Appends one [`LearnOutcome`] per point to `out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn learn_block(
    store: &mut ComponentStore,
    xs: &[Vec<f64>],
    cfg: &GmmConfig,
    sigma_ini: &[f64],
    pool: Option<&WorkerPool>,
    scr: &mut BlockScratch,
    points_base: u64,
    out: &mut Vec<LearnOutcome>,
) {
    let b = xs.len();
    let k = store.len();
    let d = cfg.dim;
    let mode = cfg.kernel_mode;
    let chi2 = cfg.chi2_threshold();
    debug_assert!(b >= 2, "learn_block: length-1 blocks take the online path");
    debug_assert!(k >= 1, "learn_block: empty store");

    // ---- Stage 1: frozen K×B distance/score tiles ----
    scr.d2.resize(k * b, 0.0);
    scr.ws.resize(k * b * d, 0.0);
    block_distance_pass(store, xs, d, &mut scr.d2, &mut scr.ws, &mut scr.es, mode, pool);
    // Stage 2's fresh-component checks need a (e, w) pair of per-point
    // kernel scratch; the stage-1 residual tile is dead now (b ≥ 2 so
    // it holds at least 2·D floats) and is reused for both.
    scr.es.resize((b * d).max(2 * d), 0.0);

    // ---- Stage 2: sequential per-point novelty/assignment decisions ----
    // Original-K scalars (sp, log_det) are untouched until stage 3, so
    // reading them live *is* reading the frozen block state.
    scr.post.resize(k * b, 0.0);
    scr.accepted.clear();
    scr.fresh.clear();
    for (bi, x) in xs.iter().enumerate() {
        let t = points_base + bi as u64 + 1;
        let novel = !scr.d2[..k * b]
            .iter()
            .skip(bi)
            .step_by(b)
            .any(|&d2| d2 < chi2);
        let cap_full = cfg.max_components > 0 && store.len() >= cfg.max_components;
        if !novel || cap_full {
            // Accepted against the frozen scores: posterior assignment
            // over the k block-start components (Eqs. 2–3, log space).
            scr.ll.clear();
            for j in 0..k {
                scr.ll.push(log_gaussian(scr.d2[j * b + bi], store.log_det(j), d));
            }
            let post = softmax_posteriors(&scr.ll, &store.sps()[..k]);
            if cfg.max_age > 0 {
                store.set_stamp(argmax(&post), t);
            }
            for (j, &p) in post.iter().enumerate() {
                scr.post[j * b + bi] = p;
            }
            scr.accepted.push(bi as u32);
            out.push(LearnOutcome::Updated);
            continue;
        }
        // Novel against the frozen scores: decide sequentially against
        // the components created earlier in this block (exact per-point
        // kernels) so near-duplicate novel points share one component.
        let (e, w) = scr.es.split_at_mut(d);
        let e = &mut e[..d];
        let w = &mut w[..d];
        let mut nearest: Option<(usize, f64)> = None;
        for &fj in scr.fresh.iter() {
            let j = fj as usize;
            sub_into(x, store.mean(j), e);
            let d2f = packed::quad_form_with_mode(store.mat(j), d, e, w, mode);
            if d2f < chi2 && nearest.map_or(true, |(_, best)| d2f < best) {
                nearest = Some((j, d2f));
            }
        }
        if let Some((j, _)) = nearest {
            // Assign the whole point to its nearest in-block component
            // (p = 1); recompute e/w against that row's current state.
            sub_into(x, store.mean(j), e);
            let d2f = packed::quad_form_with_mode(store.mat(j), d, e, w, mode);
            let (mean, lambda, log_det, sp, v) = store.row_mut(j);
            update_component(
                mean, lambda, log_det, sp, v, x, d, 1.0, d2f, w, sigma_ini, mode, e,
            );
            store.set_stamp(j, t);
            out.push(LearnOutcome::Updated);
        } else {
            init_component(store, x, sigma_ini, d);
            let j = store.len() - 1;
            store.set_stamp(j, t);
            scr.fresh.push(j as u32);
            out.push(LearnOutcome::Created);
        }
    }

    // ---- Stage 3: component-outer fused updates over the original K ----
    if !scr.accepted.is_empty() {
        block_update_pass(
            store,
            xs,
            d,
            k,
            &scr.accepted,
            &scr.post,
            &scr.d2,
            &scr.ws,
            &mut scr.es,
            sigma_ini,
            mode,
            pool,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::store::ComponentStore;

    #[test]
    fn learn_mode_wire_round_trips_and_rejects() {
        assert_eq!(LearnMode::default(), LearnMode::Online);
        assert_eq!(LearnMode::Online.to_wire(), "online");
        assert_eq!(LearnMode::MiniBatch { b: 8 }.to_wire(), "minibatch:8");
        assert_eq!(LearnMode::parse("online"), Some(LearnMode::Online));
        assert_eq!(LearnMode::parse("minibatch:32"), Some(LearnMode::MiniBatch { b: 32 }));
        for bad in ["minibatch:0", "minibatch:", "minibatch:x", "batch:4", "turbo", ""] {
            assert_eq!(LearnMode::parse(bad), None, "{bad:?} must not parse");
        }
        assert_eq!(LearnMode::Online.block_len(), 1);
        assert_eq!(LearnMode::MiniBatch { b: 5 }.block_len(), 5);
        assert_eq!(format!("{}", LearnMode::MiniBatch { b: 2 }), "minibatch:2");
    }

    #[test]
    fn argmax_prefers_lowest_index_on_ties() {
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[0.25, 0.5, 0.5, 0.1]), 1);
        assert_eq!(argmax(&[0.1, 0.2, 0.7]), 2);
    }

    #[test]
    fn init_component_sets_sigma_ini_shape() {
        let d = 3;
        let mut store = ComponentStore::new(d);
        let sigma = [0.5, 2.0, 1.0];
        init_component(&mut store, &[1.0, -2.0, 3.0], &sigma, d);
        assert_eq!(store.len(), 1);
        assert_eq!(store.mean(0), &[1.0, -2.0, 3.0]);
        assert_eq!((store.sp(0), store.v(0)), (1.0, 1));
        let mut expect_ld = 0.0;
        for i in 0..d {
            let s2 = sigma[i] * sigma[i];
            assert_eq!(store.mat(0)[packed::row_start(i, d)], 1.0 / s2);
            expect_ld += s2.ln();
        }
        assert_eq!(store.log_det(0), expect_ld);
    }

    /// The blocked stage-1 tile must equal B per-point distance passes
    /// against the same frozen store — bit for bit, in both modes.
    #[test]
    fn block_distance_pass_matches_per_point_bitwise() {
        let d = 4;
        let k = 3;
        let b = 5;
        let mut store = ComponentStore::new(d);
        let mut seed = 41u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((seed >> 33) as f64) / ((1u64 << 31) as f64) - 0.5
        };
        for j in 0..k {
            let mean: Vec<f64> = (0..d).map(|_| next() * 4.0).collect();
            // Any symmetric matrix exercises the kernels; PD not needed.
            let mut mat: Vec<f64> = (0..packed::packed_len(d)).map(|_| next()).collect();
            for i in 0..d {
                mat[packed::row_start(i, d)] += 2.0 + j as f64;
            }
            store.push(&mean, &mat, 0.1, 1.0 + j as f64, 1);
        }
        let xs: Vec<Vec<f64>> = (0..b).map(|_| (0..d).map(|_| next() * 3.0).collect()).collect();
        for mode in [KernelMode::Strict, KernelMode::Fast] {
            let mut d2 = vec![0.0; k * b];
            let mut ws = vec![0.0; k * b * d];
            let mut es = Vec::new();
            block_distance_pass(&store, &xs, d, &mut d2, &mut ws, &mut es, mode, None);
            // Per-point oracle: the online stage-1 free function.
            for (bi, x) in xs.iter().enumerate() {
                let mut pd2 = vec![0.0; k];
                let mut pws = vec![0.0; k * d];
                let mut pe = vec![0.0; d];
                distance_pass(&store, x, d, &mut pd2, &mut pws, &mut pe, mode, None);
                for j in 0..k {
                    assert_eq!(
                        d2[j * b + bi].to_bits(),
                        pd2[j].to_bits(),
                        "d2 mismatch at j={j} bi={bi} ({mode:?})"
                    );
                    assert_eq!(
                        &ws[(j * b + bi) * d..(j * b + bi + 1) * d],
                        &pws[j * d..(j + 1) * d],
                        "w mismatch at j={j} bi={bi} ({mode:?})"
                    );
                }
            }
        }
    }

    /// Near-duplicate novel points inside one block must share a single
    /// created component instead of spawning one each.
    #[test]
    fn learn_block_dedups_in_block_creates() {
        let d = 2;
        let cfg = GmmConfig::new(d).with_delta(0.5).with_beta(0.1).without_pruning();
        let sigma = cfg.sigma_ini(&[1.0, 1.0]);
        let mut store = ComponentStore::new(d);
        init_component(&mut store, &[0.0, 0.0], &sigma, d);
        let mut scr = BlockScratch::default();
        let mut out = Vec::new();
        // Two far-away, nearly identical points in one block.
        let xs = vec![vec![50.0, 50.0], vec![50.01, 49.99]];
        learn_block(&mut store, &xs, &cfg, &sigma, None, &mut scr, 1, &mut out);
        assert_eq!(out, vec![LearnOutcome::Created, LearnOutcome::Updated]);
        assert_eq!(store.len(), 2, "second novel point must reuse the in-block create");
        // The fresh component absorbed both points.
        assert_eq!(store.v(1), 2);
        assert!((store.sp(1) - 2.0).abs() < 1e-12);
        // Both stream positions were stamped onto the fresh row.
        assert_eq!(store.stamp(1), 3);
    }

    /// Accepted points update every block-start component with frozen
    /// posteriors; totals match the online invariant Σsp = points.
    #[test]
    fn learn_block_accepted_points_preserve_mass() {
        let d = 2;
        let cfg = GmmConfig::new(d).with_delta(1.0).with_beta(0.05).without_pruning();
        let sigma = cfg.sigma_ini(&[1.0, 1.0]);
        let mut store = ComponentStore::new(d);
        init_component(&mut store, &[0.0, 0.0], &sigma, d);
        let mut scr = BlockScratch::default();
        let mut out = Vec::new();
        let xs = vec![vec![0.1, 0.0], vec![-0.1, 0.1], vec![0.0, -0.2]];
        learn_block(&mut store, &xs, &cfg, &sigma, None, &mut scr, 1, &mut out);
        assert_eq!(out, vec![LearnOutcome::Updated; 3]);
        assert_eq!(store.len(), 1);
        assert_eq!(store.v(0), 4, "one create + three accepted points");
        // Each accepted point contributes exactly 1 posterior mass.
        assert!((store.total_sp() - 4.0).abs() < 1e-9, "Σsp = {}", store.total_sp());
    }
}
