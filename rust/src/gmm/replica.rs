//! Compact f32 read replicas of the packed component arenas.
//!
//! The serving read path is memory-bandwidth-bound at large `D`: a
//! scoring sweep streams `K·D(D+1)/2` packed doubles at ~1 flop/byte,
//! so after the packed layout (PR 3) and query blocking (PR 5) the next
//! win is streaming *fewer bytes*, not fewer flops. A [`ReplicaStore`]
//! is an f32 copy of a snapshot's mean and packed-matrix arenas —
//! half the bytes per sweep again — built once at snapshot publish and
//! immutable thereafter (plain `Vec<f32>`, `Send + Sync`, no interior
//! mutability, no raw pointers). The write path never sees it: live
//! models stay f64, and `Strict`-mode bit-identity contracts are
//! untouched because replicas are opt-in per model.
//!
//! ## Tolerance contract
//!
//! [`ReplicaMode::F32 { tol }`](ReplicaMode::F32) declares the accepted
//! relative error of replica-served log-densities against the f64
//! snapshot path — a *contract* parameter, enforced by the property
//! tests and the `layout_bandwidth` bench gate rather than checked per
//! query (exactly how [`KernelMode::Fast`](crate::linalg::KernelMode)'s
//! ~1e-12 bound works). The f32 kernels' intrinsic error is
//! `O(√D · 2⁻²⁴)` relative (≈3e-6 at D = 3072; see
//! [`crate::linalg::packed`]), so the default tolerance
//! [`DEFAULT_F32_TOL`] = 1e-3 has orders of magnitude of headroom.
//! Replica scores are deterministic for a fixed detected
//! [`SimdTier`](crate::linalg::SimdTier); across hosts whose detected
//! tiers differ, bits may differ within the tolerance.
//!
//! Replicas serve the quadratic-form-bound density surfaces
//! (`log_density`, `score_batch`, `posteriors`, `posteriors_batch`).
//! Conditional inference (`predict*`, `class_scores*`) is
//! Cholesky-bound, not bandwidth-bound, and always runs the f64 path;
//! a frozen top-C candidate index likewise keeps its exact f64
//! per-candidate contract and takes precedence on the surfaces it
//! covers.

use super::log_gaussian;
use super::score_block::SCORE_BLOCK;
use super::store::ComponentStore;
use crate::linalg::packed;

/// Default tolerance for a bare `"f32"` replica-mode flag: three
/// decimal digits of relative accuracy on log-densities — loose enough
/// to be honest about f32 at any supported `D`, tight enough that
/// posterior argmaxes are unaffected in practice.
pub const DEFAULT_F32_TOL: f64 = 1e-3;

/// Whether (and how) a model's published snapshots carry a compact
/// read replica.
///
/// Wire/CLI format: `"off"`, `"f32"` (= [`DEFAULT_F32_TOL`]), or
/// `"f32:TOL"` with `TOL > 0` — following the `SearchMode` `"topc:C"`
/// convention.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ReplicaMode {
    /// No replica (the default): every read serves from the f64 arenas,
    /// byte-identical to the pre-replica read path.
    #[default]
    Off,
    /// Publish an f32 [`ReplicaStore`] with each snapshot and serve the
    /// density surfaces from it, accepting `tol` relative error against
    /// the f64 path (see the module docs for the contract).
    F32 {
        /// Accepted relative error on replica-served log-densities.
        tol: f64,
    },
}

impl ReplicaMode {
    /// `F32` at the default tolerance — what a bare `"f32"` flag means.
    pub fn f32_default() -> ReplicaMode {
        ReplicaMode::F32 { tol: DEFAULT_F32_TOL }
    }

    /// Whether snapshots publish a replica at all.
    pub fn is_on(&self) -> bool {
        matches!(self, ReplicaMode::F32 { .. })
    }

    /// The configured tolerance, if replicas are on.
    pub fn tol(&self) -> Option<f64> {
        match self {
            ReplicaMode::Off => None,
            ReplicaMode::F32 { tol } => Some(*tol),
        }
    }

    /// Parse a wire/CLI name; `None` for anything unknown (including
    /// non-positive or non-finite tolerances).
    pub fn parse(s: &str) -> Option<ReplicaMode> {
        match s {
            "off" => Some(ReplicaMode::Off),
            "f32" => Some(ReplicaMode::f32_default()),
            _ => s
                .strip_prefix("f32:")
                .and_then(|t| t.parse::<f64>().ok())
                .filter(|t| t.is_finite() && *t > 0.0)
                .map(|tol| ReplicaMode::F32 { tol }),
        }
    }

    /// Wire name that [`ReplicaMode::parse`] round-trips exactly (float
    /// `Display` prints the shortest round-tripping decimal).
    pub fn to_wire(&self) -> String {
        match self {
            ReplicaMode::Off => "off".to_string(),
            ReplicaMode::F32 { tol } => format!("f32:{tol}"),
        }
    }
}

impl std::fmt::Display for ReplicaMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_wire())
    }
}

/// f32 copy of a snapshot's mean and packed-matrix arenas — the data a
/// scoring sweep actually streams. `log_det`/`sp` stay on the f64
/// [`ComponentStore`] (O(K) scalars, not worth narrowing), so a replica
/// always rides beside its source store, never replaces it.
#[derive(Debug, Clone)]
pub struct ReplicaStore {
    dim: usize,
    tri: usize,
    k: usize,
    /// `K×D` f32 means, row per component.
    means: Vec<f32>,
    /// `K×D(D+1)/2` f32 packed upper triangles, row per component.
    mats: Vec<f32>,
}

impl ReplicaStore {
    /// Narrow the live arenas once — O(K·D²) straight-line conversion,
    /// run at snapshot publish (never on the request path).
    pub fn from_store(store: &ComponentStore) -> ReplicaStore {
        let k = store.len();
        let dim = store.dim();
        let tri = store.mat_len();
        let mut means = Vec::with_capacity(k * dim);
        let mut mats = Vec::with_capacity(k * tri);
        for j in 0..k {
            means.extend(store.mean(j).iter().map(|&v| v as f32));
            mats.extend(store.mat(j).iter().map(|&v| v as f32));
        }
        ReplicaStore { dim, tri, k, means, mats }
    }

    pub fn len(&self) -> usize {
        self.k
    }

    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Component `j`'s f32 mean row.
    pub fn mean32(&self, j: usize) -> &[f32] {
        &self.means[j * self.dim..(j + 1) * self.dim]
    }

    /// Component `j`'s f32 packed matrix row.
    pub fn mat32(&self, j: usize) -> &[f32] {
        &self.mats[j * self.tri..(j + 1) * self.tri]
    }

    /// Arena payload bytes this replica holds — exactly half the f64
    /// mean+matrix bytes it mirrors.
    pub fn replica_bytes(&self) -> usize {
        (self.means.len() + self.mats.len()) * std::mem::size_of::<f32>()
    }
}

/// Owned scratch for the replica block-scoring path — the f32 analog of
/// `score_block::ScoreBlock`. Queries are narrowed to f32 once per
/// block (not once per component), residuals and the `w = Λ·e` block
/// stay f32 end to end, and only the final per-query log-density terms
/// are f64.
pub(crate) struct ReplicaBlock {
    d: usize,
    /// Narrowed query block, `rows×d`.
    x32: Vec<f32>,
    /// Residual block, `rows×d`.
    e32: Vec<f32>,
    /// Kernel scratch (`w = Λ·e` per query), `rows×d`.
    w32: Vec<f32>,
    /// Per-query terms, widened to f64.
    q: Vec<f64>,
}

impl ReplicaBlock {
    pub(crate) fn new(d: usize, queries: usize) -> ReplicaBlock {
        let rows = queries.clamp(1, SCORE_BLOCK);
        ReplicaBlock {
            d,
            x32: vec![0.0; rows * d],
            e32: vec![0.0; rows * d],
            w32: vec![0.0; rows * d],
            q: vec![0.0; rows],
        }
    }

    /// Narrow a single query to f32 (row 0) — the per-point surfaces'
    /// loader.
    pub(crate) fn load_query(&mut self, x: &[f64]) {
        debug_assert_eq!(x.len(), self.d);
        for (o, &v) in self.x32[..self.d].iter_mut().zip(x.iter()) {
            *o = v as f32;
        }
    }

    /// Narrow the block's queries to f32 — once per block.
    pub(crate) fn load_queries(&mut self, xs: &[Vec<f64>]) {
        let d = self.d;
        debug_assert!(xs.len() * d <= self.x32.len());
        for (bi, x) in xs.iter().enumerate() {
            debug_assert_eq!(x.len(), d);
            for (o, &v) in self.x32[bi * d..(bi + 1) * d].iter_mut().zip(x.iter()) {
                *o = v as f32;
            }
        }
    }

    /// Per-component log-density terms for the loaded block:
    /// `terms[bi] = ln N(x_bi; μ_j, Λ_j) + offset`, with the residual
    /// and quadratic form in f32 and the `log_gaussian` assembly in f64
    /// (`log_det` is the store's f64 value). Call
    /// [`ReplicaBlock::load_queries`] first.
    pub(crate) fn component_terms(
        &mut self,
        rep: &ReplicaStore,
        j: usize,
        log_det: f64,
        b: usize,
        offset: f64,
    ) -> &[f64] {
        let d = self.d;
        debug_assert!(b * d <= self.x32.len());
        let mean = rep.mean32(j);
        for bi in 0..b {
            let x = &self.x32[bi * d..(bi + 1) * d];
            for ((e, &xv), &mv) in
                self.e32[bi * d..(bi + 1) * d].iter_mut().zip(x.iter()).zip(mean.iter())
            {
                *e = xv - mv;
            }
        }
        packed::quad_form_multi_f32(
            rep.mat32(j),
            d,
            &self.e32[..b * d],
            b,
            &mut self.w32[..b * d],
            &mut self.q[..b],
        );
        for t in self.q[..b].iter_mut() {
            *t = log_gaussian(*t, log_det, d) + offset;
        }
        &self.q[..b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::{Figmn, GmmConfig, IncrementalMixture};
    use crate::rng::Pcg64;

    #[test]
    fn replica_mode_parses_and_round_trips() {
        assert_eq!(ReplicaMode::parse("off"), Some(ReplicaMode::Off));
        assert_eq!(
            ReplicaMode::parse("f32"),
            Some(ReplicaMode::F32 { tol: DEFAULT_F32_TOL })
        );
        assert_eq!(
            ReplicaMode::parse("f32:0.01"),
            Some(ReplicaMode::F32 { tol: 0.01 })
        );
        assert_eq!(ReplicaMode::parse("f32:1e-4"), Some(ReplicaMode::F32 { tol: 1e-4 }));
        // Rejections: empty/zero/negative/non-finite tolerances and
        // unknown names.
        for bad in ["", "f32:", "f32:0", "f32:-1", "f32:nan", "f32:inf", "f16", "on", "F32"] {
            assert_eq!(ReplicaMode::parse(bad), None, "{bad:?} must not parse");
        }
        // `to_wire` round-trips exactly, default included.
        for mode in [
            ReplicaMode::Off,
            ReplicaMode::f32_default(),
            ReplicaMode::F32 { tol: 0.25 },
            ReplicaMode::F32 { tol: 1e-6 },
        ] {
            assert_eq!(ReplicaMode::parse(&mode.to_wire()), Some(mode), "{mode}");
        }
        assert_eq!(ReplicaMode::default(), ReplicaMode::Off);
        assert!(!ReplicaMode::Off.is_on());
        assert!(ReplicaMode::f32_default().is_on());
        assert_eq!(ReplicaMode::Off.tol(), None);
        assert_eq!(ReplicaMode::f32_default().tol(), Some(DEFAULT_F32_TOL));
        assert_eq!(ReplicaMode::Off.to_wire(), "off");
        assert_eq!(ReplicaMode::F32 { tol: 0.001 }.to_wire(), "f32:0.001");
    }

    fn trained_store() -> Figmn {
        let cfg = GmmConfig::new(4).with_delta(0.4).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[2.0; 4]);
        let mut rng = Pcg64::seed(31);
        for i in 0..120 {
            let c = (i % 3) as f64 * 8.0;
            let x: Vec<f64> = (0..4).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        m
    }

    #[test]
    fn replica_store_narrows_the_arenas() {
        let m = trained_store();
        let store = m.store();
        let rep = ReplicaStore::from_store(store);
        assert_eq!(rep.len(), store.len());
        assert_eq!(rep.dim(), store.dim());
        assert!(!rep.is_empty());
        for j in 0..store.len() {
            for (w, &v) in rep.mean32(j).iter().zip(store.mean(j).iter()) {
                assert_eq!(*w, v as f32, "mean[{j}]");
            }
            for (w, &v) in rep.mat32(j).iter().zip(store.mat(j).iter()) {
                assert_eq!(*w, v as f32, "mat[{j}]");
            }
        }
        // Exactly half the f64 mean+matrix payload.
        let f64_bytes = store.len() * (store.dim() + store.mat_len()) * 8;
        assert_eq!(rep.replica_bytes(), f64_bytes / 2);
    }

    #[test]
    fn replica_block_terms_match_f64_within_f32_tolerance() {
        let m = trained_store();
        let store = m.store();
        let rep = ReplicaStore::from_store(store);
        let d = store.dim();
        let mut rng = Pcg64::seed(33);
        let xs: Vec<Vec<f64>> =
            (0..7).map(|_| (0..d).map(|_| rng.normal() * 4.0).collect()).collect();
        let mut blk = ReplicaBlock::new(d, xs.len());
        blk.load_queries(&xs);
        let mut e = vec![0.0; d];
        for j in 0..store.len() {
            let terms =
                blk.component_terms(&rep, j, store.log_det(j), xs.len(), 0.25).to_vec();
            for (bi, x) in xs.iter().enumerate() {
                crate::linalg::sub_into(x, store.mean(j), &mut e);
                let expect = log_gaussian(
                    packed::quad_form(store.mat(j), d, &e),
                    store.log_det(j),
                    d,
                ) + 0.25;
                let tol = 1e-3 * (1.0 + expect.abs());
                assert!(
                    (terms[bi] - expect).abs() <= tol,
                    "j={j} q={bi}: f32 term {} vs f64 {expect}",
                    terms[bi]
                );
            }
        }
    }
}
