//! FIGMN — the paper's fast precision-matrix IGMN (§3).
//!
//! Per data point and component the work is: one `Λ·v` product for the
//! Mahalanobis distance (Eq. 22), and the fused rank-two Sherman–Morrison
//! update (Eqs. 20–21) with the determinant-lemma update (Eqs. 25–26) —
//! all `O(D²)`. No matrix is ever inverted or factorized on the learn
//! path.
//!
//! All component state lives in the flat arenas of a
//! [`super::ComponentStore`]: means in one `K×D` block, precisions in
//! one `K×D(D+1)/2` block of packed upper-triangular symmetric storage,
//! and `log|C|`/`sp`/`v` in parallel scalar arrays. The two hot kernels
//! ([`packed::quad_form_with`] and
//! [`crate::linalg::rank_one::figmn_fused_update_packed`]) sweep packed
//! rows — half the bytes of the dense layout — while, in the default
//! [`KernelMode::Strict`], performing the same floating-point
//! operations in the same order, so results are bit-identical to the
//! dense formulation (see `tests/layout_equivalence.rs`). A model
//! configured with [`KernelMode::Fast`]
//! (`GmmConfig::with_kernel_mode`) runs the blocked SIMD-friendly
//! variants of those kernels on its distance, scoring, and update
//! sweeps instead: tolerance-equivalent to `Strict` (see
//! [`KernelMode`]), still bit-deterministic across thread counts.
//! Conditional inference (`predict`/`predict_batch`) always runs the
//! strict kernels — its Cholesky-based Schur complement has no blocked
//! variant, and prediction traffic is not the per-point bottleneck the
//! paper attacks.
//!
//! Both passes are component-local, so when an engine is attached
//! ([`Figmn::with_engine`]) the K components are sharded across the
//! fixed thread pool of [`crate::engine::WorkerPool`]: each worker runs
//! the distance pass and the fused update over the contiguous arena
//! rows of its shard with its own scratch arena, and the O(K) posterior
//! merge runs serially through the deterministic tree reduction in
//! [`super::softmax_posteriors`]. Results are bit-identical to the
//! serial path for every thread count (see the crate-level determinism
//! guarantee).
//!
//! The batch scoring surfaces (`score_batch`/`predict_batch`)
//! additionally tile the query axis: queries are grouped into blocks of
//! [`super::score_block::SCORE_BLOCK`] and every packed component row
//! is streamed once per block through the multi-query kernels of
//! [`crate::linalg::packed`] — the `K×B` tiling that keeps the serving
//! read path off the memory wall at large `D`. Blocking never reorders
//! a query's own floating-point operations, so batch results stay
//! bit-identical to mapping the per-point entry points in both kernel
//! modes (`tests/blocked_scoring_equivalence.rs`).

use super::candidates::{CandidateIndex, IndexCounters, SearchMode};
use super::inference::{
    precision_conditional, precision_conditional_multi_with, target_block_cholesky,
};
use super::learn_pipeline::{
    argmax, candidate_distance_pass, candidate_update_pass, distance_pass, init_component,
    learn_block, topc_block_pass, update_pass, BlockScratch, LearnMode, TopcBlockTile,
    LEARN_BLOCK_SLOTS,
};
use super::score_block::{component_block_terms, wblock_len, ScoreBlock, SCORE_BLOCK};
use super::store::ComponentStore;
use super::{log_gaussian, softmax_posteriors, GmmConfig, IncrementalMixture, LearnOutcome};
use crate::engine::{
    logsumexp_tree, worth_sharding, worth_sharding_batch, EngineConfig, SharedMut, WorkerPool,
};
use crate::linalg::{norm2, packed, sub_into, Cholesky, KernelMode, Matrix};

/// Cap on live per-(point, component) slots in the batch scoring paths:
/// batches are processed in chunks of `BATCH_CHUNK_SLOTS / K` points so
/// peak memory stays O(chunk·K) instead of O(batch·K). Chunking only
/// regroups pool dispatches — per-point results are unchanged.
const BATCH_CHUNK_SLOTS: usize = 1 << 16;

/// The fast IGMN (paper §3). See [`crate::gmm`] for the shared semantics.
pub struct Figmn {
    cfg: GmmConfig,
    sigma_ini: Vec<f64>,
    /// All component state: means, packed precisions Λ = C⁻¹ (kept
    /// exactly symmetric by the update rules), log|C| (determinant of
    /// the *covariance*, as in the paper), sp (Eq. 5) and age v (Eq. 4).
    store: ComponentStore,
    points: u64,
    /// Optional component-sharded thread pool (None = serial).
    engine: Option<WorkerPool>,
    /// Coarse quantizer over the component means, maintained by the
    /// learn path when `cfg.search_mode` is [`SearchMode::TopC`]
    /// (`None` in strict mode and before the first component exists).
    /// Never serialized: a restored model rebuilds it deterministically
    /// from its arenas.
    index: Option<CandidateIndex>,
    // --- reusable scratch (learn() allocates nothing after warm-up) ---
    buf_e: Vec<f64>,
    buf_d2: Vec<f64>,
    /// Per-component `w = Λ·e` saved by the distance pass (K·D flat) and
    /// reused by the fused update — see rank_one::figmn_fused_update_packed.
    buf_ws: Vec<f64>,
    buf_ll: Vec<f64>,
    buf_sp: Vec<f64>,
    /// TopC learn scratch: the candidate set of the current point…
    buf_cand: Vec<u32>,
    /// …and each candidate's Euclidean mean distance `‖x − μ_j‖`
    /// (drift bookkeeping for the index).
    buf_en: Vec<f64>,
    /// Mini-batch block scratch (frozen K×B score/w tiles and the
    /// per-block decision state) — see [`super::learn_pipeline`].
    blk: BlockScratch,
    /// Candidate-machinery observability (rebuilds, incremental index
    /// maintenance, fallback-gate scans, masked union rows) —
    /// accumulated by the learn path, surfaced via
    /// [`IncrementalMixture::index_counters`].
    counters: IndexCounters,
}

impl Figmn {
    /// `dataset_stds`: per-dimension standard deviations for
    /// `σ_ini = δ·std(x)` (Eq. 13) — an estimate is fine (§2.2).
    pub fn new(cfg: GmmConfig, dataset_stds: &[f64]) -> Self {
        let sigma_ini = cfg.sigma_ini(dataset_stds);
        let d = cfg.dim;
        // Reserve the arenas up front when the component count is
        // bounded: create never reallocates (or moves) the hot rows
        // mid-stream, and the engine's raw row views stay at stable
        // bases for the model's whole life. The eager reservation is
        // budget-clamped (see `bounded_reservation_rows`) so a generous
        // cap at large D doesn't commit gigabytes for components that
        // may never exist.
        let store = if cfg.max_components > 0 {
            ComponentStore::with_capacity(
                d,
                ComponentStore::bounded_reservation_rows(d, cfg.max_components),
            )
        } else {
            ComponentStore::new(d)
        };
        Figmn {
            cfg,
            sigma_ini,
            store,
            points: 0,
            engine: None,
            index: None,
            buf_e: vec![0.0; d],
            buf_d2: Vec::new(),
            buf_ws: Vec::new(),
            buf_ll: Vec::new(),
            buf_sp: Vec::new(),
            buf_cand: Vec::new(),
            buf_en: Vec::new(),
            blk: BlockScratch::default(),
            counters: IndexCounters::default(),
        }
    }

    pub fn config(&self) -> &GmmConfig {
        &self.cfg
    }

    pub fn sigma_ini(&self) -> &[f64] {
        &self.sigma_ini
    }

    /// The flat component arenas backing this model.
    pub fn store(&self) -> &ComponentStore {
        &self.store
    }

    /// Mutable arena access (runtime state unpacking; not public API).
    pub(crate) fn store_mut(&mut self) -> &mut ComponentStore {
        &mut self.store
    }

    pub(crate) fn from_parts(
        cfg: GmmConfig,
        sigma_ini: Vec<f64>,
        mut store: ComponentStore,
        points: u64,
    ) -> Self {
        let d = cfg.dim;
        assert_eq!(store.dim(), d, "from_parts: store dim mismatch");
        let target = ComponentStore::bounded_reservation_rows(d, cfg.max_components);
        if target > store.len() {
            // Same (budget-clamped) reservation as `new`: restored
            // models get stable arena bases for the remaining headroom.
            store.reserve(target - store.len());
        }
        // Restored TopC models rebuild their candidate index up front
        // (deterministic: equal arenas always produce equal indexes, so
        // a checkpoint round-trip scores identically to the live model).
        let index = match cfg.search_mode {
            SearchMode::TopC { .. } if !store.is_empty() => Some(CandidateIndex::build(&store)),
            _ => None,
        };
        // Refresh stamps are runtime drift bookkeeping, not serialized
        // model state: restored survivors restart their eviction clocks
        // at the checkpoint's stream position.
        store.reset_stamps(points);
        Figmn {
            cfg,
            sigma_ini,
            store,
            points,
            engine: None,
            index,
            buf_e: vec![0.0; d],
            buf_d2: Vec::new(),
            buf_ws: Vec::new(),
            buf_ll: Vec::new(),
            buf_sp: Vec::new(),
            buf_cand: Vec::new(),
            buf_en: Vec::new(),
            blk: BlockScratch::default(),
            counters: IndexCounters::default(),
        }
    }

    /// Select the read-replica mode for snapshots this model publishes
    /// from here on (see [`super::ReplicaMode`]). Replicas are
    /// read-path-only derived state, so flipping the mode on a trained
    /// model is safe: the arenas, the write path, and all previously
    /// exported snapshots are untouched.
    pub fn with_replica_mode(mut self, mode: super::ReplicaMode) -> Self {
        self.cfg.replica_mode = mode;
        self
    }

    /// Attach a component-sharded execution engine: the K components are
    /// partitioned across a fixed pool of worker threads for the learn
    /// and scoring passes. Results are bit-identical to the serial path
    /// for every thread count (crate-level determinism guarantee).
    pub fn with_engine(mut self, cfg: EngineConfig) -> Self {
        self.set_engine(Some(cfg));
        self
    }

    /// Attach (`Some`) or detach (`None`) the engine at runtime. The
    /// model's state and all future results are unaffected — only where
    /// the arithmetic runs changes.
    pub fn set_engine(&mut self, cfg: Option<EngineConfig>) {
        self.engine = cfg.map(|c| WorkerPool::new(c.resolve_threads()));
    }

    /// Worker threads backing this model (1 when no engine is attached).
    pub fn engine_threads(&self) -> usize {
        self.engine.as_ref().map_or(1, |p| p.threads())
    }

    /// Export an immutable read-path snapshot of the current mixture
    /// (see [`super::ModelSnapshot`]): a bulk copy of the component
    /// arenas whose scoring is bit-identical to this model's serial
    /// path. The snapshot is a plain joint-density view;
    /// `SupervisedGmm::snapshot` records the feature/class split on top.
    pub fn snapshot(&self) -> super::ModelSnapshot {
        super::ModelSnapshot::new(
            self.cfg.clone(),
            self.store.clone(),
            self.points,
            self.cfg.dim,
            0,
        )
    }

    /// Mean of component `j` (exposed for tests/benches/tools).
    pub fn component_mean(&self, j: usize) -> &[f64] {
        self.store.mean(j)
    }

    /// `(sp_j, v_j)` bookkeeping of component `j`.
    pub fn component_stats(&self, j: usize) -> (f64, u64) {
        (self.store.sp(j), self.store.v(j))
    }

    /// Precision matrix of component `j`, expanded to dense form
    /// (tests/benches/interop; the arenas store it packed).
    pub fn component_lambda(&self, j: usize) -> Matrix {
        self.store.mat_dense(j)
    }

    /// `log|C_j|`.
    pub fn component_log_det(&self, j: usize) -> f64 {
        self.store.log_det(j)
    }

    /// Prior p(j) = sp_j / Σ sp (Eq. 12).
    pub fn prior(&self, j: usize) -> f64 {
        self.store.sp(j) / self.store.total_sp()
    }

    /// Arena bytes per component (packed layout; see
    /// [`ComponentStore::bytes_per_component`]).
    pub fn bytes_per_component(&self) -> usize {
        self.store.bytes_per_component()
    }

    /// Total arena payload of the live mixture.
    pub fn model_bytes(&self) -> usize {
        self.store.model_bytes()
    }

    fn create(&mut self, x: &[f64]) {
        init_component(&mut self.store, x, &self.sigma_ini, self.cfg.dim);
        // Fresh components start their eviction clock at the creating
        // point's stream position.
        self.store.set_stamp(self.store.len() - 1, self.points);
    }

    fn prune(&mut self) {
        let age = self.cfg.max_age > 0;
        if !self.cfg.prune && !age {
            return;
        }
        // The store's sweep is shared with Igmn, so both variants make
        // identical prune decisions, and the mixture can never empty
        // (§2.3 sweep keeps the strongest component when everything
        // trips the predicate).
        if age {
            // v_min = u64::MAX disables the spurious arm when §2.3
            // pruning is off and only age eviction is configured.
            let v_min = if self.cfg.prune { self.cfg.v_min } else { u64::MAX };
            self.store.prune_aged(v_min, self.cfg.sp_min, self.cfg.max_age, self.points);
        } else {
            self.store.prune(self.cfg.v_min, self.cfg.sp_min);
        }
        // Priors (Eq. 12) are derived from sp on demand; nothing else to
        // renormalize.
    }

    /// `ln p(x|j)` for every component, via the engine when attached.
    fn per_component_loglik(&self, x: &[f64]) -> Vec<f64> {
        let k = self.store.len();
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let mut ll = vec![0.0; k];
        match &self.engine {
            Some(pool) if worth_sharding(k, d, pool.threads()) => {
                let store = &self.store;
                let out = SharedMut::new(ll.as_mut_ptr());
                pool.run(k, &move |_, range, scratch| {
                    scratch.ensure(d);
                    for j in range {
                        let (e, tmp) = scratch.pair(d);
                        sub_into(x, store.mean(j), e);
                        // Safety: slot j is owned by exactly one shard.
                        unsafe {
                            *out.at(j) = log_gaussian(
                                packed::quad_form_scratch(store.mat(j), d, e, tmp, mode),
                                store.log_det(j),
                                d,
                            );
                        }
                    }
                });
            }
            _ => {
                let mut e = vec![0.0; d];
                // Kernel scratch is only read by the fast path.
                let mut tmp = vec![0.0; if mode == KernelMode::Fast { d } else { 0 }];
                for (j, slot) in ll.iter_mut().enumerate() {
                    sub_into(x, self.store.mean(j), &mut e);
                    *slot = log_gaussian(
                        packed::quad_form_scratch(self.store.mat(j), d, &e, &mut tmp, mode),
                        self.store.log_det(j),
                        d,
                    );
                }
            }
        }
        ll
    }

    /// The `(index, C)` pair when top-C search is active *and* the index
    /// is current for the store. Scoring surfaces fall back to the
    /// exact full-K sweep when this is `None` — which only happens in
    /// strict mode or on a TopC model before its first component/learn
    /// (the learn path keeps the index current from then on).
    fn active_index(&self) -> Option<(&CandidateIndex, usize)> {
        let c = self.cfg.search_mode.top_c()?;
        let idx = self.index.as_ref()?;
        idx.matches(&self.store).then_some((idx, c))
    }

    /// `ln p(x|j)` over the top-C candidate set of `x`, with the
    /// (ascending) candidate list. Every evaluated term is exact; the
    /// non-candidate tail is dropped ([`SearchMode::TopC`] contract).
    fn topc_loglik(&self, index: &CandidateIndex, x: &[f64], c: usize) -> (Vec<u32>, Vec<f64>) {
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let mut cands = Vec::new();
        index.query(x, c, &self.store, &mut cands);
        let mut e = vec![0.0; d];
        let mut tmp = vec![0.0; if mode == KernelMode::Fast { d } else { 0 }];
        let ll = cands
            .iter()
            .map(|&j| {
                let j = j as usize;
                sub_into(x, self.store.mean(j), &mut e);
                log_gaussian(
                    packed::quad_form_scratch(self.store.mat(j), d, &e, &mut tmp, mode),
                    self.store.log_det(j),
                    d,
                )
            })
            .collect();
        (cands, ll)
    }

    /// Top-C batch scoring: per query, candidate lookup + `O(C·D²)`
    /// exact terms + the deterministic tree reduction over the
    /// candidate set. With an engine attached the *query* axis is
    /// sharded — every point's own instruction sequence (index walk,
    /// term order, reduction shape) is untouched by sharding, so
    /// results are bit-identical across thread counts.
    fn score_batch_topc(&self, index: &CandidateIndex, c: usize, xs: &[Vec<f64>]) -> Vec<f64> {
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let store = &self.store;
        let total_sp = store.total_sp();
        let score_one = move |x: &[f64],
                              cands: &mut Vec<u32>,
                              terms: &mut Vec<f64>,
                              e: &mut [f64],
                              tmp: &mut [f64]|
              -> f64 {
            index.query(x, c, store, cands);
            terms.clear();
            for &j in cands.iter() {
                let j = j as usize;
                sub_into(x, store.mean(j), e);
                terms.push(
                    log_gaussian(
                        packed::quad_form_scratch(store.mat(j), d, e, tmp, mode),
                        store.log_det(j),
                        d,
                    ) + (store.sp(j) / total_sp).ln(),
                );
            }
            logsumexp_tree(terms)
        };
        let b = xs.len();
        let c_eff = c.min(store.len());
        match &self.engine {
            Some(pool) if worth_sharding_batch(b, c_eff, d, pool.threads()) => {
                let mut out = vec![0.0; b];
                let outp = SharedMut::new(out.as_mut_ptr());
                pool.run(b, &move |_, range, scratch| {
                    scratch.ensure(d);
                    let mut cands = Vec::new();
                    let mut terms = Vec::new();
                    for bi in range {
                        let (e, tmp) = scratch.pair(d);
                        // Safety: slot bi is owned by exactly one shard.
                        unsafe {
                            *outp.at(bi) = score_one(&xs[bi], &mut cands, &mut terms, e, tmp);
                        }
                    }
                });
                out
            }
            _ => {
                let mut cands = Vec::new();
                let mut terms = Vec::new();
                let mut e = vec![0.0; d];
                let mut tmp = vec![0.0; d];
                xs.iter().map(|x| score_one(x, &mut cands, &mut terms, &mut e, &mut tmp)).collect()
            }
        }
    }
}

impl Figmn {
    /// The pre-index full-K learn body — strict mode runs exactly this,
    /// so a strict model is bit-identical to every pre-index release.
    /// (`TopC` with `c ≥ K` reproduces these results bit-for-bit through
    /// the candidate path: the candidate set is all of `0..K` ascending,
    /// the same arithmetic in the same order.)
    fn learn_full(&mut self, x: &[f64]) -> LearnOutcome {
        let k = self.store.len();
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        self.buf_d2.resize(k, 0.0);
        self.buf_ws.resize(k * d, 0.0);
        {
            let Figmn { store, buf_d2, buf_ws, buf_e, engine, .. } = self;
            distance_pass(store, x, d, buf_d2, buf_ws, buf_e, mode, engine.as_ref());
        }
        let accept = self
            .buf_d2
            .iter()
            .any(|&d2| d2 < self.cfg.chi2_threshold());
        let cap_full =
            self.cfg.max_components > 0 && self.store.len() >= self.cfg.max_components;
        if accept || cap_full {
            // Posteriors p(j|x) (Eqs. 2–3, log space) — the O(K) serial
            // merge between the two sharded passes.
            self.buf_ll.clear();
            self.buf_sp.clear();
            for (j, &d2j) in self.buf_d2.iter().enumerate() {
                self.buf_ll.push(log_gaussian(d2j, self.store.log_det(j), d));
                self.buf_sp.push(self.store.sp(j));
            }
            let post = softmax_posteriors(&self.buf_ll, &self.buf_sp);
            if self.cfg.max_age > 0 {
                // Age bookkeeping: the point's argmax winner is
                // refreshed (ties → lowest index). No floating-point
                // work, so the default path stays bit-identical.
                self.store.set_stamp(argmax(&post), self.points);
            }
            {
                let Figmn { store, sigma_ini, buf_d2, buf_ws, buf_e, engine, .. } = self;
                update_pass(
                    store,
                    x,
                    d,
                    &post,
                    buf_d2,
                    buf_ws,
                    buf_e,
                    sigma_ini,
                    mode,
                    engine.as_ref(),
                );
            }
            self.prune();
            LearnOutcome::Updated
        } else {
            self.create(x);
            self.prune();
            LearnOutcome::Created
        }
    }

    /// The top-C learn body. The accept/create **decision** is exactly
    /// the full-K one: a candidate passing χ² means the full sweep
    /// accepts too, and when no candidate passes, the exact fallback
    /// gate scans every component the index cannot *prove* out of χ²
    /// reach (Mahalanobis cell bound) before a create is allowed. Only
    /// the posterior mass assignment — restricted to the candidate set
    /// plus any fallback acceptors — is approximate.
    fn learn_topc(&mut self, x: &[f64], c: usize) -> LearnOutcome {
        self.learn_topc_staged(x, c, None)
    }

    /// [`Self::learn_topc`] with an optional frozen block tile. On the
    /// masked mini-batch path (`tile = Some((tile, bi))`, `bi` the
    /// point's position in its block) the candidate distance stage
    /// consumes stage-1 tile entries where still valid and recomputes
    /// the rest with the per-point kernel; each (point, row) pair's
    /// arithmetic is self-contained and identical either way, so the
    /// mix is bit-identical to a pure per-point pass. Everything after
    /// the distance stage **is** the per-point path, plus tile
    /// bookkeeping: rows that absorbed mass (`p > 0`) are marked dirty
    /// (their mean/Λ changed, so later points in the block must
    /// recompute), and a prune invalidates the whole tile (row
    /// renumbering).
    fn learn_topc_staged(
        &mut self,
        x: &[f64],
        c: usize,
        mut tile: Option<(&mut TopcBlockTile, usize)>,
    ) -> LearnOutcome {
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let chi2 = self.cfg.chi2_threshold();
        // Maintain the index (serial and data-dependent only, so TopC
        // stays bit-deterministic across thread counts).
        if CandidateIndex::ensure(&mut self.index, &self.store) {
            self.counters.rebuilds += 1;
        }
        {
            let Figmn { index, store, buf_cand, .. } = self;
            index.as_ref().expect("ensured above").query(x, c, store, buf_cand);
        }
        let cn = self.buf_cand.len();
        self.buf_d2.resize(cn, 0.0);
        self.buf_ws.resize(cn * d, 0.0);
        self.buf_en.resize(cn, 0.0);
        if let Some((t, bi)) = &tile {
            let bi = *bi;
            let Figmn { store, buf_cand, buf_d2, buf_ws, buf_en, buf_e, .. } = self;
            buf_e.resize(d, 0.0);
            for (i, &jc) in buf_cand.iter().enumerate() {
                if let Some((d2, en, w)) = t.lookup(bi, jc) {
                    buf_d2[i] = d2;
                    buf_en[i] = en;
                    buf_ws[i * d..(i + 1) * d].copy_from_slice(w);
                } else {
                    // Tile miss (row created/updated/pruned since the
                    // block froze, or point re-queried outside its
                    // stage-0 set): per-point kernel, same arithmetic.
                    let j = jc as usize;
                    let e = &mut buf_e[..d];
                    sub_into(x, store.mean(j), e);
                    buf_en[i] = norm2(e).sqrt();
                    buf_d2[i] = packed::quad_form_with_mode(
                        store.mat(j),
                        d,
                        e,
                        &mut buf_ws[i * d..(i + 1) * d],
                        mode,
                    );
                }
            }
        } else {
            let Figmn { store, buf_cand, buf_d2, buf_ws, buf_en, buf_e, engine, .. } = self;
            candidate_distance_pass(
                store,
                x,
                d,
                buf_cand,
                buf_d2,
                buf_ws,
                buf_en,
                buf_e,
                mode,
                engine.as_ref(),
            );
        }
        let mut accept = self.buf_d2.iter().any(|&d2| d2 < chi2);
        let cap_full =
            self.cfg.max_components > 0 && self.store.len() >= self.cfg.max_components;
        if !accept && !cap_full {
            // Exact fallback gate: before a create, scan every
            // non-candidate component whose cell the index cannot prove
            // out of χ² reach. Acceptors join the candidate arrays (in
            // ascending component order); evaluated non-acceptors are
            // discarded — their posterior tail is the same tolerance
            // class as the unevaluated one.
            self.counters.fallback_gate_triggers += 1;
            let mut extra: Vec<(u32, f64, f64)> = Vec::new();
            let mut extra_ws: Vec<f64> = Vec::new();
            {
                let Figmn { index, store, buf_cand, .. } = self;
                let mut e = vec![0.0; d];
                index.as_ref().expect("ensured above").scan_possible(
                    x,
                    chi2,
                    buf_cand,
                    |jc| {
                        let j = jc as usize;
                        sub_into(x, store.mean(j), &mut e);
                        let start = extra_ws.len();
                        extra_ws.resize(start + d, 0.0);
                        let d2 = packed::quad_form_with_mode(
                            store.mat(j),
                            d,
                            &e,
                            &mut extra_ws[start..],
                            mode,
                        );
                        if d2 < chi2 {
                            extra.push((jc, d2, norm2(&e).sqrt()));
                        } else {
                            extra_ws.truncate(start);
                        }
                    },
                );
            }
            for (i, &(j, d2, en)) in extra.iter().enumerate() {
                accept = true;
                let pos = self.buf_cand.partition_point(|&cj| cj < j);
                self.buf_cand.insert(pos, j);
                self.buf_d2.insert(pos, d2);
                self.buf_en.insert(pos, en);
                let row = i * d;
                self.buf_ws.splice(pos * d..pos * d, extra_ws[row..row + d].iter().copied());
            }
        }
        if accept || cap_full {
            // Posteriors restricted to the candidate set, reduced in
            // ascending component order (thread-count independent).
            self.buf_ll.clear();
            self.buf_sp.clear();
            for (i, &jc) in self.buf_cand.iter().enumerate() {
                let j = jc as usize;
                self.buf_ll.push(log_gaussian(self.buf_d2[i], self.store.log_det(j), d));
                self.buf_sp.push(self.store.sp(j));
            }
            let post = softmax_posteriors(&self.buf_ll, &self.buf_sp);
            if self.cfg.max_age > 0 {
                // Age bookkeeping over the candidate set: the winner is
                // the argmax of the restricted posteriors.
                let w = self.buf_cand[argmax(&post)] as usize;
                self.store.set_stamp(w, self.points);
            }
            {
                let Figmn { store, sigma_ini, buf_cand, buf_d2, buf_ws, buf_e, engine, .. } =
                    self;
                candidate_update_pass(
                    store,
                    x,
                    d,
                    &post,
                    buf_cand,
                    buf_d2,
                    buf_ws,
                    buf_e,
                    sigma_ini,
                    mode,
                    engine.as_ref(),
                );
            }
            // Drift bookkeeping: each updated mean moved by ω‖e‖ with
            // ω = p/sp_new (sp already includes p after the update).
            {
                let Figmn { index, store, buf_cand, buf_en, counters, .. } = self;
                let index = index.as_mut().expect("ensured above");
                for (i, &jc) in buf_cand.iter().enumerate() {
                    let sp_new = store.sp(jc as usize);
                    if post[i] > 0.0 && sp_new > 0.0 {
                        counters.incremental_updates +=
                            index.note_update(jc as usize, post[i] / sp_new * buf_en[i], store);
                    }
                }
            }
            if let Some((t, _)) = &mut tile {
                // Rows that absorbed mass changed mean/Λ in place —
                // their frozen tile entries are stale for later points.
                for (i, &jc) in self.buf_cand.iter().enumerate() {
                    if post[i] > 0.0 {
                        t.mark_dirty(jc);
                    }
                }
            }
            let len_before = self.store.len();
            self.prune();
            if self.store.len() < len_before {
                if let Some((t, _)) = &mut tile {
                    t.invalidate();
                }
            }
            LearnOutcome::Updated
        } else {
            self.create(x);
            if let Some(index) = self.index.as_mut() {
                index.note_create(&self.store);
                self.counters.incremental_updates += 1;
            }
            let len_before = self.store.len();
            self.prune();
            if self.store.len() < len_before {
                if let Some((t, _)) = &mut tile {
                    t.invalidate();
                }
            }
            LearnOutcome::Created
        }
    }

    /// Learn one mini-batch block. Length-1 blocks and an empty store
    /// route through the exact online body (so `MiniBatch{b: 1}` is
    /// bit-identical to `Online`); Strict models stage through
    /// [`learn_block`], TopC models through the masked union-row pass
    /// ([`Self::learn_chunk_topc`]). Oversized blocks are re-chunked so
    /// the frozen `K×B×D` w-tile stays within [`LEARN_BLOCK_SLOTS`].
    fn learn_chunk(&mut self, xs: &[Vec<f64>], out: &mut Vec<LearnOutcome>) {
        if xs.len() >= 2 && !self.store.is_empty() {
            let slots = self.store.len() * self.cfg.dim;
            let b_max = (LEARN_BLOCK_SLOTS / slots.max(1)).max(1);
            if xs.len() > b_max {
                for sub in xs.chunks(b_max) {
                    self.learn_chunk(sub, out);
                }
                return;
            }
        }
        if xs.len() < 2 || self.store.is_empty() {
            for x in xs {
                out.push(self.learn(x));
            }
            return;
        }
        let d = self.cfg.dim;
        for x in xs.iter() {
            assert_eq!(x.len(), d, "learn: dimensionality mismatch");
        }
        match self.cfg.search_mode {
            SearchMode::Strict => {
                if self.cfg.decay < 1.0 {
                    // Per-point forgetting applied in bulk at block
                    // start (decay^B): within a block the sp
                    // accumulators are frozen anyway, so this is the
                    // blocked analogue of the online per-point decay
                    // sweep.
                    self.store.decay_sps(self.cfg.decay.powi(xs.len() as i32));
                }
                let base = self.points;
                self.points += xs.len() as u64;
                {
                    let Figmn { cfg, sigma_ini, store, engine, blk, .. } = self;
                    learn_block(store, xs, cfg, sigma_ini, engine.as_ref(), blk, base, out);
                }
                // One §2.3 sweep per block (the online path sweeps per
                // point — block-granular pruning is part of the
                // mini-batch approximation).
                self.prune();
            }
            SearchMode::TopC { c } => self.learn_chunk_topc(xs, c, out),
        }
    }

    /// Learn one TopC mini-batch block through the masked union-row
    /// pass: stage 0 queries every point's top-C candidate set against
    /// the block-start store/index (reads only), stage 1 streams each
    /// union row's packed arena data once through the blocked kernels
    /// ([`topc_block_pass`]), and stage 2 replays the exact per-point
    /// TopC body (per-point decay, live index re-query, χ²-fallback
    /// gate, per-point update/drift/prune), consuming frozen tile
    /// entries where still valid. Because stage 2 **is** the per-point
    /// path and every consumed tile entry is bit-equal to what a
    /// per-point kernel call would produce, the block is bit-identical
    /// to feeding its points through [`Self::learn_topc`] one at a
    /// time, at every thread count — see [`super::learn_pipeline`]'s
    /// union/mask contract. The win is bandwidth: each union row is
    /// streamed once per block instead of once per masking point.
    fn learn_chunk_topc(&mut self, xs: &[Vec<f64>], c: usize, out: &mut Vec<LearnOutcome>) {
        if CandidateIndex::ensure(&mut self.index, &self.store) {
            self.counters.rebuilds += 1;
        }
        let d = self.cfg.dim;
        // Stage 0: per-point candidate sets vs the block-start state,
        // concatenated CSR-style (point bi's set = cands[offs[bi]..offs[bi+1]]).
        let mut cands: Vec<u32> = Vec::new();
        let mut offs: Vec<usize> = Vec::with_capacity(xs.len() + 1);
        offs.push(0);
        {
            let Figmn { index, store, buf_cand, .. } = self;
            let index = index.as_ref().expect("ensured above");
            for x in xs {
                index.query(x, c, store, buf_cand);
                cands.extend_from_slice(buf_cand);
                offs.push(cands.len());
            }
        }
        // Stage 1: masked blocked distance pass over the union rows.
        let mut tile = {
            let Figmn { cfg, store, engine, blk, .. } = self;
            topc_block_pass(store, xs, d, cands, offs, blk, cfg.kernel_mode, engine.as_ref())
        };
        self.counters.masked_block_rows += tile.rows as u64;
        // Stage 2: exact per-point replay.
        for (bi, x) in xs.iter().enumerate() {
            self.points += 1;
            if self.cfg.decay < 1.0 {
                self.store.decay_sps(self.cfg.decay);
            }
            out.push(self.learn_topc_staged(x, c, Some((&mut tile, bi))));
        }
    }
}

impl IncrementalMixture for Figmn {
    fn learn(&mut self, x: &[f64]) -> LearnOutcome {
        assert_eq!(x.len(), self.cfg.dim, "learn: dimensionality mismatch");
        self.points += 1;
        if self.store.is_empty() {
            self.create(x);
            if self.cfg.search_mode.top_c().is_some() {
                self.index = Some(CandidateIndex::build(&self.store));
            }
            return LearnOutcome::Created;
        }
        if self.cfg.decay < 1.0 {
            // Drift adaptation: exponential forgetting of the sp
            // accumulators before the point is applied. The decay = 1.0
            // default skips the sweep entirely, so the stationary path
            // performs exactly the pre-decay floating-point sequence.
            self.store.decay_sps(self.cfg.decay);
        }
        match self.cfg.search_mode {
            SearchMode::Strict => self.learn_full(x),
            SearchMode::TopC { c } => self.learn_topc(x, c),
        }
    }

    /// Batch write surface. [`LearnMode::Online`] models (the default)
    /// consume the batch point-by-point — exactly the trait's serial
    /// loop — while [`LearnMode::MiniBatch`] models stage `b`-point
    /// blocks through the learn pipeline (see
    /// [`super::learn_pipeline`] for the freeze semantics and the
    /// exactness contract: `b = 1` routes through the online body and
    /// is bit-identical to `Online` at every thread count).
    fn learn_batch(&mut self, xs: &[Vec<f64>]) -> Vec<LearnOutcome> {
        let mut out = Vec::with_capacity(xs.len());
        match self.cfg.learn_mode {
            LearnMode::Online => {
                for x in xs {
                    out.push(self.learn(x));
                }
            }
            LearnMode::MiniBatch { b } => {
                for chunk in xs.chunks(b.max(1)) {
                    self.learn_chunk(chunk, &mut out);
                }
            }
        }
        out
    }

    fn num_components(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.cfg.dim
    }

    fn index_counters(&self) -> IndexCounters {
        self.counters
    }

    fn predict(&self, known_vals: &[f64], known_idx: &[usize], target_idx: &[usize]) -> Vec<f64> {
        assert_eq!(known_vals.len(), known_idx.len());
        assert!(!self.store.is_empty(), "predict on empty model");
        let k = self.store.len();
        let d = self.cfg.dim;
        let mut log_liks = vec![0.0; k];
        let mut recons: Vec<Vec<f64>> = vec![Vec::new(); k];
        match &self.engine {
            Some(pool) if worth_sharding(k, d, pool.threads()) => {
                let store = &self.store;
                let ll = SharedMut::new(log_liks.as_mut_ptr());
                let rc = SharedMut::new(recons.as_mut_ptr());
                pool.run(k, &move |_, range, _| {
                    for j in range {
                        let r = precision_conditional(
                            store.mat(j),
                            d,
                            store.mean(j),
                            store.log_det(j),
                            known_vals,
                            known_idx,
                            target_idx,
                        );
                        // Safety: slot j is owned by exactly one shard.
                        unsafe {
                            *ll.at(j) = r.log_lik;
                            *rc.at(j) = r.reconstruction;
                        }
                    }
                });
            }
            _ => {
                for (j, (llj, rcj)) in log_liks.iter_mut().zip(recons.iter_mut()).enumerate() {
                    let r = precision_conditional(
                        self.store.mat(j),
                        d,
                        self.store.mean(j),
                        self.store.log_det(j),
                        known_vals,
                        known_idx,
                        target_idx,
                    );
                    *llj = r.log_lik;
                    *rcj = r.reconstruction;
                }
            }
        }
        let post = softmax_posteriors(&log_liks, self.store.sps()); // Eq. 14
        let mut out = vec![0.0; target_idx.len()];
        for (p, r) in post.iter().zip(recons.iter()) {
            for (o, &v) in out.iter_mut().zip(r.iter()) {
                *o += p * v; // Eq. 27 mixture
            }
        }
        out
    }

    fn log_density(&self, x: &[f64]) -> f64 {
        assert!(!self.store.is_empty());
        let total_sp = self.store.total_sp();
        if let Some((index, c)) = self.active_index() {
            let (cands, ll) = self.topc_loglik(index, x, c);
            let terms: Vec<f64> = cands
                .iter()
                .zip(ll.iter())
                .map(|(&j, &llj)| llj + (self.store.sp(j as usize) / total_sp).ln())
                .collect();
            return logsumexp_tree(&terms);
        }
        let ll = self.per_component_loglik(x);
        let terms: Vec<f64> = self
            .store
            .sps()
            .iter()
            .zip(ll.iter())
            .map(|(&sp, &llj)| llj + (sp / total_sp).ln())
            .collect();
        logsumexp_tree(&terms)
    }

    fn posteriors(&self, x: &[f64]) -> Vec<f64> {
        if let Some((index, c)) = self.active_index() {
            // Full-length posterior vector (API shape contract), with
            // the mass renormalized over the candidate set and zeros
            // everywhere else.
            let (cands, ll) = self.topc_loglik(index, x, c);
            let sps: Vec<f64> = cands.iter().map(|&j| self.store.sp(j as usize)).collect();
            let post = softmax_posteriors(&ll, &sps);
            let mut out = vec![0.0; self.store.len()];
            for (&j, &p) in cands.iter().zip(post.iter()) {
                out[j as usize] = p;
            }
            return out;
        }
        let ll = self.per_component_loglik(x);
        softmax_posteriors(&ll, self.store.sps())
    }

    fn points_seen(&self) -> u64 {
        self.points
    }

    /// Batch scoring runs **component-outer / query-inner** over `K×B`
    /// tiles: queries are grouped into [`SCORE_BLOCK`]-sized blocks and
    /// each packed component row is streamed once per block through the
    /// multi-query kernels (instead of once per query — the per-point
    /// path is bandwidth-bound at large `D`). With an engine attached,
    /// one pool dispatch per memory-bounded chunk shards the K axis:
    /// each worker sweeps its component shard against every query block
    /// of the chunk with its own block scratch, then the per-point
    /// merges run serially through the deterministic tree reduction.
    /// Values are identical to mapping
    /// [`IncrementalMixture::log_density`] — blocking never reorders a
    /// query's own floating-point operations, in either kernel mode.
    fn score_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        if xs.is_empty() {
            // Contract parity with mapping `log_density`: an empty batch
            // is empty output even on an untrained model.
            return Vec::new();
        }
        assert!(!self.store.is_empty(), "score_batch on empty model");
        let k = self.store.len();
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        for x in xs {
            assert_eq!(x.len(), d, "score_batch: dimensionality mismatch");
        }
        if let Some((index, c)) = self.active_index() {
            return self.score_batch_topc(index, c, xs);
        }
        let total_sp = self.store.total_sp();
        let chunk = (BATCH_CHUNK_SLOTS / k).max(1);
        // terms[bi*k + j] = ln p(x_bi|j) + ln p(j), reused per chunk.
        let mut terms = vec![0.0; chunk.min(xs.len()) * k];
        let mut out = Vec::with_capacity(xs.len());
        // Serial-path block scratch, built on first use and reused
        // across chunks (engine workers use their per-thread scratch
        // arenas instead, so pooled calls never pay this allocation).
        let mut blk: Option<ScoreBlock> = None;
        for xs_chunk in xs.chunks(chunk) {
            let b = xs_chunk.len();
            let terms = &mut terms[..b * k];
            let pool = self
                .engine
                .as_ref()
                .filter(|p| worth_sharding_batch(b, k, d, p.threads()));
            if let Some(pool) = pool {
                let store = &self.store;
                let outp = SharedMut::new(terms.as_mut_ptr());
                let wlen = wblock_len(d, SCORE_BLOCK, mode);
                pool.run(k, &move |_, range, scratch| {
                    for j in range {
                        let prior_ln = (store.sp(j) / total_sp).ln();
                        for (bs, block) in xs_chunk.chunks(SCORE_BLOCK).enumerate() {
                            let (e, w, q) = scratch.split3(SCORE_BLOCK * d, wlen, SCORE_BLOCK);
                            component_block_terms(
                                store.mat(j),
                                store.mean(j),
                                store.log_det(j),
                                d,
                                block,
                                prior_ln,
                                mode,
                                e,
                                w,
                                q,
                            );
                            let base = bs * SCORE_BLOCK;
                            for (bi, &t) in q[..block.len()].iter().enumerate() {
                                // Safety: column j is owned by exactly
                                // one shard.
                                unsafe {
                                    *outp.at((base + bi) * k + j) = t;
                                }
                            }
                        }
                    }
                });
            } else {
                let blk = blk.get_or_insert_with(|| ScoreBlock::new(d, xs.len(), mode));
                for j in 0..k {
                    let prior_ln = (self.store.sp(j) / total_sp).ln();
                    for (bs, block) in xs_chunk.chunks(SCORE_BLOCK).enumerate() {
                        let q = blk.component_terms(
                            self.store.mat(j),
                            self.store.mean(j),
                            self.store.log_det(j),
                            block,
                            prior_ln,
                            mode,
                        );
                        let base = bs * SCORE_BLOCK;
                        for (bi, &t) in q.iter().enumerate() {
                            terms[(base + bi) * k + j] = t;
                        }
                    }
                }
            }
            out.extend((0..b).map(|bi| logsumexp_tree(&terms[bi * k..(bi + 1) * k])));
        }
        out
    }

    /// Batch conditional inference with the same chunked sharding and
    /// `K×B` tiling as [`IncrementalMixture::score_batch`]: per
    /// component, each query block runs through
    /// [`precision_conditional_multi_with`], which streams the
    /// component's `Λ` entries once per block, against a target-block
    /// Cholesky factorized **once per component per call** (the factor
    /// depends on neither the queries nor the blocks). Identical to
    /// mapping [`IncrementalMixture::predict`].
    fn predict_batch(
        &self,
        known_vals: &[Vec<f64>],
        known_idx: &[usize],
        target_idx: &[usize],
    ) -> Vec<Vec<f64>> {
        if known_vals.is_empty() {
            // Contract parity with mapping `predict`: empty in, empty out.
            return Vec::new();
        }
        assert!(!self.store.is_empty(), "predict_batch on empty model");
        let k = self.store.len();
        let d = self.cfg.dim;
        let sps = self.store.sps();
        let chunk = (BATCH_CHUNK_SLOTS / k).max(1);
        // Per-component target-block factors, hoisted out of the chunk
        // and block loops (read-only below, shared across the pool).
        let factors: Vec<Cholesky> = (0..k)
            .map(|j| target_block_cholesky(self.store.mat(j), d, target_idx))
            .collect();
        let mut out = Vec::with_capacity(known_vals.len());
        for kv_chunk in known_vals.chunks(chunk) {
            let b = kv_chunk.len();
            let mut log_liks = vec![0.0; b * k];
            let mut recons: Vec<Vec<f64>> = vec![Vec::new(); b * k];
            let pool = self
                .engine
                .as_ref()
                .filter(|p| worth_sharding_batch(b, k, d, p.threads()));
            if let Some(pool) = pool {
                let store = &self.store;
                let factors = &factors;
                let ll = SharedMut::new(log_liks.as_mut_ptr());
                let rc = SharedMut::new(recons.as_mut_ptr());
                pool.run(k, &move |_, range, _| {
                    for j in range {
                        for (bs, block) in kv_chunk.chunks(SCORE_BLOCK).enumerate() {
                            let conds = precision_conditional_multi_with(
                                store.mat(j),
                                d,
                                store.mean(j),
                                store.log_det(j),
                                block,
                                known_idx,
                                target_idx,
                                &factors[j],
                            );
                            let base = bs * SCORE_BLOCK;
                            for (bi, c) in conds.into_iter().enumerate() {
                                // Safety: column j is owned by exactly
                                // one shard.
                                unsafe {
                                    *ll.at((base + bi) * k + j) = c.log_lik;
                                    *rc.at((base + bi) * k + j) = c.reconstruction;
                                }
                            }
                        }
                    }
                });
            } else {
                for j in 0..k {
                    for (bs, block) in kv_chunk.chunks(SCORE_BLOCK).enumerate() {
                        let conds = precision_conditional_multi_with(
                            self.store.mat(j),
                            d,
                            self.store.mean(j),
                            self.store.log_det(j),
                            block,
                            known_idx,
                            target_idx,
                            &factors[j],
                        );
                        let base = bs * SCORE_BLOCK;
                        for (bi, c) in conds.into_iter().enumerate() {
                            log_liks[(base + bi) * k + j] = c.log_lik;
                            recons[(base + bi) * k + j] = c.reconstruction;
                        }
                    }
                }
            }
            out.extend((0..b).map(|bi| {
                let row_ll = &log_liks[bi * k..(bi + 1) * k];
                let post = softmax_posteriors(row_ll, sps);
                let mut acc = vec![0.0; target_idx.len()];
                for (p, r) in post.iter().zip(recons[bi * k..(bi + 1) * k].iter()) {
                    for (o, &v) in acc.iter_mut().zip(r.iter()) {
                        *o += p * v;
                    }
                }
                acc
            }));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;

    fn two_cluster_data() -> Vec<[f64; 2]> {
        // Two tight clusters far apart.
        let mut pts = Vec::new();
        for i in 0..20 {
            let t = i as f64 * 0.01;
            pts.push([t, -t]);
            pts.push([10.0 + t, 10.0 - t]);
        }
        pts
    }

    fn trained() -> Figmn {
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        for p in two_cluster_data() {
            m.learn(&p);
        }
        m
    }

    #[test]
    fn discovers_two_clusters() {
        let m = trained();
        assert_eq!(m.num_components(), 2);
    }

    #[test]
    fn first_point_creates() {
        let cfg = GmmConfig::new(2);
        let mut m = Figmn::new(cfg, &[1.0, 1.0]);
        assert_eq!(m.learn(&[0.0, 0.0]), LearnOutcome::Created);
        assert_eq!(m.num_components(), 1);
        assert_eq!(m.points_seen(), 1);
    }

    #[test]
    fn beta_zero_never_creates_second() {
        let cfg = GmmConfig::new(2).with_beta(0.0).with_delta(1.0).without_pruning();
        let mut m = Figmn::new(cfg, &[1.0, 1.0]);
        m.learn(&[0.0, 0.0]);
        for p in two_cluster_data() {
            assert_eq!(m.learn(&p), LearnOutcome::Updated);
        }
        assert_eq!(m.num_components(), 1);
    }

    #[test]
    fn sp_accumulates_posterior_mass() {
        let m = trained();
        let total_sp: f64 = (0..m.num_components()).map(|j| m.component_stats(j).0).sum();
        // Each learn() adds exactly 1 total posterior mass; creations add 1.
        assert!((total_sp - m.points_seen() as f64).abs() < 1e-9);
    }

    #[test]
    fn priors_sum_to_one() {
        let m = trained();
        let s: f64 = (0..m.num_components()).map(|j| m.prior(j)).sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lambda_stays_pd_and_logdet_consistent() {
        let m = trained();
        for j in 0..m.num_components() {
            let lam = m.component_lambda(j);
            let ch = Cholesky::new(&lam).expect("Λ must stay PD");
            // The packed row factors identically to its dense expansion.
            let ch_packed =
                Cholesky::new_packed(m.store().mat(j), m.dim()).expect("packed Λ must stay PD");
            assert_eq!(ch.factor().as_slice(), ch_packed.factor().as_slice());
            // log|C| = −log|Λ|
            let log_det_c = -ch.log_det();
            assert!(
                (log_det_c - m.component_log_det(j)).abs() < 1e-6,
                "tracked log|C| diverged: {} vs {}",
                log_det_c,
                m.component_log_det(j)
            );
        }
    }

    #[test]
    fn predict_reconstructs_cluster_partner() {
        let m = trained();
        // Within cluster A, y ≈ −x; within B, y ≈ 20 − x.
        let y = m.predict(&[0.05], &[0], &[1]);
        assert!((y[0] + 0.05).abs() < 0.2, "got {}", y[0]);
        let y = m.predict(&[10.05], &[0], &[1]);
        assert!((y[0] - 9.95).abs() < 0.2, "got {}", y[0]);
    }

    #[test]
    fn posteriors_pick_right_cluster() {
        let m = trained();
        let p = m.posteriors(&[0.1, -0.1]);
        let q = m.posteriors(&[10.1, 9.9]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // The two points must prefer different components.
        let a = p.iter().cloned().fold((0, f64::MIN, 0usize), |(i, b, bi), v| {
            if v > b { (i + 1, v, i) } else { (i + 1, b, bi) }
        }).2;
        let b = q.iter().cloned().fold((0, f64::MIN, 0usize), |(i, bb, bi), v| {
            if v > bb { (i + 1, v, i) } else { (i + 1, bb, bi) }
        }).2;
        assert_ne!(a, b);
    }

    #[test]
    fn log_density_higher_on_data() {
        let m = trained();
        assert!(m.log_density(&[0.0, 0.0]) > m.log_density(&[5.0, 5.0]));
    }

    #[test]
    fn pruning_removes_spurious() {
        let cfg = GmmConfig::new(2).with_delta(0.05).with_beta(0.2).with_pruning(3, 2.0);
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        // One outlier creates a component that never fires again…
        m.learn(&[100.0, 100.0]);
        // …then a long, tight stream elsewhere.
        for i in 0..50 {
            let t = (i % 10) as f64 * 0.01;
            m.learn(&[t, t]);
        }
        // The outlier component must have been pruned.
        for j in 0..m.num_components() {
            assert!(m.component_mean(j)[0] < 50.0);
        }
    }

    #[test]
    fn prune_never_empties_the_mixture() {
        // Regression: one accepted point ages every component (v += 1)
        // while their posterior mass is still tiny, so with aggressive
        // thresholds *all* components trip `v > v_min && sp < sp_min`
        // at once. The old prune retained nothing, after which
        // log_density/predict panicked and prior() divided by zero.
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.9).with_pruning(1, 100.0);
        let mut m = Figmn::new(cfg, &[1.0]);
        m.learn(&[0.0]); // component A
        m.learn(&[1000.0]); // far away: component B
        assert_eq!(m.num_components(), 2);
        // Accepted by A (d² = 0): both components now have v = 2 > 1
        // and sp ≪ 100 — every one is "spurious".
        m.learn(&[0.0]);
        assert_eq!(m.num_components(), 1, "strongest component must survive");
        // The survivor is the one that actually absorbed the mass.
        assert!(m.component_mean(0)[0].abs() < 1.0);
        assert!((m.prior(0) - 1.0).abs() < 1e-12);
        assert!(m.log_density(&[0.0]).is_finite());
        assert!(m.posteriors(&[0.0]) == vec![1.0]);
    }

    #[test]
    fn max_components_caps() {
        let cfg = GmmConfig::new(1).with_beta(0.5).with_delta(0.001).with_max_components(3).without_pruning();
        let mut m = Figmn::new(cfg, &[1.0]);
        for i in 0..50 {
            m.learn(&[i as f64 * 100.0]); // every point is novel
        }
        assert_eq!(m.num_components(), 3);
    }

    #[test]
    #[should_panic]
    fn learn_rejects_wrong_dim() {
        let mut m = Figmn::new(GmmConfig::new(3), &[1.0, 1.0, 1.0]);
        m.learn(&[1.0]);
    }

    #[test]
    fn batch_api_matches_serial_loop() {
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut a = Figmn::new(cfg.clone(), &[5.0, 5.0]);
        let mut b = Figmn::new(cfg, &[5.0, 5.0]);
        let batch: Vec<Vec<f64>> = two_cluster_data().iter().map(|p| p.to_vec()).collect();
        let serial: Vec<LearnOutcome> = batch.iter().map(|p| a.learn(p)).collect();
        let batched = b.learn_batch(&batch);
        assert_eq!(serial, batched);
        assert_eq!(a.num_components(), b.num_components());

        let probes: Vec<Vec<f64>> =
            vec![vec![0.0, 0.0], vec![10.0, 10.0], vec![5.0, 5.0]];
        let dens = b.score_batch(&probes);
        for (x, &ld) in probes.iter().zip(dens.iter()) {
            assert_eq!(a.log_density(x), ld);
        }
        let knowns: Vec<Vec<f64>> = vec![vec![0.05], vec![10.05]];
        let preds = b.predict_batch(&knowns, &[0], &[1]);
        for (kv, pred) in knowns.iter().zip(preds.iter()) {
            assert_eq!(&a.predict(kv, &[0], &[1]), pred);
        }
        // Contract parity with the default impls: an empty batch is an
        // empty result, even on an untrained model.
        let fresh = Figmn::new(GmmConfig::new(2), &[1.0, 1.0]);
        assert!(fresh.score_batch(&[]).is_empty());
        assert!(fresh.predict_batch(&[], &[0], &[1]).is_empty());
    }

    #[test]
    fn engine_attach_detach_preserves_results() {
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut serial = Figmn::new(cfg.clone(), &[5.0, 5.0]);
        let mut pooled =
            Figmn::new(cfg, &[5.0, 5.0]).with_engine(EngineConfig::new(2));
        assert_eq!(pooled.engine_threads(), 2);
        for p in two_cluster_data() {
            assert_eq!(serial.learn(&p), pooled.learn(&p));
        }
        assert_eq!(serial.num_components(), pooled.num_components());
        for j in 0..serial.num_components() {
            assert_eq!(serial.component_mean(j), pooled.component_mean(j));
            assert_eq!(serial.component_log_det(j), pooled.component_log_det(j));
        }
        pooled.set_engine(None);
        assert_eq!(pooled.engine_threads(), 1);
        assert_eq!(serial.learn(&[5.0, 5.0]), pooled.learn(&[5.0, 5.0]));
    }

    #[test]
    fn fast_mode_tracks_strict_within_tolerance() {
        let stds = [5.0, 5.0];
        let strict_cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let fast_cfg = strict_cfg.clone().with_kernel_mode(KernelMode::Fast);
        let mut strict = Figmn::new(strict_cfg, &stds);
        let mut fast = Figmn::new(fast_cfg, &stds);
        for p in two_cluster_data() {
            assert_eq!(strict.learn(&p), fast.learn(&p));
        }
        assert_eq!(strict.num_components(), fast.num_components());
        for x in [[0.0, 0.0], [10.0, 10.0], [5.0, 5.0]] {
            let a = strict.log_density(&x);
            let b = fast.log_density(&x);
            assert!(
                (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                "log_density diverged: {a} vs {b}"
            );
        }
    }

    #[test]
    fn fast_mode_is_bit_deterministic_across_thread_counts() {
        let cfg = GmmConfig::new(2)
            .with_delta(0.3)
            .with_beta(0.1)
            .with_kernel_mode(KernelMode::Fast)
            .without_pruning();
        let stds = [5.0, 5.0];
        let mut serial = Figmn::new(cfg.clone(), &stds);
        let mut pooled = Figmn::new(cfg, &stds).with_engine(EngineConfig::new(2));
        for p in two_cluster_data() {
            assert_eq!(serial.learn(&p), pooled.learn(&p));
        }
        assert_eq!(serial.num_components(), pooled.num_components());
        for j in 0..serial.num_components() {
            assert_eq!(serial.component_mean(j), pooled.component_mean(j));
            assert_eq!(serial.store().mat(j), pooled.store().mat(j));
            assert_eq!(serial.component_log_det(j), pooled.component_log_det(j));
        }
        let probe = [1.0, -1.0];
        assert_eq!(serial.log_density(&probe), pooled.log_density(&probe));
        assert_eq!(serial.posteriors(&probe), pooled.posteriors(&probe));
    }

    #[test]
    fn max_components_reserves_the_arenas() {
        let cap = 16;
        let cfg = GmmConfig::new(2)
            .with_beta(0.5)
            .with_delta(0.001)
            .with_max_components(cap)
            .without_pruning();
        let mut m = Figmn::new(cfg, &[1.0, 1.0]);
        assert!(m.store().capacity_rows() >= cap);
        m.learn(&[0.0, 0.0]);
        let base = m.store().mean(0).as_ptr();
        for i in 1..cap * 2 {
            m.learn(&[i as f64 * 100.0, 0.0]); // every point is novel
        }
        assert_eq!(m.num_components(), cap);
        assert!(
            std::ptr::eq(base, m.store().mean(0).as_ptr()),
            "reserved arena bases must be stable across creates"
        );
    }

    #[test]
    fn memory_footprint_reflects_packed_arenas() {
        let m = trained();
        let d = m.dim();
        let tri = d * (d + 1) / 2;
        assert_eq!(m.bytes_per_component(), (d + tri + 2) * 8 + 16);
        assert_eq!(m.model_bytes(), m.num_components() * m.bytes_per_component());
        // Strictly below the dense array-of-structs payload for D ≥ 2.
        let dense_payload = (d + d * d + 2) * 8 + 16;
        assert!(m.bytes_per_component() < dense_payload);
    }

    #[test]
    fn minibatch_b1_bit_identical_to_online() {
        let data = two_cluster_data();
        for kmode in [KernelMode::Strict, KernelMode::Fast] {
            let cfg = GmmConfig::new(2)
                .with_delta(0.3)
                .with_beta(0.1)
                .without_pruning()
                .with_kernel_mode(kmode);
            let mut online = Figmn::new(cfg.clone(), &[5.0, 5.0]);
            let mut mb = Figmn::new(
                cfg.with_learn_mode(LearnMode::MiniBatch { b: 1 }),
                &[5.0, 5.0],
            );
            let xs: Vec<Vec<f64>> = data.iter().map(|p| p.to_vec()).collect();
            let a = online.learn_batch(&xs);
            let b = mb.learn_batch(&xs);
            assert_eq!(a, b);
            assert_eq!(online.store(), mb.store(), "b=1 must take the online path ({kmode:?})");
        }
    }

    #[test]
    fn minibatch_blocks_are_engine_invariant() {
        let data = two_cluster_data();
        let xs: Vec<Vec<f64>> = data.iter().map(|p| p.to_vec()).collect();
        let cfg = GmmConfig::new(2)
            .with_delta(0.3)
            .with_beta(0.1)
            .without_pruning()
            .with_learn_mode(LearnMode::MiniBatch { b: 8 });
        let mut serial = Figmn::new(cfg.clone(), &[5.0, 5.0]);
        let serial_out = serial.learn_batch(&xs);
        for threads in [2, 4] {
            let mut sharded =
                Figmn::new(cfg.clone(), &[5.0, 5.0]).with_engine(EngineConfig::new(threads));
            let out = sharded.learn_batch(&xs);
            assert_eq!(serial_out, out);
            assert_eq!(
                serial.store(),
                sharded.store(),
                "mini-batch blocks must be bit-identical at {threads} threads"
            );
        }
    }

    #[test]
    fn decay_shrinks_stale_component_mass() {
        let cfg = GmmConfig::new(2)
            .with_delta(0.3)
            .with_beta(0.1)
            .without_pruning()
            .with_decay(0.9);
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        m.learn(&[0.0, 0.0]);
        let sp_before = m.component_stats(0).0;
        // Train far away: component 0 only decays from here on.
        for _ in 0..20 {
            m.learn(&[10.0, 10.0]);
        }
        let sp_after = m.component_stats(0).0;
        assert!(
            sp_after < sp_before * 0.2,
            "decayed sp {sp_after} vs initial {sp_before}"
        );
        // Without decay the stale component keeps (and grows) its mass.
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        m.learn(&[0.0, 0.0]);
        for _ in 0..20 {
            m.learn(&[10.0, 10.0]);
        }
        assert!(m.component_stats(0).0 >= sp_before);
    }

    #[test]
    fn max_age_evicts_abandoned_component() {
        let cfg = GmmConfig::new(2)
            .with_delta(0.3)
            .with_beta(0.1)
            .without_pruning()
            .with_max_age(10);
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        m.learn(&[0.0, 0.0]);
        // The abandoned cluster outlives its horizon by a wide margin.
        for _ in 0..30 {
            m.learn(&[10.0, 10.0]);
        }
        assert_eq!(m.num_components(), 1, "stale component must age out");
        assert!((m.component_mean(0)[0] - 10.0).abs() < 1.0);
    }
}
