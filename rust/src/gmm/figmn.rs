//! FIGMN — the paper's fast precision-matrix IGMN (§3).
//!
//! Per data point and component the work is: one `Λ·v` product for the
//! Mahalanobis distance (Eq. 22), and the fused rank-two Sherman–Morrison
//! update (Eqs. 20–21) with the determinant-lemma update (Eqs. 25–26) —
//! all `O(D²)`. No matrix is ever inverted or factorized on the learn
//! path.

use super::inference::precision_conditional;
use super::{log_gaussian, softmax_posteriors, GmmConfig, IncrementalMixture, LearnOutcome};
use crate::linalg::rank_one::figmn_fused_update;
use crate::linalg::{sub_into, Matrix};

/// One Gaussian component in precision form.
#[derive(Debug, Clone)]
pub(crate) struct PrecisionComponent {
    pub mean: Vec<f64>,
    /// Λ = C⁻¹ (kept exactly symmetric by the update rules).
    pub lambda: Matrix,
    /// log |C| — note: determinant of the *covariance*, as in the paper
    /// ("we keep the precision matrix Λ, but the determinant of C").
    pub log_det: f64,
    /// Accumulator sp_j (Eq. 5).
    pub sp: f64,
    /// Age v_j (Eq. 4).
    pub v: u64,
}

/// The fast IGMN (paper §3). See [`crate::gmm`] for the shared semantics.
pub struct Figmn {
    cfg: GmmConfig,
    sigma_ini: Vec<f64>,
    comps: Vec<PrecisionComponent>,
    points: u64,
    // --- reusable scratch (learn() allocates nothing after warm-up) ---
    buf_e: Vec<f64>,
    buf_d2: Vec<f64>,
    /// Per-component `w = Λ·e` saved by the distance pass (K·D flat) and
    /// reused by the fused update — see rank_one::figmn_fused_update.
    buf_ws: Vec<f64>,
    buf_ll: Vec<f64>,
    buf_sp: Vec<f64>,
}

impl Figmn {
    /// `dataset_stds`: per-dimension standard deviations for
    /// `σ_ini = δ·std(x)` (Eq. 13) — an estimate is fine (§2.2).
    pub fn new(cfg: GmmConfig, dataset_stds: &[f64]) -> Self {
        let sigma_ini = cfg.sigma_ini(dataset_stds);
        let d = cfg.dim;
        Figmn {
            cfg,
            sigma_ini,
            comps: Vec::new(),
            points: 0,
            buf_e: vec![0.0; d],
            buf_d2: Vec::new(),
            buf_ws: Vec::new(),
            buf_ll: Vec::new(),
            buf_sp: Vec::new(),
        }
    }

    pub fn config(&self) -> &GmmConfig {
        &self.cfg
    }

    pub fn sigma_ini(&self) -> &[f64] {
        &self.sigma_ini
    }

    pub(crate) fn components(&self) -> &[PrecisionComponent] {
        &self.comps
    }

    pub(crate) fn components_mut(&mut self) -> &mut Vec<PrecisionComponent> {
        &mut self.comps
    }

    pub(crate) fn from_parts(
        cfg: GmmConfig,
        sigma_ini: Vec<f64>,
        comps: Vec<PrecisionComponent>,
        points: u64,
    ) -> Self {
        let d = cfg.dim;
        Figmn {
            cfg,
            sigma_ini,
            comps,
            points,
            buf_e: vec![0.0; d],
            buf_d2: Vec::new(),
            buf_ws: Vec::new(),
            buf_ll: Vec::new(),
            buf_sp: Vec::new(),
        }
    }

    /// Mean of component `j` (exposed for tests/benches/tools).
    pub fn component_mean(&self, j: usize) -> &[f64] {
        &self.comps[j].mean
    }

    /// `(sp_j, v_j)` bookkeeping of component `j`.
    pub fn component_stats(&self, j: usize) -> (f64, u64) {
        (self.comps[j].sp, self.comps[j].v)
    }

    /// Precision matrix of component `j`.
    pub fn component_lambda(&self, j: usize) -> &Matrix {
        &self.comps[j].lambda
    }

    /// `log|C_j|`.
    pub fn component_log_det(&self, j: usize) -> f64 {
        self.comps[j].log_det
    }

    /// Prior p(j) = sp_j / Σ sp (Eq. 12).
    pub fn prior(&self, j: usize) -> f64 {
        let total: f64 = self.comps.iter().map(|c| c.sp).sum();
        self.comps[j].sp / total
    }

    /// Squared Mahalanobis distances to every component (Eq. 22),
    /// saving each component's `w = Λ·e` for the fused update.
    fn distances_into(&mut self, x: &[f64]) {
        let k = self.comps.len();
        let d = self.cfg.dim;
        self.buf_d2.clear();
        self.buf_d2.reserve(k);
        self.buf_ws.resize(k * d, 0.0);
        for (j, c) in self.comps.iter().enumerate() {
            sub_into(x, &c.mean, &mut self.buf_e);
            let w = &mut self.buf_ws[j * d..(j + 1) * d];
            self.buf_d2.push(c.lambda.quad_form_with(&self.buf_e, w));
        }
    }

    fn create(&mut self, x: &[f64]) {
        let d = self.cfg.dim;
        let mut lambda = Matrix::zeros(d, d);
        let mut log_det = 0.0;
        for i in 0..d {
            let s2 = self.sigma_ini[i] * self.sigma_ini[i];
            lambda[(i, i)] = 1.0 / s2;
            log_det += s2.ln();
        }
        self.comps.push(PrecisionComponent {
            mean: x.to_vec(),
            lambda,
            log_det,
            sp: 1.0,
            v: 1,
        });
    }

    fn update_all(&mut self, x: &[f64]) {
        let d2 = std::mem::take(&mut self.buf_d2);
        // Posteriors p(j|x) (Eqs. 2–3, log space).
        self.buf_ll.clear();
        self.buf_sp.clear();
        for (c, &d2j) in self.comps.iter().zip(d2.iter()) {
            self.buf_ll.push(log_gaussian(d2j, c.log_det, self.cfg.dim));
            self.buf_sp.push(c.sp);
        }
        let post = softmax_posteriors(&self.buf_ll, &self.buf_sp);

        for (j, c) in self.comps.iter_mut().enumerate() {
            let p = post[j];
            c.v += 1; // Eq. 4
            c.sp += p; // Eq. 5
            let omega = p / c.sp; // Eq. 7 (with the *updated* sp)
            if omega <= 0.0 {
                // ω = 0: Eqs. 8–11 are exact no-ops; skip the O(D²) work.
                continue;
            }
            sub_into(x, &c.mean, &mut self.buf_e); // Eq. 6
            for i in 0..self.cfg.dim {
                c.mean[i] += omega * self.buf_e[i]; // Eqs. 8–9
            }
            // Fused rank-one form of Eqs. 20–21/25–26 (exact old-mean
            // Eq. 11 — DESIGN.md §Deviations; single-pass rewrite —
            // EXPERIMENTS.md §Perf L3-1), reusing w/q from the distance
            // pass.
            let d = self.cfg.dim;
            let w = &self.buf_ws[j * d..(j + 1) * d];
            match figmn_fused_update(&mut c.lambda, w, d2[j], omega, c.log_det) {
                Some(r) => c.log_det = r.log_det,
                None => {
                    // Float underflow destroyed positive-definiteness
                    // (reachable only at extreme conditioning). Reset the
                    // component's shape to σ_ini around its current mean.
                    let mut log_det = 0.0;
                    c.lambda.scale_in_place(0.0);
                    for i in 0..self.cfg.dim {
                        let s2 = self.sigma_ini[i] * self.sigma_ini[i];
                        c.lambda[(i, i)] = 1.0 / s2;
                        log_det += s2.ln();
                    }
                    c.log_det = log_det;
                }
            }
        }
        self.buf_d2 = d2;
    }

    fn prune(&mut self) {
        if !self.cfg.prune {
            return;
        }
        let (v_min, sp_min) = (self.cfg.v_min, self.cfg.sp_min);
        if self.comps.len() > 1 {
            self.comps.retain(|c| !(c.v > v_min && c.sp < sp_min));
        }
        // Priors (Eq. 12) are derived from sp on demand; nothing else to
        // renormalize.
    }
}

impl IncrementalMixture for Figmn {
    fn learn(&mut self, x: &[f64]) -> LearnOutcome {
        assert_eq!(x.len(), self.cfg.dim, "learn: dimensionality mismatch");
        self.points += 1;
        if self.comps.is_empty() {
            self.create(x);
            return LearnOutcome::Created;
        }
        self.distances_into(x);
        let accept = self
            .buf_d2
            .iter()
            .any(|&d2| d2 < self.cfg.chi2_threshold());
        let cap_full =
            self.cfg.max_components > 0 && self.comps.len() >= self.cfg.max_components;
        if accept || cap_full {
            self.update_all(x);
            self.prune();
            LearnOutcome::Updated
        } else {
            self.create(x);
            self.prune();
            LearnOutcome::Created
        }
    }

    fn num_components(&self) -> usize {
        self.comps.len()
    }

    fn dim(&self) -> usize {
        self.cfg.dim
    }

    fn predict(&self, known_vals: &[f64], known_idx: &[usize], target_idx: &[usize]) -> Vec<f64> {
        assert_eq!(known_vals.len(), known_idx.len());
        assert!(!self.comps.is_empty(), "predict on empty model");
        let mut log_liks = Vec::with_capacity(self.comps.len());
        let mut sps = Vec::with_capacity(self.comps.len());
        let mut recons: Vec<Vec<f64>> = Vec::with_capacity(self.comps.len());
        for c in &self.comps {
            let r = precision_conditional(
                &c.lambda,
                &c.mean,
                c.log_det,
                known_vals,
                known_idx,
                target_idx,
            );
            log_liks.push(r.log_lik);
            sps.push(c.sp);
            recons.push(r.reconstruction);
        }
        let post = softmax_posteriors(&log_liks, &sps); // Eq. 14
        let mut out = vec![0.0; target_idx.len()];
        for (p, r) in post.iter().zip(recons.iter()) {
            for (o, &v) in out.iter_mut().zip(r.iter()) {
                *o += p * v; // Eq. 27 mixture
            }
        }
        out
    }

    fn log_density(&self, x: &[f64]) -> f64 {
        assert!(!self.comps.is_empty());
        let total_sp: f64 = self.comps.iter().map(|c| c.sp).sum();
        let mut best = f64::NEG_INFINITY;
        let mut terms = Vec::with_capacity(self.comps.len());
        let mut e = vec![0.0; self.cfg.dim];
        for c in &self.comps {
            sub_into(x, &c.mean, &mut e);
            let d2 = c.lambda.quad_form(&e);
            let t = log_gaussian(d2, c.log_det, self.cfg.dim) + (c.sp / total_sp).ln();
            terms.push(t);
            best = best.max(t);
        }
        if !best.is_finite() {
            return f64::NEG_INFINITY;
        }
        best + terms.iter().map(|t| (t - best).exp()).sum::<f64>().ln()
    }

    fn posteriors(&self, x: &[f64]) -> Vec<f64> {
        let mut ll = Vec::with_capacity(self.comps.len());
        let mut sp = Vec::with_capacity(self.comps.len());
        let mut e = vec![0.0; self.cfg.dim];
        for c in &self.comps {
            sub_into(x, &c.mean, &mut e);
            ll.push(log_gaussian(c.lambda.quad_form(&e), c.log_det, self.cfg.dim));
            sp.push(c.sp);
        }
        softmax_posteriors(&ll, &sp)
    }

    fn points_seen(&self) -> u64 {
        self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;

    fn two_cluster_data() -> Vec<[f64; 2]> {
        // Two tight clusters far apart.
        let mut pts = Vec::new();
        for i in 0..20 {
            let t = i as f64 * 0.01;
            pts.push([t, -t]);
            pts.push([10.0 + t, 10.0 - t]);
        }
        pts
    }

    fn trained() -> Figmn {
        let cfg = GmmConfig::new(2).with_delta(0.3).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        for p in two_cluster_data() {
            m.learn(&p);
        }
        m
    }

    #[test]
    fn discovers_two_clusters() {
        let m = trained();
        assert_eq!(m.num_components(), 2);
    }

    #[test]
    fn first_point_creates() {
        let cfg = GmmConfig::new(2);
        let mut m = Figmn::new(cfg, &[1.0, 1.0]);
        assert_eq!(m.learn(&[0.0, 0.0]), LearnOutcome::Created);
        assert_eq!(m.num_components(), 1);
        assert_eq!(m.points_seen(), 1);
    }

    #[test]
    fn beta_zero_never_creates_second() {
        let cfg = GmmConfig::new(2).with_beta(0.0).with_delta(1.0).without_pruning();
        let mut m = Figmn::new(cfg, &[1.0, 1.0]);
        m.learn(&[0.0, 0.0]);
        for p in two_cluster_data() {
            assert_eq!(m.learn(&p), LearnOutcome::Updated);
        }
        assert_eq!(m.num_components(), 1);
    }

    #[test]
    fn sp_accumulates_posterior_mass() {
        let m = trained();
        let total_sp: f64 = (0..m.num_components()).map(|j| m.component_stats(j).0).sum();
        // Each learn() adds exactly 1 total posterior mass; creations add 1.
        assert!((total_sp - m.points_seen() as f64).abs() < 1e-9);
    }

    #[test]
    fn priors_sum_to_one() {
        let m = trained();
        let s: f64 = (0..m.num_components()).map(|j| m.prior(j)).sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lambda_stays_pd_and_logdet_consistent() {
        let m = trained();
        for j in 0..m.num_components() {
            let lam = m.component_lambda(j);
            let ch = Cholesky::new(lam).expect("Λ must stay PD");
            // log|C| = −log|Λ|
            let log_det_c = -ch.log_det();
            assert!(
                (log_det_c - m.component_log_det(j)).abs() < 1e-6,
                "tracked log|C| diverged: {} vs {}",
                log_det_c,
                m.component_log_det(j)
            );
        }
    }

    #[test]
    fn predict_reconstructs_cluster_partner() {
        let m = trained();
        // Within cluster A, y ≈ −x; within B, y ≈ 20 − x.
        let y = m.predict(&[0.05], &[0], &[1]);
        assert!((y[0] + 0.05).abs() < 0.2, "got {}", y[0]);
        let y = m.predict(&[10.05], &[0], &[1]);
        assert!((y[0] - 9.95).abs() < 0.2, "got {}", y[0]);
    }

    #[test]
    fn posteriors_pick_right_cluster() {
        let m = trained();
        let p = m.posteriors(&[0.1, -0.1]);
        let q = m.posteriors(&[10.1, 9.9]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // The two points must prefer different components.
        let a = p.iter().cloned().fold((0, f64::MIN, 0usize), |(i, b, bi), v| {
            if v > b { (i + 1, v, i) } else { (i + 1, b, bi) }
        }).2;
        let b = q.iter().cloned().fold((0, f64::MIN, 0usize), |(i, bb, bi), v| {
            if v > bb { (i + 1, v, i) } else { (i + 1, bb, bi) }
        }).2;
        assert_ne!(a, b);
    }

    #[test]
    fn log_density_higher_on_data() {
        let m = trained();
        assert!(m.log_density(&[0.0, 0.0]) > m.log_density(&[5.0, 5.0]));
    }

    #[test]
    fn pruning_removes_spurious() {
        let cfg = GmmConfig::new(2).with_delta(0.05).with_beta(0.2).with_pruning(3, 2.0);
        let mut m = Figmn::new(cfg, &[5.0, 5.0]);
        // One outlier creates a component that never fires again…
        m.learn(&[100.0, 100.0]);
        // …then a long, tight stream elsewhere.
        for i in 0..50 {
            let t = (i % 10) as f64 * 0.01;
            m.learn(&[t, t]);
        }
        // The outlier component must have been pruned.
        for j in 0..m.num_components() {
            assert!(m.component_mean(j)[0] < 50.0);
        }
    }

    #[test]
    fn max_components_caps() {
        let cfg = GmmConfig::new(1).with_beta(0.5).with_delta(0.001).with_max_components(3).without_pruning();
        let mut m = Figmn::new(cfg, &[1.0]);
        for i in 0..50 {
            m.learn(&[i as f64 * 100.0]); // every point is novel
        }
        assert_eq!(m.num_components(), 3);
    }

    #[test]
    #[should_panic]
    fn learn_rejects_wrong_dim() {
        let mut m = Figmn::new(GmmConfig::new(3), &[1.0, 1.0, 1.0]);
        m.learn(&[1.0]);
    }
}
