//! Flat structure-of-arrays storage for the mixture's components.
//!
//! The pre-refactor layout was an array of structs — each component a
//! heap `Vec` for its mean plus a dense `Matrix` for its symmetric
//! matrix — which scattered the learn hot path's working set across K
//! allocations and stored every symmetric matrix twice over. A
//! [`ComponentStore`] instead owns all mixture state in six contiguous
//! arenas:
//!
//! - `means` — `K×D` row-major,
//! - `mats` — `K×D(D+1)/2` **packed upper-triangular symmetric**
//!   matrices (`Λ` for the precision path, `C` for the covariance
//!   baseline; see [`crate::linalg::packed`] for layout and the
//!   bit-identity contract of the packed kernels),
//! - `log_dets`, `sps`, `vs` — `K` scalars each,
//! - `stamps` — `K` stream positions, the drift bookkeeping behind the
//!   max-age eviction arm of [`ComponentStore::prune_aged`].
//!
//! Component `j` is row `j` of every arena, so the engine's contiguous
//! component shards map to contiguous arena slices — each worker
//! streams its rows sequentially, and the packed matrices halve the
//! bytes per sweep (the `layout_bandwidth` bench quantifies this).
//!
//! Lifecycle: `create` is an arena row append ([`ComponentStore::push`]);
//! the §2.3 prune is a stable in-place compaction (plus a swap+truncate
//! when only the strongest component survives) — **order-preserving**,
//! exactly like the pre-refactor `Vec::retain`, because component order
//! feeds the deterministic tree reductions and must not depend on the
//! storage layout.
//!
//! Publishing a read snapshot is `Clone` — six `memcpy`s, no
//! per-component traversal.
//!
//! ## Capacity reservation
//!
//! The engine's sharded passes stream the arenas through raw base
//! pointers ([`StoreRawMut`]), so a `push` that reallocates an arena
//! would leave any outstanding raw view dangling — and even off the
//! engine path, mid-stream reallocation moves the hot rows. Models
//! therefore reserve up front: [`ComponentStore::with_capacity`] sizes
//! all six arenas for `max_components` rows (or a growth hint), and
//! [`ComponentStore::push`] grows all arenas *together*, geometrically,
//! when unreserved — O(log K) moves over a stream instead of per-arena
//! drift. A generation counter (bumped by every push/truncate) lets
//! [`StoreRawMut::row_mut`] assert in debug builds that no such
//! mutation happened while a raw view was live.

use crate::engine::SharedMut;
use crate::linalg::packed;
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What the packed per-component matrices of a store semantically are —
/// drives the byte accounting: the precision path (`Figmn`) tracks
/// `log|C|` per component, while the covariance baseline (`Igmn`)
/// derives determinants from each factorization, so its `log_dets` lane
/// carries no model state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatKind {
    /// Matrices are precisions `Λ = C⁻¹`; `log_dets` is live state.
    Precision,
    /// Matrices are covariances `C`; `log_dets` is unused padding.
    Covariance,
}

/// All mixture component state, in flat contiguous arenas (see the
/// module docs). Shared by `Figmn` (matrices are precisions `Λ`) and
/// `Igmn` (matrices are covariances `C`; `log_dets` stays unused).
#[derive(Debug)]
pub struct ComponentStore {
    dim: usize,
    /// Packed matrix row length `D·(D+1)/2`.
    tri: usize,
    kind: MatKind,
    /// Bumped by every mutation that can change K or move the arenas
    /// (push/truncate/reserve); [`StoreRawMut`] snapshots it so stale
    /// raw views are caught in debug builds. Shared-ownership atomic so
    /// the guard's read has provenance *independent* of the `&mut self`
    /// borrows it detects (sound under Stacked/Tree Borrows — a plain
    /// field pointer would itself be invalidated by the very mutation
    /// it is trying to catch).
    generation: Arc<AtomicU64>,
    means: Vec<f64>,
    mats: Vec<f64>,
    log_dets: Vec<f64>,
    sps: Vec<f64>,
    vs: Vec<u64>,
    /// Last-refresh stream position per component: the index of the last
    /// learned point this component *won* (took the argmax posterior),
    /// or its creation position while it has won nothing since. Drift
    /// bookkeeping for [`ComponentStore::prune_aged`] — not serialized
    /// model state, and (like the generation counter) excluded from
    /// `PartialEq`, so a checkpoint round-trip that re-stamps survivors
    /// still compares equal.
    stamps: Vec<u64>,
}

/// A clone is an independent store (the snapshot path): fresh data
/// buffers and a fresh staleness domain — mutating the original must
/// not invalidate views of the clone or vice versa.
impl Clone for ComponentStore {
    fn clone(&self) -> ComponentStore {
        ComponentStore {
            dim: self.dim,
            tri: self.tri,
            kind: self.kind,
            generation: Arc::new(AtomicU64::new(0)),
            means: self.means.clone(),
            mats: self.mats.clone(),
            log_dets: self.log_dets.clone(),
            sps: self.sps.clone(),
            vs: self.vs.clone(),
            stamps: self.stamps.clone(),
        }
    }
}

impl ComponentStore {
    /// Empty store for `dim`-dimensional components (precision variant).
    pub fn new(dim: usize) -> ComponentStore {
        ComponentStore::new_with_kind(dim, MatKind::Precision)
    }

    /// Empty store whose matrices are covariances (the `Igmn` baseline).
    pub fn new_covariance(dim: usize) -> ComponentStore {
        ComponentStore::new_with_kind(dim, MatKind::Covariance)
    }

    fn new_with_kind(dim: usize, kind: MatKind) -> ComponentStore {
        assert!(dim > 0, "ComponentStore: dim must be positive");
        ComponentStore {
            dim,
            tri: packed::packed_len(dim),
            kind,
            generation: Arc::new(AtomicU64::new(0)),
            means: Vec::new(),
            mats: Vec::new(),
            log_dets: Vec::new(),
            sps: Vec::new(),
            vs: Vec::new(),
            stamps: Vec::new(),
        }
    }

    /// Empty precision store with all five arenas pre-sized for `rows`
    /// components, so the first `rows` pushes never reallocate (and
    /// never move the hot rows mid-stream).
    pub fn with_capacity(dim: usize, rows: usize) -> ComponentStore {
        let mut s = ComponentStore::new(dim);
        s.reserve(rows);
        s
    }

    /// Covariance-variant [`ComponentStore::with_capacity`].
    pub fn with_capacity_covariance(dim: usize, rows: usize) -> ComponentStore {
        let mut s = ComponentStore::new_covariance(dim);
        s.reserve(rows);
        s
    }

    /// Reserve room for at least `additional` more component rows in
    /// every arena. Reserving does not move live rows' *values*, but it
    /// may reallocate (and move) the arenas, so it bumps the generation:
    /// any outstanding [`StoreRawMut`] view is stale afterwards, and the
    /// debug guard in [`StoreRawMut::row_mut`] will catch it.
    pub fn reserve(&mut self, additional: usize) {
        self.means.reserve(additional * self.dim);
        self.mats.reserve(additional * self.tri);
        self.log_dets.reserve(additional);
        self.sps.reserve(additional);
        self.vs.reserve(additional);
        self.stamps.reserve(additional);
        self.generation.fetch_add(1, Ordering::Release);
    }

    /// How many rows to reserve eagerly for a model capped at `rows`
    /// components: the full cap while the arena footprint stays within
    /// a fixed budget (so `push` never reallocates for bounded models
    /// of ordinary size), clamped so a generous defensive cap at large
    /// `D` — where one packed row alone is megabytes — does not commit
    /// gigabytes up front for components that may never exist. Beyond
    /// the clamp, [`ComponentStore::push`]'s lock-step geometric growth
    /// takes over.
    pub(crate) fn bounded_reservation_rows(dim: usize, rows: usize) -> usize {
        // Eager-reservation budget per model (bytes of arena payload).
        const RESERVE_BYTES_CAP: usize = 256 << 20;
        let tri = packed::packed_len(dim);
        let row_bytes =
            (dim + tri + 2) * std::mem::size_of::<f64>() + 2 * std::mem::size_of::<u64>();
        rows.min((RESERVE_BYTES_CAP / row_bytes).max(1))
    }

    /// Component rows that fit before *any* arena must reallocate.
    pub fn capacity_rows(&self) -> usize {
        (self.means.capacity() / self.dim)
            .min(self.mats.capacity() / self.tri)
            .min(self.log_dets.capacity())
            .min(self.sps.capacity())
            .min(self.vs.capacity())
            .min(self.stamps.capacity())
    }

    /// Number of live components `K`.
    pub fn len(&self) -> usize {
        self.sps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sps.is_empty()
    }

    /// Joint dimensionality `D`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Packed matrix length per component, `D·(D+1)/2`.
    pub fn mat_len(&self) -> usize {
        self.tri
    }

    /// Append a component row to every arena. `mat` is packed
    /// upper-triangular (length `D·(D+1)/2`). The fresh row's refresh
    /// stamp starts at 0; age-tracking callers re-stamp it with the
    /// current stream position via [`ComponentStore::set_stamp`].
    ///
    /// When the reservation is exhausted, all six arenas grow together
    /// (geometric doubling, minimum 8 rows) so their capacities stay in
    /// lock-step and a stream of creates moves the hot rows at most
    /// O(log K) times. Bumps the generation: any [`StoreRawMut`] view
    /// taken before this call is stale afterwards.
    pub(crate) fn push(&mut self, mean: &[f64], mat: &[f64], log_det: f64, sp: f64, v: u64) {
        assert_eq!(mean.len(), self.dim, "push: mean length");
        assert_eq!(mat.len(), self.tri, "push: packed matrix length");
        if self.len() >= self.capacity_rows() {
            self.reserve(self.len().max(8));
        }
        self.means.extend_from_slice(mean);
        self.mats.extend_from_slice(mat);
        self.log_dets.push(log_det);
        self.sps.push(sp);
        self.vs.push(v);
        self.stamps.push(0);
        self.generation.fetch_add(1, Ordering::Release);
    }

    /// Mean of component `j` (row `j` of the means arena).
    pub fn mean(&self, j: usize) -> &[f64] {
        &self.means[j * self.dim..(j + 1) * self.dim]
    }

    /// Packed symmetric matrix of component `j`.
    pub fn mat(&self, j: usize) -> &[f64] {
        &self.mats[j * self.tri..(j + 1) * self.tri]
    }

    /// Dense expansion of component `j`'s matrix (interop/tests; the
    /// hot paths never unpack).
    pub fn mat_dense(&self, j: usize) -> Matrix {
        packed::unpack_symmetric(self.mat(j), self.dim)
    }

    pub fn log_det(&self, j: usize) -> f64 {
        self.log_dets[j]
    }

    pub fn sp(&self, j: usize) -> f64 {
        self.sps[j]
    }

    pub fn v(&self, j: usize) -> u64 {
        self.vs[j]
    }

    /// The whole `sp` arena (posterior priors are derived from it).
    pub fn sps(&self) -> &[f64] {
        &self.sps
    }

    /// `Σ sp` with the same left-fold the array-of-structs path used,
    /// so priors come out bit-identical.
    pub fn total_sp(&self) -> f64 {
        self.sps.iter().sum()
    }

    /// Last-refresh stream position of component `j` (see
    /// [`ComponentStore::set_stamp`]).
    pub fn stamp(&self, j: usize) -> u64 {
        self.stamps[j]
    }

    /// Record that component `j` was refreshed at stream position `t`.
    /// The models stamp the posterior-argmax winner of every learned
    /// point plus every freshly created component, so `now − stamp(j)`
    /// is "points since `j` last won a point" — the age that the
    /// max-age arm of [`ComponentStore::prune_aged`] tests.
    pub(crate) fn set_stamp(&mut self, j: usize, t: u64) {
        self.stamps[j] = t;
    }

    /// Re-stamp every component to `t`. Checkpoint restore uses this:
    /// refresh stamps are bookkeeping rather than serialized model
    /// state, so survivors restart their eviction clocks at the restore
    /// point instead of being mass-evicted on the first prune.
    pub(crate) fn reset_stamps(&mut self, t: u64) {
        for s in &mut self.stamps {
            *s = t;
        }
    }

    /// Multiply every accumulator `sp` by `factor` — the exponential
    /// forgetting step of the drift-adaptive learn modes — and decay
    /// the integer ages `v` alongside, truncating toward zero. Decaying
    /// both keeps the §2.3 spuriousness gate (`v > v_min && sp <
    /// sp_min`) comparing a count and a mass from the same forgetting
    /// window, instead of a lifetime count against decayed mass. One
    /// sweep over the two scalar arenas; callers only invoke this when
    /// `decay < 1.0`, so the decay-off path stays byte-identical.
    pub(crate) fn decay_sps(&mut self, factor: f64) {
        for sp in &mut self.sps {
            *sp *= factor;
        }
        for v in &mut self.vs {
            *v = (*v as f64 * factor) as u64;
        }
    }

    /// Disjoint mutable views of row `j` across all arenas:
    /// `(mean, mat, log_det, sp, v)`.
    pub(crate) fn row_mut(
        &mut self,
        j: usize,
    ) -> (&mut [f64], &mut [f64], &mut f64, &mut f64, &mut u64) {
        let d = self.dim;
        let t = self.tri;
        (
            &mut self.means[j * d..(j + 1) * d],
            &mut self.mats[j * t..(j + 1) * t],
            &mut self.log_dets[j],
            &mut self.sps[j],
            &mut self.vs[j],
        )
    }

    /// Current arena generation — bumped by every push/truncate/reserve
    /// (anything that may reallocate or change the row set). The
    /// candidate index keys its freshness off this counter.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Raw-pointer view for the engine's sharded update pass: each
    /// worker mutates only the rows of its own contiguous component
    /// shard (see [`StoreRawMut::row_mut`]'s safety contract). The view
    /// snapshots the store generation; `row_mut` debug-asserts it is
    /// still current, catching any push/truncate (and therefore any
    /// possible arena reallocation) that slipped in while the raw base
    /// pointers were live.
    pub(crate) fn raw_mut(&mut self) -> StoreRawMut {
        StoreRawMut {
            dim: self.dim,
            tri: self.tri,
            gen_seen: self.generation.load(Ordering::Acquire),
            gen_live: self.generation.clone(),
            means: SharedMut::new(self.means.as_mut_ptr()),
            mats: SharedMut::new(self.mats.as_mut_ptr()),
            log_dets: SharedMut::new(self.log_dets.as_mut_ptr()),
            sps: SharedMut::new(self.sps.as_mut_ptr()),
            vs: SharedMut::new(self.vs.as_mut_ptr()),
        }
    }

    /// Swap rows `a` and `b` in every arena — bulk `split_at_mut` +
    /// `swap_with_slice` per arena (one bounds check each) instead of
    /// the per-element `Vec::swap` walk.
    pub(crate) fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let d = self.dim;
        let t = self.tri;
        {
            let (head, tail) = self.means.split_at_mut(hi * d);
            head[lo * d..(lo + 1) * d].swap_with_slice(&mut tail[..d]);
        }
        {
            let (head, tail) = self.mats.split_at_mut(hi * t);
            head[lo * t..(lo + 1) * t].swap_with_slice(&mut tail[..t]);
        }
        self.log_dets.swap(lo, hi);
        self.sps.swap(lo, hi);
        self.vs.swap(lo, hi);
        self.stamps.swap(lo, hi);
    }

    /// Overwrite row `dst` with row `src` (compaction helper). Already
    /// bulk moves: `copy_within` is a `memmove` per arena, the
    /// row-granular analogue of `swap_rows`' `swap_with_slice`.
    fn copy_row(&mut self, src: usize, dst: usize) {
        let d = self.dim;
        let t = self.tri;
        self.means.copy_within(src * d..(src + 1) * d, dst * d);
        self.mats.copy_within(src * t..(src + 1) * t, dst * t);
        self.log_dets[dst] = self.log_dets[src];
        self.sps[dst] = self.sps[src];
        self.vs[dst] = self.vs[src];
        self.stamps[dst] = self.stamps[src];
    }

    /// Drop every row past the first `k`. Bumps the generation (K
    /// changes), invalidating outstanding [`StoreRawMut`] views.
    pub(crate) fn truncate(&mut self, k: usize) {
        self.means.truncate(k * self.dim);
        self.mats.truncate(k * self.tri);
        self.log_dets.truncate(k);
        self.sps.truncate(k);
        self.vs.truncate(k);
        self.stamps.truncate(k);
        self.generation.fetch_add(1, Ordering::Release);
    }

    /// The §2.3 spuriousness sweep shared by both variants: remove every
    /// component with `v > v_min && sp < sp_min` — except that the
    /// mixture is never allowed to empty. When *every* component trips
    /// the predicate at once (possible on short/adversarial streams),
    /// the single strongest component — highest `sp`, lowest index on
    /// ties — survives, so densities/predictions and the `sp/Σsp`
    /// priors stay well-defined. Survivors keep their relative order
    /// (stable compaction, like the pre-refactor `Vec::retain`), so
    /// pruning is layout-invariant. Both `Figmn` and `Igmn` funnel
    /// through this one function, so their prune decisions are
    /// identical by construction (the paper's §4 equivalence).
    ///
    /// Returns how many components were removed.
    pub(crate) fn prune(&mut self, v_min: u64, sp_min: f64) -> usize {
        self.prune_aged(v_min, sp_min, 0, 0)
    }

    /// [`ComponentStore::prune`] with the drift-adaptive max-age arm: a
    /// component is additionally doomed when `max_age > 0` and more than
    /// `max_age` points have passed since it last won a point
    /// (`now − stamp > max_age`; see [`ComponentStore::set_stamp`]).
    /// Both arms share the same machinery — the never-empty
    /// keep-strongest fallback and the order-preserving stable
    /// compaction — so age eviction composes with the §2.3 sweep
    /// without changing its layout-invariance guarantees. Callers that
    /// want the age arm alone pass `v_min = u64::MAX`, which makes the
    /// spuriousness predicate vacuously false.
    ///
    /// Returns how many components were removed.
    pub(crate) fn prune_aged(
        &mut self,
        v_min: u64,
        sp_min: f64,
        max_age: u64,
        now: u64,
    ) -> usize {
        let k = self.len();
        if k <= 1 {
            return 0;
        }
        let doomed = |sp: f64, v: u64, stamp: u64| {
            (v > v_min && sp < sp_min) || (max_age > 0 && now.saturating_sub(stamp) > max_age)
        };
        if (0..k).all(|j| doomed(self.sps[j], self.vs[j], self.stamps[j])) {
            let mut keep = 0usize;
            let mut best = self.sps[0];
            for (j, &s) in self.sps.iter().enumerate().skip(1) {
                if s > best {
                    best = s;
                    keep = j;
                }
            }
            self.swap_rows(0, keep);
            self.truncate(1);
        } else {
            let mut w = 0usize;
            for j in 0..k {
                if doomed(self.sps[j], self.vs[j], self.stamps[j]) {
                    continue;
                }
                if w != j {
                    self.copy_row(j, w);
                }
                w += 1;
            }
            self.truncate(w);
        }
        k - self.len()
    }

    /// Model-state bytes one component occupies, **variant-aware**: `D`
    /// mean + `D(D+1)/2` packed matrix + `sp` floats + the `u64` age
    /// and the `u64` refresh stamp, plus the tracked `log_det` float on
    /// the precision path only —
    /// the covariance baseline documents that lane as unused (it
    /// derives determinants from each factorization), so counting it
    /// would overstate `Igmn` memory in `WorkerStats`/registry stats.
    /// The dense array-of-structs layout paid `D²` matrix floats (plus
    /// two heap headers) for the same state — about 2× this at large
    /// `D`.
    pub fn bytes_per_component(&self) -> usize {
        let scalars = match self.kind {
            MatKind::Precision => 2, // log_det + sp
            MatKind::Covariance => 1, // sp only
        };
        (self.dim + self.tri + scalars) * std::mem::size_of::<f64>()
            + 2 * std::mem::size_of::<u64>()
    }

    /// Total model-state bytes for the live mixture (see
    /// [`ComponentStore::bytes_per_component`] for what counts).
    pub fn model_bytes(&self) -> usize {
        self.len() * self.bytes_per_component()
    }

    /// Payload bytes one component occupied in the pre-refactor dense
    /// array-of-structs layout (`D` mean + `D²` matrix + 2 scalar
    /// floats + the `u64` age and the `u64` refresh stamp — the same
    /// scalar bookkeeping as the packed layout, so only the matrix
    /// layout differs) — the baseline the layout benches compare
    /// [`ComponentStore::bytes_per_component`] against.
    pub fn dense_equivalent_bytes(dim: usize) -> usize {
        (dim + dim * dim + 2) * std::mem::size_of::<f64>() + 2 * std::mem::size_of::<u64>()
    }
}

/// Stores are equal when they hold the same components of the same
/// variant — the generation (a history counter) and the refresh stamps
/// (eviction bookkeeping, reset on checkpoint restore) deliberately do
/// not participate, so e.g. a pruned store equals a freshly built one
/// with the same survivors.
impl PartialEq for ComponentStore {
    fn eq(&self, other: &ComponentStore) -> bool {
        self.dim == other.dim
            && self.kind == other.kind
            && self.means == other.means
            && self.mats == other.mats
            && self.log_dets == other.log_dets
            && self.sps == other.sps
            && self.vs == other.vs
    }
}

/// Raw-pointer row access for the engine's sharded update pass; cheap
/// to clone, and the shard closure captures it by value.
#[derive(Clone)]
pub(crate) struct StoreRawMut {
    dim: usize,
    tri: usize,
    /// Store generation when this view was taken.
    gen_seen: u64,
    /// The store's live generation counter. Shared ownership (not a
    /// pointer derived from the store borrow), so reading it stays
    /// sound even after a `&mut ComponentStore` mutation invalidated
    /// the arena base pointers — which is exactly the situation the
    /// guard exists to catch.
    gen_live: Arc<AtomicU64>,
    means: SharedMut<f64>,
    mats: SharedMut<f64>,
    log_dets: SharedMut<f64>,
    sps: SharedMut<f64>,
    vs: SharedMut<u64>,
}

impl StoreRawMut {
    /// Mutable views of row `j`: `(mean, mat, log_det, sp, v)`.
    ///
    /// # Safety
    /// `j` must be in bounds of the source store, no other thread may
    /// access row `j` during the same engine pass (guaranteed when `j`
    /// comes from the pool's disjoint shard ranges), and the store must
    /// not have been mutated through `&mut self` methods since
    /// `raw_mut` — a push could have reallocated the arenas out from
    /// under these base pointers. Debug builds assert the last
    /// condition via the generation counter.
    pub unsafe fn row_mut(
        &self,
        j: usize,
    ) -> (&mut [f64], &mut [f64], &mut f64, &mut f64, &mut u64) {
        debug_assert!(
            self.gen_live.load(Ordering::Acquire) == self.gen_seen,
            "StoreRawMut is stale: the store was mutated (push/truncate/reserve) while raw \
             arena base pointers were live — K and arena capacities must be frozen for the \
             lifetime of a StoreRawMut"
        );
        (
            self.means.slice(j * self.dim, self.dim),
            self.mats.slice(j * self.tri, self.tri),
            &mut *self.log_dets.at(j),
            &mut *self.sps.at(j),
            &mut *self.vs.at(j),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(rows: &[(f64, f64, u64)]) -> ComponentStore {
        // 2-D store; mean/diag tagged by the row's sp so moves are visible.
        let mut s = ComponentStore::new(2);
        for &(tag, sp, v) in rows {
            let mean = [tag, -tag];
            let mat = packed::from_diag(&[tag, tag]);
            s.push(&mean, &mat, tag.ln(), sp, v);
        }
        s
    }

    #[test]
    fn push_and_accessors() {
        let s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.dim(), 2);
        assert_eq!(s.mat_len(), 3);
        assert_eq!(s.mean(1), &[4.0, -4.0]);
        assert_eq!(s.mat(1), &[4.0, 0.0, 4.0]);
        assert_eq!(s.log_det(1), 4.0f64.ln());
        assert_eq!(s.sp(0), 2.0);
        assert_eq!(s.v(0), 3);
        assert_eq!(s.sps(), &[2.0, 5.0]);
        assert_eq!(s.total_sp(), 7.0);
        let dense = s.mat_dense(0);
        assert_eq!(dense.as_slice(), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn row_mut_is_disjoint_per_field() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        {
            let (mean, mat, log_det, sp, v) = s.row_mut(0);
            mean[0] = 9.0;
            mat[2] = 8.0;
            *log_det = 7.0;
            *sp = 6.0;
            *v = 5;
        }
        assert_eq!(s.mean(0), &[9.0, -1.0]);
        assert_eq!(s.mat(0), &[1.0, 0.0, 8.0]);
        assert_eq!(s.log_det(0), 7.0);
        assert_eq!(s.sp(0), 6.0);
        assert_eq!(s.v(0), 5);
        // Row 1 untouched.
        assert_eq!(s.mean(1), &[4.0, -4.0]);
    }

    #[test]
    fn swap_and_truncate() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6), (7.0, 8.0, 9)]);
        s.swap_rows(0, 2);
        assert_eq!(s.mean(0), &[7.0, -7.0]);
        assert_eq!(s.sp(0), 8.0);
        assert_eq!(s.mean(2), &[1.0, -1.0]);
        s.truncate(1);
        assert_eq!(s.len(), 1);
        assert_eq!(s.mean(0), &[7.0, -7.0]);
        assert_eq!(s.mat(0), &[7.0, 0.0, 7.0]);
    }

    #[test]
    fn prune_is_stable_and_order_preserving() {
        // Rows 1 and 3 are doomed (v > 1, sp < 4); survivors keep order.
        let mut s = store_with(&[(1.0, 5.0, 0), (2.0, 1.0, 3), (3.0, 6.0, 4), (4.0, 2.0, 5)]);
        let removed = s.prune(1, 4.0);
        assert_eq!(removed, 2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.mean(0), &[1.0, -1.0]);
        assert_eq!(s.mean(1), &[3.0, -3.0]);
        assert_eq!(s.sps(), &[5.0, 6.0]);
        assert_eq!(s.v(1), 4);
    }

    #[test]
    fn prune_keeps_strongest_when_all_doomed() {
        let mut s = store_with(&[(1.0, 0.5, 9), (2.0, 2.5, 9), (3.0, 2.5, 9)]);
        let removed = s.prune(1, 100.0);
        assert_eq!(removed, 2);
        assert_eq!(s.len(), 1);
        // Highest sp, lowest index on ties → row 1 (tag 2.0).
        assert_eq!(s.mean(0), &[2.0, -2.0]);
        assert_eq!(s.sp(0), 2.5);
    }

    #[test]
    fn prune_never_empties_single_component() {
        let mut s = store_with(&[(1.0, 0.1, 99)]);
        assert_eq!(s.prune(0, 1e9), 0);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn clone_is_independent_bulk_copy() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let snap = s.clone();
        let (mean, ..) = s.row_mut(0);
        mean[0] = 100.0;
        assert_eq!(snap.mean(0), &[1.0, -1.0], "clone must not alias");
        assert_eq!(snap, store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]));
    }

    #[test]
    fn byte_accounting_tracks_packed_layout() {
        // Precision variant: D=2 → 2 mean + 3 packed + log_det + sp
        // floats, + u64 age + u64 refresh stamp.
        let s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        assert_eq!(s.bytes_per_component(), 7 * 8 + 16);
        assert_eq!(s.model_bytes(), 2 * s.bytes_per_component());
        // The packed matrix is strictly smaller than dense for D ≥ 2.
        assert!(s.mat_len() < s.dim() * s.dim());

        // Covariance variant: the unused log_det lane is not billed —
        // one f64 less per component than the precision variant.
        let mut c = ComponentStore::new_covariance(2);
        c.push(&[0.0, 0.0], &packed::from_diag(&[1.0, 1.0]), 0.0, 1.0, 1);
        c.push(&[1.0, 1.0], &packed::from_diag(&[2.0, 2.0]), 0.0, 1.0, 1);
        assert_eq!(c.bytes_per_component(), 6 * 8 + 16);
        assert_eq!(c.bytes_per_component() + 8, s.bytes_per_component());
        assert_eq!(c.model_bytes(), 2 * c.bytes_per_component());
    }

    #[test]
    fn stamps_follow_row_moves() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6), (7.0, 8.0, 9)]);
        assert_eq!(s.stamp(0), 0, "push starts fresh rows at stamp 0");
        s.set_stamp(0, 10);
        s.set_stamp(1, 20);
        s.set_stamp(2, 30);
        s.swap_rows(0, 2);
        assert_eq!(s.stamp(0), 30);
        assert_eq!(s.stamp(2), 10);
        s.truncate(2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.stamp(1), 20);
        s.reset_stamps(77);
        assert_eq!((s.stamp(0), s.stamp(1)), (77, 77));
    }

    #[test]
    fn decay_scales_every_sp_and_v() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        s.decay_sps(0.5);
        assert_eq!(s.sps(), &[1.0, 2.5]);
        assert_eq!(s.total_sp(), 3.5);
        // Ages decay alongside, truncating toward zero: 3·0.5 → 1.
        assert_eq!(s.v(0), 1);
        assert_eq!(s.v(1), 3);
        // Decay touches nothing else.
        assert_eq!(s.mean(0), &[1.0, -1.0]);
    }

    #[test]
    fn prune_aged_evicts_stale_components_and_keeps_order() {
        let mut s = store_with(&[(1.0, 5.0, 0), (2.0, 6.0, 0), (3.0, 7.0, 0)]);
        s.set_stamp(0, 100);
        s.set_stamp(1, 40); // 60 points stale → doomed at max_age 50
        s.set_stamp(2, 90);
        // §2.3 arm disabled via v_min = MAX; only the age arm fires.
        let removed = s.prune_aged(u64::MAX, 0.0, 50, 100);
        assert_eq!(removed, 1);
        assert_eq!(s.sps(), &[5.0, 7.0], "survivors keep their order");
        assert_eq!((s.stamp(0), s.stamp(1)), (100, 90));
        // max_age = 0 disables the arm entirely.
        assert_eq!(s.prune_aged(u64::MAX, 0.0, 0, u64::MAX), 0);
    }

    #[test]
    fn prune_aged_shares_keep_strongest_fallback() {
        // Every component is stale → the highest-sp one still survives.
        let mut s = store_with(&[(1.0, 0.5, 9), (2.0, 2.5, 9), (3.0, 1.5, 9)]);
        let removed = s.prune_aged(u64::MAX, 0.0, 10, 1000);
        assert_eq!(removed, 2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.mean(0), &[2.0, -2.0]);
    }

    #[test]
    fn prune_aged_combines_both_arms() {
        // Row 1 is spurious (v > 1, sp < 4); row 3 is stale; rows 0/2
        // survive both predicates.
        let mut s = store_with(&[(1.0, 5.0, 0), (2.0, 1.0, 3), (3.0, 6.0, 4), (4.0, 9.0, 5)]);
        for j in 0..4 {
            s.set_stamp(j, 100);
        }
        s.set_stamp(3, 10);
        let removed = s.prune_aged(1, 4.0, 50, 100);
        assert_eq!(removed, 2);
        assert_eq!(s.sps(), &[5.0, 6.0]);
    }

    #[test]
    fn reservation_prevents_arena_moves() {
        let rows = 64;
        let mut s = ComponentStore::with_capacity(2, rows);
        assert!(s.capacity_rows() >= rows);
        let mat = packed::from_diag(&[1.0, 1.0]);
        s.push(&[0.0, 0.0], &mat, 0.0, 1.0, 1);
        let base = s.mean(0).as_ptr();
        for i in 1..rows {
            s.push(&[i as f64, 0.0], &mat, 0.0, 1.0, 1);
        }
        assert_eq!(s.len(), rows);
        assert!(
            std::ptr::eq(base, s.mean(0).as_ptr()),
            "reserved arenas must not move across {rows} pushes"
        );
        // reserve() grows room without touching live rows.
        s.reserve(rows);
        assert!(s.capacity_rows() >= 2 * rows);
        assert_eq!(s.mean(1), &[1.0, 0.0]);
    }

    #[test]
    fn eager_reservation_is_budget_clamped() {
        // Ordinary bounded models reserve their full cap…
        assert_eq!(ComponentStore::bounded_reservation_rows(8, 256), 256);
        assert_eq!(ComponentStore::bounded_reservation_rows(64, 1024), 1024);
        // …but at CIFAR-scale D a packed row is megabytes, so a
        // generous defensive cap clamps to the byte budget instead of
        // committing gigabytes up front (never to zero, though).
        let rows = ComponentStore::bounded_reservation_rows(3072, 1024);
        assert!((1..1024).contains(&rows), "clamped rows = {rows}");
        assert_eq!(ComponentStore::bounded_reservation_rows(3072, 0), 0);
    }

    #[test]
    fn unreserved_push_grows_all_arenas_in_lockstep() {
        let mut s = ComponentStore::new(3);
        let mat = packed::from_diag(&[1.0, 1.0, 1.0]);
        let mut growths = 0;
        let mut last_cap = s.capacity_rows();
        for i in 0..100 {
            s.push(&[i as f64, 0.0, 0.0], &mat, 0.0, 1.0, 1);
            // Every arena keeps up with K: the six capacities grow
            // together, geometrically (O(log K) growth events).
            assert!(s.capacity_rows() >= s.len());
            if s.capacity_rows() != last_cap {
                growths += 1;
                last_cap = s.capacity_rows();
            }
        }
        assert!(s.capacity_rows() >= 100);
        assert!(growths <= 8, "expected geometric growth, saw {growths} reallocations");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "StoreRawMut is stale")]
    fn stale_raw_view_is_caught_after_push() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let raw = s.raw_mut();
        // A create while raw base pointers are live: the generation
        // bump makes the next row_mut fail fast instead of risking a
        // dangling-pointer write after a reallocation.
        s.push(&[9.0, 9.0], &packed::from_diag(&[1.0, 1.0]), 0.0, 1.0, 1);
        unsafe {
            let _ = raw.row_mut(0);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "StoreRawMut is stale")]
    fn stale_raw_view_is_caught_after_truncate() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let raw = s.raw_mut();
        s.truncate(1);
        unsafe {
            let _ = raw.row_mut(0);
        }
    }

    #[test]
    fn equality_ignores_generation_history() {
        // A pruned store equals a freshly built one with the same
        // survivors, despite different generation histories.
        let mut pruned = store_with(&[(1.0, 5.0, 0), (2.0, 1.0, 3), (3.0, 6.0, 4)]);
        pruned.prune(1, 4.0);
        let fresh = store_with(&[(1.0, 5.0, 0), (3.0, 6.0, 4)]);
        assert_eq!(pruned, fresh);
        // Variants with identical payloads still differ.
        let cov = ComponentStore::new_covariance(2);
        assert!(ComponentStore::new(2) != cov);
    }

    #[test]
    fn raw_mut_rows_address_the_arenas() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let raw = s.raw_mut();
        unsafe {
            let (mean, mat, log_det, sp, v) = raw.row_mut(1);
            mean[1] = 42.0;
            mat[0] = 41.0;
            *log_det = 40.0;
            *sp = 39.0;
            *v = 38;
        }
        assert_eq!(s.mean(1), &[4.0, 42.0]);
        assert_eq!(s.mat(1), &[41.0, 0.0, 4.0]);
        assert_eq!(s.log_det(1), 40.0);
        assert_eq!(s.sp(1), 39.0);
        assert_eq!(s.v(1), 38);
    }
}
