//! Flat structure-of-arrays storage for the mixture's components.
//!
//! The pre-refactor layout was an array of structs — each component a
//! heap `Vec` for its mean plus a dense `Matrix` for its symmetric
//! matrix — which scattered the learn hot path's working set across K
//! allocations and stored every symmetric matrix twice over. A
//! [`ComponentStore`] instead owns all mixture state in five contiguous
//! arenas:
//!
//! - `means` — `K×D` row-major,
//! - `mats` — `K×D(D+1)/2` **packed upper-triangular symmetric**
//!   matrices (`Λ` for the precision path, `C` for the covariance
//!   baseline; see [`crate::linalg::packed`] for layout and the
//!   bit-identity contract of the packed kernels),
//! - `log_dets`, `sps`, `vs` — `K` scalars each.
//!
//! Component `j` is row `j` of every arena, so the engine's contiguous
//! component shards map to contiguous arena slices — each worker
//! streams its rows sequentially, and the packed matrices halve the
//! bytes per sweep (the `layout_bandwidth` bench quantifies this).
//!
//! Lifecycle: `create` is an arena row append ([`ComponentStore::push`]);
//! the §2.3 prune is a stable in-place compaction (plus a swap+truncate
//! when only the strongest component survives) — **order-preserving**,
//! exactly like the pre-refactor `Vec::retain`, because component order
//! feeds the deterministic tree reductions and must not depend on the
//! storage layout.
//!
//! Publishing a read snapshot is `Clone` — five `memcpy`s, no
//! per-component traversal.

use crate::engine::SharedMut;
use crate::linalg::packed;
use crate::linalg::Matrix;

/// All mixture component state, in flat contiguous arenas (see the
/// module docs). Shared by `Figmn` (matrices are precisions `Λ`) and
/// `Igmn` (matrices are covariances `C`; `log_dets` stays unused).
#[derive(Debug, Clone, PartialEq)]
pub struct ComponentStore {
    dim: usize,
    /// Packed matrix row length `D·(D+1)/2`.
    tri: usize,
    means: Vec<f64>,
    mats: Vec<f64>,
    log_dets: Vec<f64>,
    sps: Vec<f64>,
    vs: Vec<u64>,
}

impl ComponentStore {
    /// Empty store for `dim`-dimensional components.
    pub fn new(dim: usize) -> ComponentStore {
        assert!(dim > 0, "ComponentStore: dim must be positive");
        ComponentStore {
            dim,
            tri: packed::packed_len(dim),
            means: Vec::new(),
            mats: Vec::new(),
            log_dets: Vec::new(),
            sps: Vec::new(),
            vs: Vec::new(),
        }
    }

    /// Number of live components `K`.
    pub fn len(&self) -> usize {
        self.sps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sps.is_empty()
    }

    /// Joint dimensionality `D`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Packed matrix length per component, `D·(D+1)/2`.
    pub fn mat_len(&self) -> usize {
        self.tri
    }

    /// Append a component row to every arena. `mat` is packed
    /// upper-triangular (length `D·(D+1)/2`).
    pub(crate) fn push(&mut self, mean: &[f64], mat: &[f64], log_det: f64, sp: f64, v: u64) {
        assert_eq!(mean.len(), self.dim, "push: mean length");
        assert_eq!(mat.len(), self.tri, "push: packed matrix length");
        self.means.extend_from_slice(mean);
        self.mats.extend_from_slice(mat);
        self.log_dets.push(log_det);
        self.sps.push(sp);
        self.vs.push(v);
    }

    /// Mean of component `j` (row `j` of the means arena).
    pub fn mean(&self, j: usize) -> &[f64] {
        &self.means[j * self.dim..(j + 1) * self.dim]
    }

    /// Packed symmetric matrix of component `j`.
    pub fn mat(&self, j: usize) -> &[f64] {
        &self.mats[j * self.tri..(j + 1) * self.tri]
    }

    /// Dense expansion of component `j`'s matrix (interop/tests; the
    /// hot paths never unpack).
    pub fn mat_dense(&self, j: usize) -> Matrix {
        packed::unpack_symmetric(self.mat(j), self.dim)
    }

    pub fn log_det(&self, j: usize) -> f64 {
        self.log_dets[j]
    }

    pub fn sp(&self, j: usize) -> f64 {
        self.sps[j]
    }

    pub fn v(&self, j: usize) -> u64 {
        self.vs[j]
    }

    /// The whole `sp` arena (posterior priors are derived from it).
    pub fn sps(&self) -> &[f64] {
        &self.sps
    }

    /// `Σ sp` with the same left-fold the array-of-structs path used,
    /// so priors come out bit-identical.
    pub fn total_sp(&self) -> f64 {
        self.sps.iter().sum()
    }

    /// Disjoint mutable views of row `j` across all arenas:
    /// `(mean, mat, log_det, sp, v)`.
    pub(crate) fn row_mut(
        &mut self,
        j: usize,
    ) -> (&mut [f64], &mut [f64], &mut f64, &mut f64, &mut u64) {
        let d = self.dim;
        let t = self.tri;
        (
            &mut self.means[j * d..(j + 1) * d],
            &mut self.mats[j * t..(j + 1) * t],
            &mut self.log_dets[j],
            &mut self.sps[j],
            &mut self.vs[j],
        )
    }

    /// Raw-pointer view for the engine's sharded update pass: each
    /// worker mutates only the rows of its own contiguous component
    /// shard (see [`StoreRawMut::row_mut`]'s safety contract).
    pub(crate) fn raw_mut(&mut self) -> StoreRawMut {
        StoreRawMut {
            dim: self.dim,
            tri: self.tri,
            means: SharedMut::new(self.means.as_mut_ptr()),
            mats: SharedMut::new(self.mats.as_mut_ptr()),
            log_dets: SharedMut::new(self.log_dets.as_mut_ptr()),
            sps: SharedMut::new(self.sps.as_mut_ptr()),
            vs: SharedMut::new(self.vs.as_mut_ptr()),
        }
    }

    /// Swap rows `a` and `b` in every arena.
    pub(crate) fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let d = self.dim;
        let t = self.tri;
        for off in 0..d {
            self.means.swap(a * d + off, b * d + off);
        }
        for off in 0..t {
            self.mats.swap(a * t + off, b * t + off);
        }
        self.log_dets.swap(a, b);
        self.sps.swap(a, b);
        self.vs.swap(a, b);
    }

    /// Overwrite row `dst` with row `src` (compaction helper).
    fn copy_row(&mut self, src: usize, dst: usize) {
        let d = self.dim;
        let t = self.tri;
        self.means.copy_within(src * d..(src + 1) * d, dst * d);
        self.mats.copy_within(src * t..(src + 1) * t, dst * t);
        self.log_dets[dst] = self.log_dets[src];
        self.sps[dst] = self.sps[src];
        self.vs[dst] = self.vs[src];
    }

    /// Drop every row past the first `k`.
    pub(crate) fn truncate(&mut self, k: usize) {
        self.means.truncate(k * self.dim);
        self.mats.truncate(k * self.tri);
        self.log_dets.truncate(k);
        self.sps.truncate(k);
        self.vs.truncate(k);
    }

    /// The §2.3 spuriousness sweep shared by both variants: remove every
    /// component with `v > v_min && sp < sp_min` — except that the
    /// mixture is never allowed to empty. When *every* component trips
    /// the predicate at once (possible on short/adversarial streams),
    /// the single strongest component — highest `sp`, lowest index on
    /// ties — survives, so densities/predictions and the `sp/Σsp`
    /// priors stay well-defined. Survivors keep their relative order
    /// (stable compaction, like the pre-refactor `Vec::retain`), so
    /// pruning is layout-invariant. Both `Figmn` and `Igmn` funnel
    /// through this one function, so their prune decisions are
    /// identical by construction (the paper's §4 equivalence).
    ///
    /// Returns how many components were removed.
    pub(crate) fn prune(&mut self, v_min: u64, sp_min: f64) -> usize {
        let k = self.len();
        if k <= 1 {
            return 0;
        }
        let doomed = |sp: f64, v: u64| v > v_min && sp < sp_min;
        if (0..k).all(|j| doomed(self.sps[j], self.vs[j])) {
            let mut keep = 0usize;
            let mut best = self.sps[0];
            for (j, &s) in self.sps.iter().enumerate().skip(1) {
                if s > best {
                    best = s;
                    keep = j;
                }
            }
            self.swap_rows(0, keep);
            self.truncate(1);
        } else {
            let mut w = 0usize;
            for j in 0..k {
                if doomed(self.sps[j], self.vs[j]) {
                    continue;
                }
                if w != j {
                    self.copy_row(j, w);
                }
                w += 1;
            }
            self.truncate(w);
        }
        k - self.len()
    }

    /// Arena bytes one component occupies: `D` mean + `D(D+1)/2` packed
    /// matrix + `log_det` + `sp` floats, plus the `u64` age. The dense
    /// array-of-structs layout paid `D²` matrix floats (plus two heap
    /// headers) for the same state — about 2× this at large `D`.
    pub fn bytes_per_component(&self) -> usize {
        (self.dim + self.tri + 2) * std::mem::size_of::<f64>() + std::mem::size_of::<u64>()
    }

    /// Total arena payload for the live mixture.
    pub fn model_bytes(&self) -> usize {
        self.len() * self.bytes_per_component()
    }

    /// Payload bytes one component occupied in the pre-refactor dense
    /// array-of-structs layout (`D` mean + `D²` matrix + 2 scalar
    /// floats + the `u64` age) — the baseline the layout benches
    /// compare [`ComponentStore::bytes_per_component`] against.
    pub fn dense_equivalent_bytes(dim: usize) -> usize {
        (dim + dim * dim + 2) * std::mem::size_of::<f64>() + std::mem::size_of::<u64>()
    }
}

/// Raw-pointer row access for the engine's sharded update pass; `Copy`
/// so the shard closure can capture it by value.
#[derive(Clone, Copy)]
pub(crate) struct StoreRawMut {
    dim: usize,
    tri: usize,
    means: SharedMut<f64>,
    mats: SharedMut<f64>,
    log_dets: SharedMut<f64>,
    sps: SharedMut<f64>,
    vs: SharedMut<u64>,
}

impl StoreRawMut {
    /// Mutable views of row `j`: `(mean, mat, log_det, sp, v)`.
    ///
    /// # Safety
    /// `j` must be in bounds of the source store, and no other thread
    /// may access row `j` during the same engine pass — guaranteed when
    /// `j` comes from the pool's disjoint shard ranges.
    pub unsafe fn row_mut(
        &self,
        j: usize,
    ) -> (&mut [f64], &mut [f64], &mut f64, &mut f64, &mut u64) {
        (
            self.means.slice(j * self.dim, self.dim),
            self.mats.slice(j * self.tri, self.tri),
            &mut *self.log_dets.at(j),
            &mut *self.sps.at(j),
            &mut *self.vs.at(j),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(rows: &[(f64, f64, u64)]) -> ComponentStore {
        // 2-D store; mean/diag tagged by the row's sp so moves are visible.
        let mut s = ComponentStore::new(2);
        for &(tag, sp, v) in rows {
            let mean = [tag, -tag];
            let mat = packed::from_diag(&[tag, tag]);
            s.push(&mean, &mat, tag.ln(), sp, v);
        }
        s
    }

    #[test]
    fn push_and_accessors() {
        let s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.dim(), 2);
        assert_eq!(s.mat_len(), 3);
        assert_eq!(s.mean(1), &[4.0, -4.0]);
        assert_eq!(s.mat(1), &[4.0, 0.0, 4.0]);
        assert_eq!(s.log_det(1), 4.0f64.ln());
        assert_eq!(s.sp(0), 2.0);
        assert_eq!(s.v(0), 3);
        assert_eq!(s.sps(), &[2.0, 5.0]);
        assert_eq!(s.total_sp(), 7.0);
        let dense = s.mat_dense(0);
        assert_eq!(dense.as_slice(), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn row_mut_is_disjoint_per_field() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        {
            let (mean, mat, log_det, sp, v) = s.row_mut(0);
            mean[0] = 9.0;
            mat[2] = 8.0;
            *log_det = 7.0;
            *sp = 6.0;
            *v = 5;
        }
        assert_eq!(s.mean(0), &[9.0, -1.0]);
        assert_eq!(s.mat(0), &[1.0, 0.0, 8.0]);
        assert_eq!(s.log_det(0), 7.0);
        assert_eq!(s.sp(0), 6.0);
        assert_eq!(s.v(0), 5);
        // Row 1 untouched.
        assert_eq!(s.mean(1), &[4.0, -4.0]);
    }

    #[test]
    fn swap_and_truncate() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6), (7.0, 8.0, 9)]);
        s.swap_rows(0, 2);
        assert_eq!(s.mean(0), &[7.0, -7.0]);
        assert_eq!(s.sp(0), 8.0);
        assert_eq!(s.mean(2), &[1.0, -1.0]);
        s.truncate(1);
        assert_eq!(s.len(), 1);
        assert_eq!(s.mean(0), &[7.0, -7.0]);
        assert_eq!(s.mat(0), &[7.0, 0.0, 7.0]);
    }

    #[test]
    fn prune_is_stable_and_order_preserving() {
        // Rows 1 and 3 are doomed (v > 1, sp < 4); survivors keep order.
        let mut s = store_with(&[(1.0, 5.0, 0), (2.0, 1.0, 3), (3.0, 6.0, 4), (4.0, 2.0, 5)]);
        let removed = s.prune(1, 4.0);
        assert_eq!(removed, 2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.mean(0), &[1.0, -1.0]);
        assert_eq!(s.mean(1), &[3.0, -3.0]);
        assert_eq!(s.sps(), &[5.0, 6.0]);
        assert_eq!(s.v(1), 4);
    }

    #[test]
    fn prune_keeps_strongest_when_all_doomed() {
        let mut s = store_with(&[(1.0, 0.5, 9), (2.0, 2.5, 9), (3.0, 2.5, 9)]);
        let removed = s.prune(1, 100.0);
        assert_eq!(removed, 2);
        assert_eq!(s.len(), 1);
        // Highest sp, lowest index on ties → row 1 (tag 2.0).
        assert_eq!(s.mean(0), &[2.0, -2.0]);
        assert_eq!(s.sp(0), 2.5);
    }

    #[test]
    fn prune_never_empties_single_component() {
        let mut s = store_with(&[(1.0, 0.1, 99)]);
        assert_eq!(s.prune(0, 1e9), 0);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn clone_is_independent_bulk_copy() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let snap = s.clone();
        let (mean, ..) = s.row_mut(0);
        mean[0] = 100.0;
        assert_eq!(snap.mean(0), &[1.0, -1.0], "clone must not alias");
        assert_eq!(snap, store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]));
    }

    #[test]
    fn byte_accounting_tracks_packed_layout() {
        let s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        // D=2: 2 mean + 3 packed + log_det + sp floats, + u64 age.
        assert_eq!(s.bytes_per_component(), 7 * 8 + 8);
        assert_eq!(s.model_bytes(), 2 * s.bytes_per_component());
        // The packed matrix is strictly smaller than dense for D ≥ 2.
        assert!(s.mat_len() < s.dim() * s.dim());
    }

    #[test]
    fn raw_mut_rows_address_the_arenas() {
        let mut s = store_with(&[(1.0, 2.0, 3), (4.0, 5.0, 6)]);
        let raw = s.raw_mut();
        unsafe {
            let (mean, mat, log_det, sp, v) = raw.row_mut(1);
            mean[1] = 42.0;
            mat[0] = 41.0;
            *log_det = 40.0;
            *sp = 39.0;
            *v = 38;
        }
        assert_eq!(s.mean(1), &[4.0, 42.0]);
        assert_eq!(s.mat(1), &[41.0, 0.0, 4.0]);
        assert_eq!(s.log_det(1), 40.0);
        assert_eq!(s.sp(1), 39.0);
        assert_eq!(s.v(1), 38);
    }
}
