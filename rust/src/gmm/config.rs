//! Shared configuration for both IGMN variants.

use super::candidates::SearchMode;
use super::learn_pipeline::LearnMode;
use super::replica::ReplicaMode;
use crate::linalg::KernelMode;
use crate::stats::chi2_quantile;

/// Hyper-parameters of the (F)IGMN (paper §2).
///
/// Built with a fluent API; [`GmmConfig::chi2_threshold`] is derived once
/// from `β` and `D` (the `χ²_{D,1−β}` update criterion of §2.1).
#[derive(Debug, Clone)]
pub struct GmmConfig {
    /// Joint input dimensionality `D`.
    pub dim: usize,
    /// σ_ini scaling factor `δ` (Eq. 13), e.g. 0.01 … 1.
    pub delta: f64,
    /// Novelty percentile `β` (§2.1), e.g. 0.1. `β = 0` disables creation
    /// after the first component (threshold = +∞), reproducing the paper's
    /// Table 2/3 single-component timing setup.
    pub beta: f64,
    /// Minimum age before a component may be pruned (§2.3), e.g. 5.
    pub v_min: u64,
    /// Accumulator threshold under which an old component is spurious
    /// (§2.3), e.g. 3.
    pub sp_min: f64,
    /// Hard cap on component count (0 = unlimited). Not in the paper;
    /// used by the coordinator to bound worker memory — when full, the
    /// nearest component is updated instead of creating a new one.
    pub max_components: usize,
    /// Whether pruning (§2.3) runs at all (the paper's timing experiments
    /// effectively disable it via β = 0).
    pub prune: bool,
    /// Which implementation the hot packed kernels run in:
    /// [`KernelMode::Strict`] (default; bit-identical scalar reference)
    /// or [`KernelMode::Fast`] (blocked SIMD-friendly loops,
    /// tolerance-equivalent — see [`KernelMode`] for the contract).
    /// Affects the precision path's distance/score sweeps and fused
    /// update; conditional inference (`predict`) and the covariance
    /// baseline always run the strict kernels.
    pub kernel_mode: KernelMode,
    /// How the learn/score surfaces search the component axis:
    /// [`SearchMode::Strict`] (default; full-K sweeps, bit-identical to
    /// the pre-index code paths) or [`SearchMode::TopC`] (evaluate only
    /// the C nearest components per query with an exact-fallback gate
    /// on learn — see [`SearchMode`] for the contract). Affects the
    /// precision path only; conditional inference (`predict`) and the
    /// covariance baseline always run the full-K sweep.
    pub search_mode: SearchMode,
    /// Whether published snapshots carry an f32 read replica and serve
    /// the density surfaces from it: [`ReplicaMode::Off`] (default; the
    /// read path is byte-identical to the pre-replica code) or
    /// [`ReplicaMode::F32`] (half the bytes per scoring sweep,
    /// tolerance-gated — see [`ReplicaMode`] for the contract). Affects
    /// only immutable published snapshots; the write path and
    /// conditional inference always run f64.
    pub replica_mode: ReplicaMode,
    /// How the write path consumes the stream: [`LearnMode::Online`]
    /// (default; one point at a time, bit-identical to the pre-pipeline
    /// learn path at every thread count) or [`LearnMode::MiniBatch`]
    /// (stage `b`-point blocks through the batched distance pass — see
    /// [`LearnMode`] for the contract). Affects the precision path's
    /// `learn_batch` only; the covariance baseline always learns
    /// point-by-point.
    pub learn_mode: LearnMode,
    /// Per-point exponential forgetting factor applied to every
    /// component's accumulator `sp` before the point is learned.
    /// `1.0` (default) disables forgetting and adds no floating-point
    /// work; values in `(0, 1)` make the mixture track non-stationary
    /// streams (old evidence decays, so drifted-away components lose
    /// their priors and eventually trip the §2.3 prune).
    pub decay: f64,
    /// Max-age eviction horizon (0 = off): a component that has not won
    /// a point (argmax posterior) in more than `max_age` learned points
    /// is evicted by the §2.3 prune sweep's age arm. The integer age
    /// `v` cannot decay, so this is the drift-adaptive complement to
    /// [`GmmConfig::decay`] for components stranded by a distribution
    /// shift.
    pub max_age: u64,
    chi2_threshold: f64,
}

impl GmmConfig {
    /// Defaults follow the paper's running examples: δ = 0.01, β = 0.1,
    /// v_min = 5, sp_min = 3, pruning on.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "GmmConfig: dim must be positive");
        let mut cfg = GmmConfig {
            dim,
            delta: 0.01,
            beta: 0.1,
            v_min: 5,
            sp_min: 3.0,
            max_components: 0,
            prune: true,
            kernel_mode: KernelMode::Strict,
            search_mode: SearchMode::Strict,
            replica_mode: ReplicaMode::Off,
            learn_mode: LearnMode::Online,
            decay: 1.0,
            max_age: 0,
            chi2_threshold: 0.0,
        };
        cfg.recompute_threshold();
        cfg
    }

    pub fn with_delta(mut self, delta: f64) -> Self {
        assert!(delta > 0.0, "delta must be positive");
        self.delta = delta;
        self
    }

    pub fn with_beta(mut self, beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta in [0,1)");
        self.beta = beta;
        self.recompute_threshold();
        self
    }

    pub fn with_pruning(mut self, v_min: u64, sp_min: f64) -> Self {
        self.v_min = v_min;
        self.sp_min = sp_min;
        self.prune = true;
        self
    }

    pub fn without_pruning(mut self) -> Self {
        self.prune = false;
        self
    }

    pub fn with_max_components(mut self, k: usize) -> Self {
        self.max_components = k;
        self
    }

    /// Select the packed-kernel implementation (see
    /// [`GmmConfig::kernel_mode`]).
    pub fn with_kernel_mode(mut self, mode: KernelMode) -> Self {
        self.kernel_mode = mode;
        self
    }

    /// Select the component-axis search strategy (see
    /// [`GmmConfig::search_mode`]).
    pub fn with_search_mode(mut self, mode: SearchMode) -> Self {
        self.search_mode = mode;
        self
    }

    /// Select the snapshot read-replica mode (see
    /// [`GmmConfig::replica_mode`]).
    pub fn with_replica_mode(mut self, mode: ReplicaMode) -> Self {
        self.replica_mode = mode;
        self
    }

    /// Select the write-path learn mode (see [`GmmConfig::learn_mode`]).
    pub fn with_learn_mode(mut self, mode: LearnMode) -> Self {
        self.learn_mode = mode;
        self
    }

    /// Set the per-point `sp` forgetting factor (see
    /// [`GmmConfig::decay`]). `1.0` disables forgetting.
    pub fn with_decay(mut self, decay: f64) -> Self {
        assert!(
            decay > 0.0 && decay <= 1.0,
            "decay must be in (0, 1], got {decay}"
        );
        self.decay = decay;
        self
    }

    /// Set the max-age eviction horizon (see [`GmmConfig::max_age`]).
    /// `0` disables age eviction.
    pub fn with_max_age(mut self, max_age: u64) -> Self {
        self.max_age = max_age;
        self
    }

    /// The update-vs-create threshold `χ²_{D,1−β}` (§2.1). `+∞` for β = 0:
    /// every point after the first updates the existing mixture.
    pub fn chi2_threshold(&self) -> f64 {
        self.chi2_threshold
    }

    fn recompute_threshold(&mut self) {
        self.chi2_threshold = if self.beta <= 0.0 {
            f64::INFINITY
        } else {
            chi2_quantile(self.dim as f64, 1.0 - self.beta)
        };
    }

    /// Per-dimension `σ_ini = δ·std(x)` (Eq. 13) from dataset (or
    /// estimated) standard deviations.
    pub fn sigma_ini(&self, stds: &[f64]) -> Vec<f64> {
        assert_eq!(stds.len(), self.dim, "sigma_ini: stds length != dim");
        stds.iter()
            .map(|&s| {
                let v = self.delta * s;
                assert!(v > 0.0, "sigma_ini must be positive (std={s}, delta={})", self.delta);
                v
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_zero_threshold_infinite() {
        let cfg = GmmConfig::new(8).with_beta(0.0);
        assert!(cfg.chi2_threshold().is_infinite());
    }

    #[test]
    fn threshold_matches_chi2_quantile() {
        let cfg = GmmConfig::new(9).with_beta(0.1);
        assert!((cfg.chi2_threshold() - chi2_quantile(9.0, 0.9)).abs() < 1e-10);
    }

    #[test]
    fn kernel_mode_defaults_strict_and_round_trips() {
        let cfg = GmmConfig::new(4);
        assert_eq!(cfg.kernel_mode, KernelMode::Strict);
        let cfg = cfg.with_kernel_mode(KernelMode::Fast);
        assert_eq!(cfg.kernel_mode, KernelMode::Fast);
        assert_eq!(KernelMode::parse("fast"), Some(KernelMode::Fast));
        assert_eq!(KernelMode::parse("strict"), Some(KernelMode::Strict));
        assert_eq!(KernelMode::parse("turbo"), None);
        assert_eq!(KernelMode::Fast.as_str(), "fast");
        assert_eq!(KernelMode::default(), KernelMode::Strict);
    }

    #[test]
    fn search_mode_defaults_strict_and_round_trips() {
        let cfg = GmmConfig::new(4);
        assert_eq!(cfg.search_mode, SearchMode::Strict);
        let cfg = cfg.with_search_mode(SearchMode::TopC { c: 32 });
        assert_eq!(cfg.search_mode, SearchMode::TopC { c: 32 });
        assert_eq!(cfg.search_mode.to_wire(), "topc:32");
    }

    #[test]
    fn replica_mode_defaults_off_and_round_trips() {
        let cfg = GmmConfig::new(4);
        assert_eq!(cfg.replica_mode, ReplicaMode::Off);
        let cfg = cfg.with_replica_mode(ReplicaMode::F32 { tol: 1e-2 });
        assert_eq!(cfg.replica_mode, ReplicaMode::F32 { tol: 1e-2 });
        assert_eq!(cfg.replica_mode.to_wire(), "f32:0.01");
    }

    #[test]
    fn learn_mode_defaults_online_and_round_trips() {
        let cfg = GmmConfig::new(4);
        assert_eq!(cfg.learn_mode, LearnMode::Online);
        assert_eq!(cfg.decay, 1.0);
        assert_eq!(cfg.max_age, 0);
        let cfg = cfg
            .with_learn_mode(LearnMode::MiniBatch { b: 32 })
            .with_decay(0.999)
            .with_max_age(5000);
        assert_eq!(cfg.learn_mode, LearnMode::MiniBatch { b: 32 });
        assert_eq!(cfg.learn_mode.to_wire(), "minibatch:32");
        assert_eq!(cfg.decay, 0.999);
        assert_eq!(cfg.max_age, 5000);
    }

    #[test]
    #[should_panic(expected = "decay must be in (0, 1]")]
    fn decay_rejects_out_of_range() {
        let _ = GmmConfig::new(2).with_decay(0.0);
    }

    #[test]
    fn sigma_ini_scales_stds() {
        let cfg = GmmConfig::new(3).with_delta(0.5);
        assert_eq!(cfg.sigma_ini(&[2.0, 4.0, 1.0]), vec![1.0, 2.0, 0.5]);
    }

    #[test]
    #[should_panic]
    fn sigma_ini_rejects_zero_std() {
        let cfg = GmmConfig::new(1);
        cfg.sigma_ini(&[0.0]);
    }
}
