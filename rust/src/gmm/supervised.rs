//! Supervised classification on top of the autoassociative mixture.
//!
//! The paper uses the IGMN's "any element predicts any other element"
//! property for supervised learning: features and a class encoding share
//! one joint input vector; at query time the class block is reconstructed
//! from the feature block (Eq. 15/27). This wrapper packages that as a
//! conventional classifier with one-hot class encoding, which is what the
//! Table 4 (AUC) experiments use — the reconstructed class activations
//! are the ranking scores.

use super::{Figmn, GmmConfig, IncrementalMixture, Igmn, LearnOutcome};

/// Chunk length [`SupervisedGmm::train_batch`] materializes joint
/// vectors in: big enough that a mini-batch model's blocks stay intact
/// for every practical block length, small enough that batch training
/// never holds more than O(CHUNK·D) extra memory.
const TRAIN_JOINT_CHUNK: usize = 256;

/// A classifier wrapper over any [`IncrementalMixture`].
pub struct SupervisedGmm<M: IncrementalMixture> {
    model: M,
    n_features: usize,
    n_classes: usize,
    feature_idx: Vec<usize>,
    class_idx: Vec<usize>,
}

impl<M: IncrementalMixture> SupervisedGmm<M> {
    /// Wrap an already-constructed mixture whose joint dimension is
    /// `n_features + n_classes`.
    pub fn from_model(model: M, n_features: usize, n_classes: usize) -> Self {
        assert_eq!(model.dim(), n_features + n_classes, "joint dim mismatch");
        let (feature_idx, class_idx) = super::index_split(n_features, n_classes);
        SupervisedGmm { model, n_features, n_classes, feature_idx, class_idx }
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// Mutable access to the wrapped mixture (e.g. to attach an engine).
    pub fn model_mut(&mut self) -> &mut M {
        &mut self.model
    }

    /// Present one labeled example (single-pass, online).
    pub fn train_one(&mut self, x: &[f64], class: usize) -> LearnOutcome {
        assert_eq!(x.len(), self.n_features);
        assert!(class < self.n_classes);
        let mut joint = Vec::with_capacity(self.model.dim());
        joint.extend_from_slice(x);
        for c in 0..self.n_classes {
            joint.push(if c == class { 1.0 } else { 0.0 });
        }
        self.model.learn(&joint)
    }

    /// Present a batch of labeled examples in stream order. Joints are
    /// materialized in chunks of [`TRAIN_JOINT_CHUNK`] and handed to
    /// the mixture's `learn_batch`, so an online model consumes them
    /// exactly as looping [`SupervisedGmm::train_one`] would, while a
    /// [`super::LearnMode::MiniBatch`] model stages its blocked learn
    /// pipeline (chunking bounds the extra memory at O(CHUNK·D) and
    /// caps the effective block length at the chunk size).
    pub fn train_batch(&mut self, xs: &[Vec<f64>], classes: &[usize]) -> Vec<LearnOutcome> {
        assert_eq!(xs.len(), classes.len());
        let mut out = Vec::with_capacity(xs.len());
        let mut joints: Vec<Vec<f64>> = Vec::with_capacity(TRAIN_JOINT_CHUNK.min(xs.len()));
        for (chunk_x, chunk_c) in
            xs.chunks(TRAIN_JOINT_CHUNK).zip(classes.chunks(TRAIN_JOINT_CHUNK))
        {
            joints.clear();
            for (x, &class) in chunk_x.iter().zip(chunk_c.iter()) {
                assert_eq!(x.len(), self.n_features);
                assert!(class < self.n_classes);
                let mut joint = Vec::with_capacity(self.model.dim());
                joint.extend_from_slice(x);
                for c in 0..self.n_classes {
                    joint.push(if c == class { 1.0 } else { 0.0 });
                }
                joints.push(joint);
            }
            out.extend(self.model.learn_batch(&joints));
        }
        out
    }

    /// Present one raw joint vector `[features…, outputs…]` — regression
    /// mode: the trailing block holds continuous targets instead of a
    /// one-hot class (the paper's §1 autoassociative usage). Both modes
    /// can interleave on one model only if the output block semantics
    /// match; the coordinator keeps them separate per model.
    pub fn train_joint(&mut self, joint: &[f64]) -> LearnOutcome {
        assert_eq!(joint.len(), self.model.dim());
        self.model.learn(joint)
    }

    /// Raw conditional-mean reconstruction of the output block (Eq. 27),
    /// without the one-hot clipping/normalization of
    /// [`SupervisedGmm::class_scores`] — regression predictions.
    pub fn predict_targets(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_features);
        self.model.predict(x, &self.feature_idx, &self.class_idx)
    }

    /// Class scores: the reconstructed one-hot block, shifted/clipped to
    /// be non-negative and normalized to sum 1. Suitable both for argmax
    /// classification and as AUC ranking scores.
    pub fn class_scores(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_features);
        let raw = self.model.predict(x, &self.feature_idx, &self.class_idx);
        clip_normalize(raw)
    }

    /// Batched class scores through the mixture's `predict_batch`
    /// (identical to mapping [`SupervisedGmm::class_scores`]). On the
    /// native mixtures this rides the component-outer query-blocked
    /// conditional path, so each component's matrix is streamed once
    /// per query block instead of once per example.
    pub fn class_scores_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        for x in xs {
            assert_eq!(x.len(), self.n_features);
        }
        self.model
            .predict_batch(xs, &self.feature_idx, &self.class_idx)
            .into_iter()
            .map(clip_normalize)
            .collect()
    }

    /// Batched regression reconstructions of the output block through
    /// the mixture's blocked `predict_batch` (identical to mapping
    /// [`SupervisedGmm::predict_targets`]).
    pub fn predict_targets_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        for x in xs {
            assert_eq!(x.len(), self.n_features);
        }
        self.model.predict_batch(xs, &self.feature_idx, &self.class_idx)
    }

    /// Hard classification: argmax of the class scores.
    pub fn predict_class(&self, x: &[f64]) -> usize {
        let scores = self.class_scores(x);
        argmax(&scores)
    }

    /// Batched hard classification — identical to mapping
    /// [`SupervisedGmm::predict_class`], through the blocked batch
    /// scoring path.
    pub fn predict_class_batch(&self, xs: &[Vec<f64>]) -> Vec<usize> {
        self.class_scores_batch(xs).iter().map(|scores| argmax(scores)).collect()
    }

    pub fn num_components(&self) -> usize {
        self.model.num_components()
    }
}

impl SupervisedGmm<Figmn> {
    /// Export an immutable read-path snapshot of the joint mixture with
    /// the feature/class split recorded, so scorer threads can serve
    /// [`super::ModelSnapshot::class_scores`] bit-identically to this
    /// wrapper. `None` until the model has seen at least one point (an
    /// empty mixture has nothing to score).
    pub fn snapshot(&self) -> Option<super::ModelSnapshot> {
        if self.model.num_components() == 0 {
            return None;
        }
        Some(self.model.snapshot().with_split(self.n_features, self.n_classes))
    }
}

/// Convenience constructor for the fast variant.
///
/// `feature_stds` are the per-feature standard deviations; class one-hot
/// dimensions get a fixed 0.5 std estimate (a Bernoulli's upper bound —
/// §2.2 allows estimates).
pub fn supervised_figmn(
    cfg_for_features: GmmConfig,
    feature_stds: &[f64],
    n_classes: usize,
) -> SupervisedGmm<Figmn> {
    let joint = joint_config(&cfg_for_features, feature_stds.len(), n_classes);
    let stds = joint_stds(feature_stds, n_classes);
    SupervisedGmm::from_model(Figmn::new(joint, &stds), feature_stds.len(), n_classes)
}

/// Convenience constructor for the covariance baseline.
pub fn supervised_igmn(
    cfg_for_features: GmmConfig,
    feature_stds: &[f64],
    n_classes: usize,
) -> SupervisedGmm<Igmn> {
    let joint = joint_config(&cfg_for_features, feature_stds.len(), n_classes);
    let stds = joint_stds(feature_stds, n_classes);
    SupervisedGmm::from_model(Igmn::new(joint, &stds), feature_stds.len(), n_classes)
}

fn joint_config(cfg: &GmmConfig, n_features: usize, n_classes: usize) -> GmmConfig {
    let mut joint = GmmConfig::new(n_features + n_classes)
        .with_delta(cfg.delta)
        .with_beta(cfg.beta)
        .with_max_components(cfg.max_components)
        .with_kernel_mode(cfg.kernel_mode)
        .with_learn_mode(cfg.learn_mode)
        .with_decay(cfg.decay)
        .with_max_age(cfg.max_age);
    if cfg.prune {
        joint = joint.with_pruning(cfg.v_min, cfg.sp_min);
    } else {
        joint = joint.without_pruning();
    }
    joint
}

fn joint_stds(feature_stds: &[f64], n_classes: usize) -> Vec<f64> {
    let mut stds = feature_stds.to_vec();
    stds.extend(std::iter::repeat(0.5).take(n_classes));
    stds
}

/// Index of the maximum score — the exact argmax expression
/// `predict_class` always used (ties resolve to the highest index, per
/// `Iterator::max_by`), factored out so the batched path cannot drift.
fn argmax(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Clip the reconstructed one-hot block to non-negative and normalize to
/// sum 1, falling back to a softmax when every activation clipped.
/// Shared with [`super::ModelSnapshot::class_scores`] so the snapshot
/// read path is bit-identical to this wrapper.
pub(crate) fn clip_normalize(raw: Vec<f64>) -> Vec<f64> {
    let mut scores: Vec<f64> = raw.iter().map(|&v| v.max(0.0)).collect();
    let total: f64 = scores.iter().sum();
    if total <= 0.0 {
        // Every activation clipped: fall back to softmax of raw.
        let best = raw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut t = 0.0;
        for (s, &r) in scores.iter_mut().zip(raw.iter()) {
            *s = (r - best).exp();
            t += *s;
        }
        for s in &mut scores {
            *s /= t;
        }
    } else {
        for s in &mut scores {
            *s /= total;
        }
    }
    scores
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn gaussian_blobs(n: usize, seed: u64) -> Vec<(Vec<f64>, usize)> {
        let mut rng = Pcg64::seed(seed);
        let centers = [[0.0, 0.0], [6.0, 6.0], [0.0, 6.0]];
        (0..n)
            .map(|i| {
                let c = i % 3;
                let x = vec![
                    centers[c][0] + rng.normal() * 0.7,
                    centers[c][1] + rng.normal() * 0.7,
                ];
                (x, c)
            })
            .collect()
    }

    #[test]
    fn learns_three_blobs() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut clf = supervised_figmn(cfg, &[3.0, 3.0], 3);
        for (x, y) in gaussian_blobs(300, 1) {
            clf.train_one(&x, y);
        }
        let mut correct = 0;
        let test = gaussian_blobs(90, 2);
        for (x, y) in &test {
            if clf.predict_class(x) == *y {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn scores_are_distribution() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut clf = supervised_figmn(cfg, &[3.0, 3.0], 3);
        for (x, y) in gaussian_blobs(120, 3) {
            clf.train_one(&x, y);
        }
        let s = clf.class_scores(&[0.1, 0.2]);
        assert_eq!(s.len(), 3);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(s.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn batch_training_and_scoring_match_serial() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut a = supervised_figmn(cfg.clone(), &[3.0, 3.0], 3);
        let mut b = supervised_figmn(cfg, &[3.0, 3.0], 3);
        let data = gaussian_blobs(150, 6);
        for (x, y) in &data {
            a.train_one(x, *y);
        }
        let xs: Vec<Vec<f64>> = data.iter().map(|(x, _)| x.clone()).collect();
        let ys: Vec<usize> = data.iter().map(|(_, y)| *y).collect();
        b.train_batch(&xs, &ys);
        assert_eq!(a.num_components(), b.num_components());
        let probes: Vec<Vec<f64>> =
            gaussian_blobs(10, 7).into_iter().map(|(x, _)| x).collect();
        let batch_scores = b.class_scores_batch(&probes);
        for (x, bs) in probes.iter().zip(batch_scores.iter()) {
            assert_eq!(&a.class_scores(x), bs);
        }
    }

    #[test]
    fn batched_classification_matches_per_point() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut clf = supervised_figmn(cfg, &[3.0, 3.0], 3);
        for (x, y) in gaussian_blobs(200, 8) {
            clf.train_one(&x, y);
        }
        // 40 probes: one full 32-block plus a ragged tail.
        let probes: Vec<Vec<f64>> =
            gaussian_blobs(40, 9).into_iter().map(|(x, _)| x).collect();
        assert_eq!(
            clf.predict_class_batch(&probes),
            probes.iter().map(|x| clf.predict_class(x)).collect::<Vec<_>>()
        );
        assert_eq!(
            clf.predict_targets_batch(&probes),
            probes.iter().map(|x| clf.predict_targets(x)).collect::<Vec<_>>()
        );
        assert!(clf.predict_class_batch(&[]).is_empty());
    }

    #[test]
    fn minibatch_wrapper_trains_and_classifies() {
        use crate::gmm::LearnMode;
        let cfg = GmmConfig::new(2)
            .with_delta(0.5)
            .with_beta(0.05)
            .without_pruning()
            .with_learn_mode(LearnMode::MiniBatch { b: 16 });
        let mut clf = supervised_figmn(cfg, &[3.0, 3.0], 3);
        assert_eq!(clf.model().config().learn_mode, LearnMode::MiniBatch { b: 16 });
        let data = gaussian_blobs(300, 11);
        let xs: Vec<Vec<f64>> = data.iter().map(|(x, _)| x.clone()).collect();
        let ys: Vec<usize> = data.iter().map(|(_, y)| *y).collect();
        let outcomes = clf.train_batch(&xs, &ys);
        assert_eq!(outcomes.len(), xs.len());
        let mut correct = 0;
        let test = gaussian_blobs(90, 12);
        for (x, y) in &test {
            if clf.predict_class(x) == *y {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.9, "mini-batch accuracy {acc}");
    }

    #[test]
    fn igmn_and_figmn_wrappers_agree() {
        let cfg = GmmConfig::new(2).with_delta(0.8).with_beta(0.02).without_pruning();
        let mut a = supervised_figmn(cfg.clone(), &[3.0, 3.0], 3);
        let mut b = supervised_igmn(cfg, &[3.0, 3.0], 3);
        for (x, y) in gaussian_blobs(150, 4) {
            a.train_one(&x, y);
            b.train_one(&x, y);
        }
        assert_eq!(a.num_components(), b.num_components());
        for (x, _) in gaussian_blobs(30, 5) {
            assert_eq!(a.predict_class(&x), b.predict_class(&x));
        }
    }
}
