//! Checkpoint (de)serialization for the fast model.
//!
//! The coordinator checkpoints worker models as JSON (see
//! [`crate::coordinator::checkpoint`]); the format is versioned and
//! validated on load — a corrupt or non-PD checkpoint is rejected rather
//! than silently producing NaNs mid-stream.
//!
//! ## Format history
//!
//! - **v2 (current)** — components carry `lambda_packed`: the packed
//!   upper-triangular precision (`D·(D+1)/2` floats), written straight
//!   from the [`super::ComponentStore`] arenas. Since the dual-mode
//!   kernels landed, v2 documents also carry an optional top-level
//!   `kernel_mode` (`"strict"`/`"fast"`): it round-trips the model's
//!   configured [`KernelMode`], and readers that predate (or ignore)
//!   the field still load the document — the arenas are mode-agnostic
//!   state, so a `Fast`-trained checkpoint loads everywhere and scores
//!   within the fast-mode tolerance contract on strict readers.
//! - **v1 (read-only compat)** — the pre-store per-component format:
//!   `lambda` as a dense row-major `D×D` matrix. The loader packs its
//!   upper triangle; the update rules kept v1 matrices exactly
//!   symmetric, so the packed values equal the dense ones and a v1
//!   checkpoint scores **bit-identically** after loading (see
//!   `tests/checkpoint_compat.rs`).
//!
//! The covariance baseline ([`Igmn`]) checkpoints with the same
//! versioning: v2 writes `cov_packed` rows (no `log_det` — the baseline
//! derives determinants from each factorization), v1 read-compat
//! accepts the dense `cov` per-component form under `"kind":"igmn"`.

use super::store::ComponentStore;
use super::{Figmn, GmmConfig, Igmn, IncrementalMixture, LearnMode, ReplicaMode, SearchMode};
use crate::json::Json;
use crate::linalg::{packed, KernelMode};

/// Current checkpoint format version.
pub const CHECKPOINT_VERSION: f64 = 2.0;

/// Oldest format version the loader still accepts.
pub const CHECKPOINT_MIN_VERSION: f64 = 1.0;

/// Read the optional `kernel_mode` field: absent (pre-dual-mode and v1
/// documents) defaults to [`KernelMode::Strict`]; present-but-invalid
/// is rejected like any other corrupt field.
fn read_kernel_mode(j: &Json) -> Result<KernelMode, String> {
    match j.get("kernel_mode") {
        None => Ok(KernelMode::Strict),
        Some(v) => v
            .as_str()
            .and_then(KernelMode::parse)
            .ok_or_else(|| "bad kernel_mode".to_string()),
    }
}

/// Read the optional `search_mode` field (additive since the candidate
/// index landed): absent defaults to [`SearchMode::Strict`] — the
/// exact full-K sweep every pre-index reader ran — and
/// present-but-invalid is rejected like any other corrupt field. The
/// candidate index itself is never serialized; a top-C model rebuilds
/// it deterministically from the restored arenas.
fn read_search_mode(j: &Json) -> Result<SearchMode, String> {
    match j.get("search_mode") {
        None => Ok(SearchMode::Strict),
        Some(v) => v
            .as_str()
            .and_then(SearchMode::parse)
            .ok_or_else(|| "bad search_mode".to_string()),
    }
}

/// Read the optional `replica_mode` field (additive since the f32 read
/// replicas landed): absent defaults to [`ReplicaMode::Off`] — the
/// all-f64 read path every pre-replica reader ran — and
/// present-but-invalid is rejected like any other corrupt field. The
/// replica itself is never serialized; it is derived state rebuilt at
/// the next snapshot publish from the restored f64 arenas.
fn read_replica_mode(j: &Json) -> Result<ReplicaMode, String> {
    match j.get("replica_mode") {
        None => Ok(ReplicaMode::Off),
        Some(v) => v
            .as_str()
            .and_then(ReplicaMode::parse)
            .ok_or_else(|| "bad replica_mode".to_string()),
    }
}

/// Read the optional `learn_mode` field (additive since the staged
/// learn pipeline): absent defaults to [`LearnMode::Online`] — the
/// per-point write path every pre-pipeline reader ran — and
/// present-but-invalid is rejected like any other corrupt field.
fn read_learn_mode(j: &Json) -> Result<LearnMode, String> {
    match j.get("learn_mode") {
        None => Ok(LearnMode::Online),
        Some(v) => v
            .as_str()
            .and_then(LearnMode::parse)
            .ok_or_else(|| "bad learn_mode".to_string()),
    }
}

/// Read the optional `decay` drift knob (additive with the learn
/// pipeline): absent defaults to `1.0` (forgetting off);
/// present-but-outside `(0, 1]` is rejected like any corrupt field.
fn read_decay(j: &Json) -> Result<f64, String> {
    match j.get("decay") {
        None => Ok(1.0),
        Some(v) => match v.as_f64() {
            Some(d) if d > 0.0 && d <= 1.0 => Ok(d),
            _ => Err("bad decay".to_string()),
        },
    }
}

/// Read the optional `max_age` drift knob (additive with the learn
/// pipeline): absent defaults to `0` (age eviction off). The refresh
/// stamps themselves are never serialized — restored survivors restart
/// their eviction clocks at the checkpoint's stream position.
fn read_max_age(j: &Json) -> Result<u64, String> {
    match j.get("max_age") {
        None => Ok(0),
        Some(v) => v.as_usize().map(|a| a as u64).ok_or_else(|| "bad max_age".to_string()),
    }
}

impl Figmn {
    /// Serialize the full model state to JSON (v2 packed layout).
    pub fn to_json(&self) -> Json {
        let cfg = self.config();
        let store = self.store();
        let comps: Vec<Json> = (0..store.len())
            .map(|j| {
                Json::obj(vec![
                    ("mean", Json::num_array(store.mean(j))),
                    ("lambda_packed", Json::num_array(store.mat(j))),
                    ("log_det", store.log_det(j).into()),
                    ("sp", store.sp(j).into()),
                    ("v", (store.v(j) as usize).into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", CHECKPOINT_VERSION.into()),
            // Which build wrote this checkpoint — the real
            // CARGO_PKG_VERSION, for post-mortem provenance. Loaders
            // only validate the *format* version above.
            ("crate_version", crate::version().into()),
            ("kind", "figmn".into()),
            ("dim", cfg.dim.into()),
            ("delta", cfg.delta.into()),
            ("beta", cfg.beta.into()),
            ("v_min", (cfg.v_min as usize).into()),
            ("sp_min", cfg.sp_min.into()),
            ("prune", cfg.prune.into()),
            ("max_components", cfg.max_components.into()),
            // Additive since the dual-mode kernels: readers that ignore
            // it still load the document (the arenas carry no
            // mode-specific state).
            ("kernel_mode", cfg.kernel_mode.as_str().into()),
            // Additive since the candidate index: the index is derived
            // state (rebuilt from the arenas on load), so only the mode
            // selector travels. Old readers ignore it and score full-K.
            ("search_mode", cfg.search_mode.to_wire().into()),
            // Additive since the f32 read replicas: the replica is
            // derived state (rebuilt at snapshot publish from the f64
            // arenas), so only the mode travels. Old readers ignore it
            // and serve all-f64.
            ("replica_mode", cfg.replica_mode.to_wire().into()),
            // Additive with the staged learn pipeline: the write-path
            // learn mode and the drift knobs travel with the model. Old
            // readers ignore them and learn online/stationary; the
            // refresh stamps are derived state and never travel.
            ("learn_mode", cfg.learn_mode.to_wire().into()),
            ("decay", cfg.decay.into()),
            ("max_age", (cfg.max_age as usize).into()),
            ("sigma_ini", Json::num_array(self.sigma_ini())),
            ("points", (self.points_seen() as usize).into()),
            ("components", Json::Arr(comps)),
        ])
    }

    /// Restore a model from [`Figmn::to_json`] output (v2), or from a
    /// pre-store v1 checkpoint (dense per-component `lambda`).
    pub fn from_json(j: &Json) -> Result<Figmn, String> {
        let get = |k: &str| j.get(k).ok_or_else(|| format!("checkpoint missing '{k}'"));
        let version = get("version")?.as_f64().ok_or("bad version")?;
        if version != CHECKPOINT_VERSION && version != CHECKPOINT_MIN_VERSION {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        if get("kind")?.as_str() != Some("figmn") {
            return Err("not a figmn checkpoint".into());
        }
        // `crate_version` is provenance metadata: optional (pre-manifest
        // checkpoints lack it) but must be a string when present.
        if let Some(cv) = j.get("crate_version") {
            if cv.as_str().is_none() {
                return Err("bad crate_version".into());
            }
        }
        let dim = get("dim")?.as_usize().ok_or("bad dim")?;
        let delta = get("delta")?.as_f64().ok_or("bad delta")?;
        let beta = get("beta")?.as_f64().ok_or("bad beta")?;
        let v_min = get("v_min")?.as_usize().ok_or("bad v_min")? as u64;
        let sp_min = get("sp_min")?.as_f64().ok_or("bad sp_min")?;
        let prune = get("prune")?.as_bool().ok_or("bad prune")?;
        let max_components = get("max_components")?.as_usize().ok_or("bad max_components")?;
        let sigma_ini = get("sigma_ini")?.to_f64_vec().ok_or("bad sigma_ini")?;
        if sigma_ini.len() != dim {
            return Err("sigma_ini length != dim".into());
        }
        let points = get("points")?.as_usize().ok_or("bad points")? as u64;

        let mut cfg = GmmConfig::new(dim)
            .with_delta(delta)
            .with_beta(beta)
            .with_max_components(max_components)
            .with_kernel_mode(read_kernel_mode(j)?)
            .with_search_mode(read_search_mode(j)?)
            .with_replica_mode(read_replica_mode(j)?)
            .with_learn_mode(read_learn_mode(j)?)
            .with_decay(read_decay(j)?)
            .with_max_age(read_max_age(j)?);
        cfg = if prune { cfg.with_pruning(v_min, sp_min) } else { cfg.without_pruning() };

        let tri = packed::packed_len(dim);
        let mut store = ComponentStore::new(dim);
        for (i, cj) in get("components")?.as_array().ok_or("bad components")?.iter().enumerate() {
            let mean = cj.get("mean").and_then(Json::to_f64_vec).ok_or("bad mean")?;
            if mean.len() != dim {
                return Err(format!("component {i}: mean shape mismatch"));
            }
            // v2 stores the packed upper triangle directly; v1 stored
            // the dense matrix — validate the *whole* dense payload
            // (finite everywhere, symmetric), then pack its upper
            // triangle. The v1 writer kept Λ exactly symmetric, so
            // genuine old checkpoints always pass; a corrupt or
            // tampered lower triangle is rejected rather than silently
            // dropped (packing an asymmetric matrix would change what
            // the v1 reader computed).
            let lam = if version == CHECKPOINT_VERSION {
                let p = cj
                    .get("lambda_packed")
                    .and_then(Json::to_f64_vec)
                    .ok_or("bad lambda_packed")?;
                if p.len() != tri {
                    return Err(format!("component {i}: packed lambda shape mismatch"));
                }
                p
            } else {
                let flat = cj.get("lambda").and_then(Json::to_f64_vec).ok_or("bad lambda")?;
                if flat.len() != dim * dim {
                    return Err(format!("component {i}: lambda shape mismatch"));
                }
                if flat.iter().any(|x| !x.is_finite()) {
                    return Err(format!("component {i}: non-finite values"));
                }
                for r in 0..dim {
                    for c in r + 1..dim {
                        if flat[r * dim + c] != flat[c * dim + r] {
                            return Err(format!("component {i}: asymmetric lambda"));
                        }
                    }
                }
                packed::pack_symmetric_slice(&flat, dim)
            };
            let log_det =
                cj.get("log_det").and_then(Json::as_f64).ok_or("bad log_det")?;
            let sp = cj.get("sp").and_then(Json::as_f64).ok_or("bad sp")?;
            let v = cj.get("v").and_then(Json::as_usize).ok_or("bad v")? as u64;
            if !log_det.is_finite() || !sp.is_finite() || sp <= 0.0 {
                return Err(format!("component {i}: corrupt scalars"));
            }
            if mean.iter().chain(lam.iter()).any(|x| !x.is_finite()) {
                return Err(format!("component {i}: non-finite values"));
            }
            store.push(&mean, &lam, log_det, sp, v);
        }
        Ok(Figmn::from_parts(cfg, sigma_ini, store, points))
    }
}

impl Igmn {
    /// Serialize the covariance baseline to JSON (v2 packed layout,
    /// `kind: "igmn"`, `cov_packed` rows — no `log_det`: the baseline
    /// derives determinants from each factorization).
    pub fn to_json(&self) -> Json {
        let cfg = self.config();
        let store = self.store();
        let comps: Vec<Json> = (0..store.len())
            .map(|j| {
                Json::obj(vec![
                    ("mean", Json::num_array(store.mean(j))),
                    ("cov_packed", Json::num_array(store.mat(j))),
                    ("sp", store.sp(j).into()),
                    ("v", (store.v(j) as usize).into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", CHECKPOINT_VERSION.into()),
            ("crate_version", crate::version().into()),
            ("kind", "igmn".into()),
            ("dim", cfg.dim.into()),
            ("delta", cfg.delta.into()),
            ("beta", cfg.beta.into()),
            ("v_min", (cfg.v_min as usize).into()),
            ("sp_min", cfg.sp_min.into()),
            ("prune", cfg.prune.into()),
            ("max_components", cfg.max_components.into()),
            ("kernel_mode", cfg.kernel_mode.as_str().into()),
            // Config fidelity only — the covariance baseline always
            // sweeps every component, serves all-f64, and learns
            // point-by-point regardless of the mode selectors.
            ("search_mode", cfg.search_mode.to_wire().into()),
            ("replica_mode", cfg.replica_mode.to_wire().into()),
            ("learn_mode", cfg.learn_mode.to_wire().into()),
            ("decay", cfg.decay.into()),
            ("max_age", (cfg.max_age as usize).into()),
            ("sigma_ini", Json::num_array(self.sigma_ini())),
            ("points", (self.points_seen() as usize).into()),
            ("components", Json::Arr(comps)),
        ])
    }

    /// Restore from [`Igmn::to_json`] output (v2 `cov_packed`), or from
    /// a v1-format document carrying dense per-component `cov` matrices
    /// (validated finite + symmetric, exactly like the Figmn v1 path).
    pub fn from_json(j: &Json) -> Result<Igmn, String> {
        let get = |k: &str| j.get(k).ok_or_else(|| format!("checkpoint missing '{k}'"));
        let version = get("version")?.as_f64().ok_or("bad version")?;
        if version != CHECKPOINT_VERSION && version != CHECKPOINT_MIN_VERSION {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        if get("kind")?.as_str() != Some("igmn") {
            return Err("not an igmn checkpoint".into());
        }
        if let Some(cv) = j.get("crate_version") {
            if cv.as_str().is_none() {
                return Err("bad crate_version".into());
            }
        }
        let dim = get("dim")?.as_usize().ok_or("bad dim")?;
        let delta = get("delta")?.as_f64().ok_or("bad delta")?;
        let beta = get("beta")?.as_f64().ok_or("bad beta")?;
        let v_min = get("v_min")?.as_usize().ok_or("bad v_min")? as u64;
        let sp_min = get("sp_min")?.as_f64().ok_or("bad sp_min")?;
        let prune = get("prune")?.as_bool().ok_or("bad prune")?;
        let max_components = get("max_components")?.as_usize().ok_or("bad max_components")?;
        let sigma_ini = get("sigma_ini")?.to_f64_vec().ok_or("bad sigma_ini")?;
        if sigma_ini.len() != dim {
            return Err("sigma_ini length != dim".into());
        }
        let points = get("points")?.as_usize().ok_or("bad points")? as u64;

        let mut cfg = GmmConfig::new(dim)
            .with_delta(delta)
            .with_beta(beta)
            .with_max_components(max_components)
            .with_kernel_mode(read_kernel_mode(j)?)
            .with_search_mode(read_search_mode(j)?)
            .with_replica_mode(read_replica_mode(j)?)
            .with_learn_mode(read_learn_mode(j)?)
            .with_decay(read_decay(j)?)
            .with_max_age(read_max_age(j)?);
        cfg = if prune { cfg.with_pruning(v_min, sp_min) } else { cfg.without_pruning() };

        let tri = packed::packed_len(dim);
        let mut store = ComponentStore::new_covariance(dim);
        for (i, cj) in get("components")?.as_array().ok_or("bad components")?.iter().enumerate() {
            let mean = cj.get("mean").and_then(Json::to_f64_vec).ok_or("bad mean")?;
            if mean.len() != dim {
                return Err(format!("component {i}: mean shape mismatch"));
            }
            let cov = if version == CHECKPOINT_VERSION {
                let p = cj
                    .get("cov_packed")
                    .and_then(Json::to_f64_vec)
                    .ok_or("bad cov_packed")?;
                if p.len() != tri {
                    return Err(format!("component {i}: packed cov shape mismatch"));
                }
                p
            } else {
                // v1: dense row-major matrix, validated everywhere
                // before the lower triangle is dropped by packing.
                let flat = cj.get("cov").and_then(Json::to_f64_vec).ok_or("bad cov")?;
                if flat.len() != dim * dim {
                    return Err(format!("component {i}: cov shape mismatch"));
                }
                if flat.iter().any(|x| !x.is_finite()) {
                    return Err(format!("component {i}: non-finite values"));
                }
                for r in 0..dim {
                    for c in r + 1..dim {
                        if flat[r * dim + c] != flat[c * dim + r] {
                            return Err(format!("component {i}: asymmetric cov"));
                        }
                    }
                }
                packed::pack_symmetric_slice(&flat, dim)
            };
            let sp = cj.get("sp").and_then(Json::as_f64).ok_or("bad sp")?;
            let v = cj.get("v").and_then(Json::as_usize).ok_or("bad v")? as u64;
            if !sp.is_finite() || sp <= 0.0 {
                return Err(format!("component {i}: corrupt scalars"));
            }
            if mean.iter().chain(cov.iter()).any(|x| !x.is_finite()) {
                return Err(format!("component {i}: non-finite values"));
            }
            store.push(&mean, &cov, 0.0, sp, v);
        }
        Ok(Igmn::from_parts(cfg, sigma_ini, store, points))
    }
}

#[cfg(test)]
mod tests {
    use crate::gmm::{
        Figmn, GmmConfig, Igmn, IncrementalMixture, KernelMode, ReplicaMode, SearchMode,
    };
    use crate::json::parse;
    use crate::rng::Pcg64;
    use crate::testutil::assert_close;

    fn trained_model() -> Figmn {
        let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1);
        let mut m = Figmn::new(cfg, &[2.0, 2.0, 2.0]);
        let mut rng = Pcg64::seed(99);
        for _ in 0..200 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 8.0 };
            let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        m
    }

    #[test]
    fn round_trip_preserves_behaviour() {
        let m = trained_model();
        let text = m.to_json().to_string_compact();
        let restored = Figmn::from_json(&parse(&text).unwrap()).unwrap();

        assert_eq!(restored.num_components(), m.num_components());
        assert_eq!(restored.points_seen(), m.points_seen());
        let mut rng = Pcg64::seed(7);
        for _ in 0..20 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            assert_close(&m.posteriors(&x), &restored.posteriors(&x), 1e-12);
            assert_eq!(m.log_density(&x), restored.log_density(&x));
            let p1 = m.predict(&x[..2], &[0, 1], &[2]);
            let p2 = restored.predict(&x[..2], &[0, 1], &[2]);
            assert_close(&p1, &p2, 1e-12);
        }
    }

    #[test]
    fn checkpoint_is_packed_v2() {
        let m = trained_model();
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_f64()), Some(2.0));
        let comps = doc.get("components").unwrap().as_array().unwrap();
        let tri = 3 * (3 + 1) / 2;
        for c in comps {
            let packed = c.get("lambda_packed").and_then(crate::json::Json::to_f64_vec).unwrap();
            assert_eq!(packed.len(), tri, "v2 stores the packed triangle");
            assert!(c.get("lambda").is_none(), "v2 must not store the dense matrix");
        }
    }

    #[test]
    fn restored_model_keeps_learning_identically() {
        let m = trained_model();
        let mut original = m;
        let mut restored =
            Figmn::from_json(&parse(&original.to_json().to_string_compact()).unwrap()).unwrap();
        let mut rng = Pcg64::seed(5);
        for _ in 0..50 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            assert_eq!(original.learn(&x), restored.learn(&x));
        }
        assert_eq!(original.num_components(), restored.num_components());
    }

    #[test]
    fn checkpoint_carries_real_crate_version() {
        let m = trained_model();
        let doc = m.to_json();
        // The checkpoint records the build that wrote it…
        assert_eq!(
            doc.get("crate_version").and_then(|v| v.as_str()),
            Some(crate::version()),
        );
        // …which is the real manifest version, not a placeholder.
        assert_eq!(crate::version(), env!("CARGO_PKG_VERSION"));
        assert!(!crate::version().is_empty());
        // Round trip preserves behaviour with the field present.
        let restored = Figmn::from_json(&parse(&doc.to_string_compact()).unwrap()).unwrap();
        assert_eq!(restored.num_components(), m.num_components());
        // Pre-manifest checkpoints (no crate_version) still load…
        let mut obj = match doc.clone() {
            crate::json::Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.remove("crate_version");
        assert!(Figmn::from_json(&crate::json::Json::Obj(obj)).is_ok());
        // …but a malformed crate_version is rejected.
        let bad = doc
            .to_string_compact()
            .replace(&format!("\"crate_version\":\"{}\"", crate::version()), "\"crate_version\":42");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn kernel_mode_round_trips_and_defaults_strict() {
        // Fast-trained models write and restore their mode…
        let cfg = GmmConfig::new(2)
            .with_delta(0.5)
            .with_beta(0.1)
            .with_kernel_mode(KernelMode::Fast);
        let mut m = Figmn::new(cfg, &[2.0, 2.0]);
        let mut rng = Pcg64::seed(3);
        for _ in 0..60 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal() * 3.0).collect();
            m.learn(&x);
        }
        let doc = m.to_json();
        assert_eq!(doc.get("kernel_mode").and_then(|v| v.as_str()), Some("fast"));
        let restored = Figmn::from_json(&doc).unwrap();
        assert_eq!(restored.config().kernel_mode, KernelMode::Fast);
        // …and score bit-identically to the source (same mode, same
        // arenas).
        for _ in 0..10 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal() * 3.0).collect();
            assert_eq!(m.log_density(&x), restored.log_density(&x));
        }
        // A reader (or writer) without the field gets Strict — the
        // additive-field degrade path.
        let stripped = match doc.clone() {
            crate::json::Json::Obj(mut o) => {
                o.remove("kernel_mode");
                crate::json::Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let as_strict = Figmn::from_json(&stripped).unwrap();
        assert_eq!(as_strict.config().kernel_mode, KernelMode::Strict);
        // Invalid values are rejected like any corrupt field.
        let bad = doc
            .to_string_compact()
            .replace("\"kernel_mode\":\"fast\"", "\"kernel_mode\":\"warp\"");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
        let bad = doc
            .to_string_compact()
            .replace("\"kernel_mode\":\"fast\"", "\"kernel_mode\":3");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn search_mode_round_trips_and_defaults_strict() {
        // Top-C models write and restore their mode, and the restored
        // model rebuilds its candidate index from the arenas: scores
        // are bit-identical to the source evaluated through a fresh
        // index on the same state.
        let cfg = GmmConfig::new(2)
            .with_delta(0.5)
            .with_beta(0.1)
            .with_search_mode(SearchMode::TopC { c: 2 });
        let mut m = Figmn::new(cfg, &[2.0, 2.0]);
        let mut rng = Pcg64::seed(13);
        for _ in 0..80 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 10.0 };
            let x: Vec<f64> = (0..2).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        let doc = m.to_json();
        assert_eq!(doc.get("search_mode").and_then(|v| v.as_str()), Some("topc:2"));
        let restored = Figmn::from_json(&doc).unwrap();
        assert_eq!(restored.config().search_mode, SearchMode::TopC { c: 2 });
        assert_eq!(restored.num_components(), m.num_components());
        // The snapshots of both models walk freshly built indexes over
        // identical arenas, so they agree bit-for-bit.
        let (s1, s2) = (m.snapshot(), restored.snapshot());
        for _ in 0..10 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal() * 5.0).collect();
            assert_eq!(s1.log_density(&x), s2.log_density(&x));
            assert_eq!(s1.posteriors(&x), s2.posteriors(&x));
        }
        // A document without the field loads as Strict — the
        // additive-field degrade path for pre-index readers/writers.
        let stripped = match doc.clone() {
            crate::json::Json::Obj(mut o) => {
                o.remove("search_mode");
                crate::json::Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let as_strict = Figmn::from_json(&stripped).unwrap();
        assert_eq!(as_strict.config().search_mode, SearchMode::Strict);
        // Invalid values are rejected like any corrupt field.
        let bad_vals =
            ["\"search_mode\":\"topc:0\"", "\"search_mode\":\"near\"", "\"search_mode\":7"];
        for bad_val in bad_vals {
            let bad = doc.to_string_compact().replace("\"search_mode\":\"topc:2\"", bad_val);
            assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "{bad_val}");
        }
    }

    #[test]
    fn replica_mode_round_trips_and_defaults_off() {
        // Replica-configured models write and restore their mode, and
        // the restored model rebuilds its f32 replica at the next
        // snapshot publish from the (exactly restored) f64 arenas.
        let cfg = GmmConfig::new(2)
            .with_delta(0.5)
            .with_beta(0.1)
            .with_replica_mode(ReplicaMode::F32 { tol: 1e-2 });
        let mut m = Figmn::new(cfg, &[2.0, 2.0]);
        let mut rng = Pcg64::seed(23);
        for _ in 0..80 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 10.0 };
            let x: Vec<f64> = (0..2).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        let doc = m.to_json();
        assert_eq!(doc.get("replica_mode").and_then(|v| v.as_str()), Some("f32:0.01"));
        let restored = Figmn::from_json(&doc).unwrap();
        assert_eq!(restored.config().replica_mode, ReplicaMode::F32 { tol: 1e-2 });
        assert_eq!(restored.num_components(), m.num_components());
        // Both snapshots carry a replica over identical arenas, so the
        // f32 read path agrees bit-for-bit.
        let (s1, s2) = (m.snapshot(), restored.snapshot());
        assert!(s1.has_replica() && s2.has_replica());
        for _ in 0..10 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal() * 5.0).collect();
            assert_eq!(s1.log_density(&x), s2.log_density(&x));
            assert_eq!(s1.posteriors(&x), s2.posteriors(&x));
        }
        // A document without the field loads as Off — the
        // additive-field degrade path for pre-replica readers/writers.
        let stripped = match doc.clone() {
            crate::json::Json::Obj(mut o) => {
                o.remove("replica_mode");
                crate::json::Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let as_off = Figmn::from_json(&stripped).unwrap();
        assert_eq!(as_off.config().replica_mode, ReplicaMode::Off);
        assert!(!as_off.snapshot().has_replica());
        // Invalid values are rejected like any corrupt field.
        let bad_vals = [
            "\"replica_mode\":\"f32:0\"",
            "\"replica_mode\":\"f16\"",
            "\"replica_mode\":\"f32:\"",
            "\"replica_mode\":7",
        ];
        for bad_val in bad_vals {
            let bad = doc.to_string_compact().replace("\"replica_mode\":\"f32:0.01\"", bad_val);
            assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "{bad_val}");
        }
    }

    #[test]
    fn learn_mode_and_drift_knobs_round_trip_and_default() {
        use crate::gmm::LearnMode;
        // Mini-batch drift-adaptive models write and restore all three
        // knobs.
        let cfg = GmmConfig::new(2)
            .with_delta(0.5)
            .with_beta(0.1)
            .with_learn_mode(LearnMode::MiniBatch { b: 4 })
            .with_decay(0.995)
            .with_max_age(100);
        let mut m = Figmn::new(cfg, &[2.0, 2.0]);
        let mut rng = Pcg64::seed(31);
        let xs: Vec<Vec<f64>> = (0..60)
            .map(|_| {
                let c = if rng.uniform() < 0.5 { 0.0 } else { 10.0 };
                (0..2).map(|_| c + rng.normal()).collect()
            })
            .collect();
        m.learn_batch(&xs);
        let doc = m.to_json();
        assert_eq!(doc.get("learn_mode").and_then(|v| v.as_str()), Some("minibatch:4"));
        assert_eq!(doc.get("decay").and_then(|v| v.as_f64()), Some(0.995));
        assert_eq!(doc.get("max_age").and_then(|v| v.as_usize()), Some(100));
        let restored = Figmn::from_json(&doc).unwrap();
        assert_eq!(restored.config().learn_mode, LearnMode::MiniBatch { b: 4 });
        assert_eq!(restored.config().decay, 0.995);
        assert_eq!(restored.config().max_age, 100);
        assert_eq!(restored.num_components(), m.num_components());
        assert_eq!(restored.points_seen(), m.points_seen());
        // Identical arenas → identical scoring (the refresh stamps are
        // excluded from both the document and store equality).
        for _ in 0..10 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal() * 5.0).collect();
            assert_eq!(m.log_density(&x), restored.log_density(&x));
        }
        // A document without the fields loads with all three off — the
        // additive-field degrade path for pre-pipeline readers/writers.
        let stripped = match doc.clone() {
            crate::json::Json::Obj(mut o) => {
                o.remove("learn_mode");
                o.remove("decay");
                o.remove("max_age");
                crate::json::Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let as_default = Figmn::from_json(&stripped).unwrap();
        assert_eq!(as_default.config().learn_mode, LearnMode::Online);
        assert_eq!(as_default.config().decay, 1.0);
        assert_eq!(as_default.config().max_age, 0);
        // Invalid values are rejected like any corrupt field.
        for (from, to) in [
            ("\"learn_mode\":\"minibatch:4\"", "\"learn_mode\":\"minibatch:0\""),
            ("\"learn_mode\":\"minibatch:4\"", "\"learn_mode\":\"turbo\""),
            ("\"learn_mode\":\"minibatch:4\"", "\"learn_mode\":9"),
            ("\"decay\":0.995", "\"decay\":0"),
            ("\"decay\":0.995", "\"decay\":1.5"),
            ("\"decay\":0.995", "\"decay\":\"fast\""),
            ("\"max_age\":100", "\"max_age\":\"soon\""),
        ] {
            let bad = doc.to_string_compact().replace(from, to);
            assert_ne!(bad, doc.to_string_compact(), "replacement {from} did not apply");
            assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "{to}");
        }
    }

    #[test]
    fn igmn_round_trip_preserves_behaviour() {
        let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1);
        let mut m = Igmn::new(cfg, &[2.0, 2.0, 2.0]);
        let mut rng = Pcg64::seed(41);
        for _ in 0..120 {
            let c = if rng.uniform() < 0.5 { 0.0 } else { 8.0 };
            let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
            m.learn(&x);
        }
        let doc = m.to_json();
        assert_eq!(doc.get("kind").and_then(|v| v.as_str()), Some("igmn"));
        assert_eq!(doc.get("version").and_then(|v| v.as_f64()), Some(2.0));
        let comps = doc.get("components").unwrap().as_array().unwrap();
        for c in comps {
            assert!(c.get("cov_packed").is_some(), "v2 igmn stores the packed triangle");
            assert!(c.get("cov").is_none());
            assert!(c.get("log_det").is_none(), "the baseline tracks no log_det");
        }
        let mut restored = Igmn::from_json(&parse(&doc.to_string_compact()).unwrap()).unwrap();
        assert_eq!(restored.num_components(), m.num_components());
        assert_eq!(restored.points_seen(), m.points_seen());
        for _ in 0..10 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            assert_eq!(m.log_density(&x), restored.log_density(&x));
            assert_eq!(m.posteriors(&x), restored.posteriors(&x));
        }
        // Restored baselines keep learning identically.
        for _ in 0..30 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
            assert_eq!(m.learn(&x), restored.learn(&x));
        }
        assert_eq!(m.num_components(), restored.num_components());
        // A figmn doc is not an igmn doc and vice versa.
        assert!(Igmn::from_json(&trained_model().to_json()).is_err());
        assert!(Figmn::from_json(&doc).is_err());
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        let m = trained_model();
        let good = m.to_json().to_string_compact();

        // Truncated document.
        assert!(parse(&good[..good.len() / 2]).is_err());
        // Wrong kind.
        let bad = good.replace("\"figmn\"", "\"other\"");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
        // Wrong version (v1 is still accepted — see
        // tests/checkpoint_compat.rs — but unknown versions are not).
        let bad = good.replace("\"version\":2", "\"version\":999");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
        // A v1 claim with a packed payload is rejected (v1 requires the
        // dense `lambda` field).
        let bad = good.replace("\"version\":2", "\"version\":1");
        assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err());
        // Missing field.
        assert!(Figmn::from_json(&parse(r#"{"version":1,"kind":"figmn"}"#).unwrap()).is_err());
    }
}
